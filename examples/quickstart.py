"""Quickstart: the public API in ~60 lines.

Builds a reduced RetNet (the paper's model family), trains a few steps on the
synthetic pipeline, PTQ-deploys it (SmoothQuant-free minimal path), and
generates tokens through the HSA engine's phase-dependent dataflows.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.hsa import HSAConfig, HSAEngine
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch.serve import generate
from repro.models import deploy, lm
from repro.optim import adamw
from repro.runtime import train_step as ts


def main() -> None:
    # 1. pick an architecture (any of the 10 assigned ids works here)
    cfg = configs.get_config("retnet-1.3b").reduced()
    print(f"model: {cfg.name} ({cfg.family})")

    # 2. train a few steps
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    opts = ts.TrainOptions()
    step = ts.train_step_fn(cfg, HSAEngine(), opt_cfg, opts)
    state, _, paths = ts.init_state(cfg, opt_cfg, opts, jax.random.key(0))
    data = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=4))
    jit_step = jax.jit(step, donate_argnums=(0,))
    for i in range(10):
        state, metrics = jit_step(state, jax.tree.map(jnp.asarray,
                                                      data.batch(i)))
        if i % 3 == 0:
            print(f"  step {i}: loss {float(metrics['loss']):.4f}")

    # 3. PTQ deploy: INT8 prefill + MXINT4 (4.25 bits/weight) decode formats
    served = deploy.deploy_quantize(state["params"], paths)
    n_mx = sum(v.size for p, v in
               jax.tree_util.tree_flatten_with_path(served)[0]
               if "mx_packed" in str(p[-1]))
    print(f"deployed: {n_mx / 1e6:.2f} MB packed int4 weight bytes")

    # 4. serve: prefill (W8A8 MMM dataflow) + decode (W4A8 MVM dataflow)
    engine = HSAEngine(HSAConfig())      # the paper's default format policy
    prompts = jnp.asarray(data.batch(99)["tokens"][:2, :16])
    toks, t_prefill, t_decode = generate(cfg, served, engine, prompts,
                                         n_out=12)
    print(f"generated: {toks[0].tolist()}")
    print(f"prefill {t_prefill*1e3:.0f} ms, decode {t_decode*1e3:.0f} ms")


if __name__ == "__main__":
    main()
