"""Quickstart: the public API in ~60 lines.

Builds a reduced RetNet (the paper's model family), trains a few steps on the
synthetic pipeline, then serves it through `repro.serving` — the one entry
point that owns PTQ deployment (SmoothQuant-free minimal path) and the HSA
engine's phase-dependent dataflows, with the decode loop fused on-device.

The whole serving surface is three calls::

    from repro.serving import EngineSpec, GenerationConfig, InferenceEngine

    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    result = engine.generate(prompts, GenerationConfig(max_new_tokens=32))
    result.tokens      # [B, 32] int32, padded after any stop token

`from_config` also adopts trained weights (``params=..., linear_paths=...``,
as below), and `GenerationConfig` carries temperature / top-k / top-p /
stop-token sampling controls.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.hsa import HSAEngine
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.optim import adamw
from repro.runtime import train_step as ts
from repro.serving import EngineSpec, GenerationConfig, InferenceEngine


def main() -> None:
    # 1. pick an architecture (any of the 10 assigned ids works here)
    cfg = configs.get_config("retnet-1.3b").reduced()
    print(f"model: {cfg.name} ({cfg.family})")

    # 2. train a few steps
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    opts = ts.TrainOptions()
    step = ts.train_step_fn(cfg, HSAEngine(), opt_cfg, opts)
    state, _, paths = ts.init_state(cfg, opt_cfg, opts, jax.random.key(0))
    data = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=4))
    jit_step = jax.jit(step, donate_argnums=(0,))
    for i in range(10):
        state, metrics = jit_step(state, jax.tree.map(jnp.asarray,
                                                      data.batch(i)))
        if i % 3 == 0:
            print(f"  step {i}: loss {float(metrics['loss']):.4f}")

    # 3+4. deploy + serve in one step: InferenceEngine owns the PTQ pass
    # (INT8 prefill + MXINT4 4.25-bit decode formats) and the HSA engine's
    # W8A8-MMM prefill / W4A8-MVM fused decode loop.
    engine = InferenceEngine.from_config(cfg, EngineSpec(),
                                         params=state["params"],
                                         linear_paths=paths)
    n_mx = sum(v.size for p, v in
               jax.tree_util.tree_flatten_with_path(engine.params)[0]
               if "mx_packed" in str(p[-1]))
    print(f"deployed: {n_mx / 1e6:.2f} MB packed int4 weight bytes")

    prompts = jnp.asarray(data.batch(99)["tokens"][:2, :16])
    res = engine.generate(prompts, GenerationConfig(max_new_tokens=12))
    print(f"generated: {res.tokens[0].tolist()}")
    print(f"prefill {res.prefill_s*1e3:.0f} ms, decode {res.decode_s*1e3:.0f} ms")


if __name__ == "__main__":
    main()
