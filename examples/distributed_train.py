"""Distributed training demo on a virtual 8-device mesh: FSDP/TP sharding,
checkpointing, failure injection, elastic re-mesh, straggler watch.

Must own jax device-count before init, so it re-execs itself with XLA_FLAGS:

    PYTHONPATH=src python examples/distributed_train.py
"""

import os
import subprocess
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    sys.exit(subprocess.run([sys.executable, __file__], env=env).returncode)

sys.argv = [
    "train", "--arch", "internlm2-1.8b", "--reduced",
    "--steps", "16", "--batch", "8", "--seq", "64",
    "--mesh", "tiny",                 # 2x2: data x model
    "--ckpt-dir", "/tmp/repro_dist_demo",
    "--ckpt-every", "5",
    "--fail-at", "8",                 # kill a host mid-run -> elastic re-mesh
]

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main()
