"""Edge inference — the paper's LISO/SILO evaluation, end to end (C1-C6).

Runs both scenarios (scaled for CPU) through `repro.serving.InferenceEngine`
— the real quantized serving stack with the fused decode loop — and then
projects the same workload onto the paper's 28nm accelerator and a TPU v5e
chip with the analytic edge model, reproducing the Table II metrics.

    PYTHONPATH=src python examples/edge_inference.py [--scale 0.05]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import edge_model as em
from repro.serving import EngineSpec, GenerationConfig, InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.04,
                    help="scale of the paper's 750/50 token counts")
    args = ap.parse_args()

    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    cfg = engine.cfg

    print("== measured (reduced model, CPU, real quantized stack) ==")
    for scen in (em.LISO, em.SILO):
        n_in = max(2, int(scen.tokens_in * args.scale))
        n_out = max(2, int(scen.tokens_out * args.scale))
        prompts = jax.random.randint(jax.random.key(1), (1, n_in), 1,
                                     cfg.vocab_size, dtype=jnp.int32)
        res = engine.generate(prompts,
                              GenerationConfig(max_new_tokens=n_out))
        total = n_in + n_out
        t_p, t_d = res.prefill_s, res.decode_s
        print(f"  {scen.name}: in/out {n_in}/{n_out}  "
              f"prefill {t_p*1e3:.0f}ms decode {t_d/n_out*1e3:.1f}ms/tok  "
              f"tokens/s {total/(t_p+t_d):.2f}")

    print("== projected (paper's 28nm accelerator, DDR5 51.2 GB/s) ==")
    spec = em.retnet_model_spec(params=1.34e9, n_layers=24, d_model=2048,
                                n_heads=8)
    for scen, paper in ((em.LISO, 247.38), (em.SILO, 116.55)):
        r = em.run_scenario(spec, em.PAPER_ACCEL, em.HSA, scen)
        print(f"  {scen.name}: {r.tokens_per_s:.1f} tok/s, "
              f"{r.tokens_per_s_per_mm2(em.PAPER_ACCEL):.1f} tok/s/mm^2 "
              f"(paper {paper}), decode {r.decode_mj_per_token:.1f} mJ/tok")

    print("== projected (one TPU v5e chip, HBM 819 GB/s) ==")
    v5e = em.HardwareSpec(name="tpu-v5e", peak_mac_per_s=98.5e12,
                          dram_bw=819e9, area_mm2=float("nan"))
    for scen in (em.LISO, em.SILO):
        r = em.run_scenario(spec, v5e, em.HSA, scen)
        print(f"  {scen.name}: {r.tokens_per_s:.0f} tok/s "
              f"(decode {r.decode.latency_s/scen.tokens_out*1e3:.2f} ms/tok, "
              f"{r.decode.bound}-bound)")


if __name__ == "__main__":
    main()
