"""Full PTQ pipeline (paper Section III): calibrate -> SmoothQuant ->
MXINT4/INT8 deploy -> quality report.

    PYTHONPATH=src python examples/quantize_model.py [--arch qwen3-8b]
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import smoothquant as sq
from repro.core.hsa import HSAConfig, HSAEngine
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import deploy, lm


def calibrate_and_smooth(cfg, params, batches):
    """Collect per-channel activation absmax at each block input and fold
    SmoothQuant scales into (ln gamma, first-layer weights) pairs."""
    engine = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp"))
    absmax = None
    for batch in batches:
        # calibration proxy: absmax of the embedding stream (block input)
        x = params["embed"][batch["tokens"]]
        cur = sq.collect_act_absmax(x)
        absmax = cur if absmax is None else sq.merge_absmax(absmax, cur)

    # fold into every block's ln1 gamma + first projection (wq or in_proj)
    n_folded = 0
    blocks = params["blocks"]
    first_proj = next(k for k in ("attn", "mamba", "ret")
                      if k in blocks)
    wkey = {"attn": "wq", "mamba": "in_proj", "ret": "wq"}[first_proj]
    gamma = blocks["ln1"]["g"]
    w = blocks[first_proj][wkey]["w"]

    def fold_one(g, ww):
        g2, w2, _ = sq.smooth_linear_pair(g, ww, absmax)
        return g2, w2

    g2, w2 = jax.vmap(fold_one)(gamma, w)
    blocks["ln1"]["g"] = g2
    blocks[first_proj][wkey]["w"] = w2
    n_folded = g2.shape[0]
    return params, n_folded


def logit_kl(cfg, p_ref, p_test, engine_ref, engine_test, batch):
    ref, _ = lm.forward_prefill(p_ref, batch, cfg, engine_ref,
                                cache_len=batch["tokens"].shape[1] + 2)
    tst, _ = lm.forward_prefill(p_test, batch, cfg, engine_test,
                                cache_len=batch["tokens"].shape[1] + 2)
    ref = jax.nn.log_softmax(ref.astype(jnp.float32), -1)
    tst = jax.nn.log_softmax(tst.astype(jnp.float32), -1)
    return float(jnp.mean(jnp.sum(jnp.exp(ref) * (ref - tst), axis=-1)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()
    cfg = configs.get_config(args.arch).reduced()

    params, _, paths = lm.init(cfg, jax.random.key(0))
    data = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=48,
                                        global_batch=4))
    batches = [jax.tree.map(jnp.asarray, data.batch(i)) for i in range(4)]

    print(f"[ptq] calibrating {cfg.name} on {len(batches)} batches")
    params, n = calibrate_and_smooth(cfg, params, batches)
    print(f"[ptq] SmoothQuant folded into {n} layers")

    served = deploy.deploy_quantize(params, paths)
    fp = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp"))
    w8 = HSAEngine(HSAConfig())
    w4 = HSAEngine(HSAConfig(prefill_format="mxint4"))

    eval_batch = {"tokens": batches[0]["tokens"][:2]}
    kl8 = logit_kl(cfg, params, served, fp, w8, eval_batch)
    kl4 = logit_kl(cfg, params, served, fp, w4, eval_batch)
    print(f"[ptq] logit KL vs FP: W8A8={kl8:.5f}  W4A8(MXINT4)={kl4:.5f}")
    print("[ptq] (paper Table III: W4A8 MXINT4 tracks W8A8 closely; "
          "naive INT4 would collapse)")


if __name__ == "__main__":
    main()
