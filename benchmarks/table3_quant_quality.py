"""Table III/IV proxy: quantization quality of MXINT4 (4b shift) vs
comparators.

WikiText2/GSM8K are not available offline, so we reproduce the tables'
*relative orderings* with measurable proxies on a reduced RetNet +
Llama-style dense model:

  * weight-space MSE / SNR per scheme,
  * end-to-end logit KL divergence vs the FP16 model (the ppl-delta proxy),
  * greedy-decode agreement.

Expected orderings (the paper's claims): W8A8 ~ FP16 > MXINT4-W4A8 (close)
>> naive per-tensor INT4 (collapses, cf. V3Q rows blowing up to 1e35 ppl).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mxint4 as mx
from repro.serving import EngineSpec, InferenceEngine

from benchmarks.bench_lib import emit, time_fn


def weight_mse() -> None:
    rng = np.random.default_rng(0)
    w = rng.normal(size=(512, 1024)).astype(np.float32) * 0.02
    w[rng.integers(0, 512, 8), rng.integers(0, 1024, 8)] *= 40  # outliers
    w = jnp.asarray(w)
    ref_pow = float(jnp.mean(w ** 2))

    def snr(wq):
        return 10 * np.log10(ref_pow / float(jnp.mean((w - wq) ** 2)))

    q4 = mx.quantize_mxint4(w)
    emit("table3.weight_snr_db.mxint4_4bshift",
         time_fn(lambda: mx.dequantize_mxint4(q4, jnp.float32)),
         f"{snr(mx.dequantize_mxint4(q4, jnp.float32)):.1f}")
    mant, scale = mx.quantize_int4_fp16_scale(w)
    emit("table3.weight_snr_db.int4_fp16scale", 0.0,
         f"{snr(mx.dequantize_int4_fp16_scale(mant, scale)):.1f}")
    q8 = mx.quantize_int8_tensor(w)
    emit("table3.weight_snr_db.int8_tensor", 0.0,
         f"{snr(mx.dequantize_int8(q8, jnp.float32)):.1f}")
    mant, scale = mx.quantize_int4_naive(w)
    emit("table3.weight_snr_db.int4_naive", 0.0,
         f"{snr(mx.dequantize_int4_naive(mant, scale)):.1f} (collapses)")


def logit_kl() -> None:
    # Engine variants share one set of weights: the fp engine keeps masters,
    # the quantized ones PTQ-deploy those same masters via from_config.
    fp = InferenceEngine.from_config(
        "retnet-1.3b", EngineSpec(reduced=True, quantize=False))
    cfg = fp.cfg
    w8 = InferenceEngine.from_config(cfg, EngineSpec(prefill_format="w8a8"),
                                     params=fp.params)
    # mxint4 on the prefill path = W4A8 everywhere (stress case); reuses
    # w8's already-deployed tree rather than re-running the PTQ pass
    w4 = InferenceEngine(cfg, w8.params, EngineSpec(prefill_format="mxint4"))
    toks = jax.random.randint(jax.random.key(1), (4, 48), 1, cfg.vocab_size)

    def logits(engine):
        lg, _ = engine.prefill(toks, cache_len=50)
        return jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)

    ref = logits(fp)

    def kl(lg):
        return float(jnp.mean(jnp.sum(jnp.exp(ref) * (ref - lg), axis=-1)))

    kl8 = kl(logits(w8))
    emit("table3.logit_kl.w8a8", 0.0, f"{kl8:.5f}")
    kl4 = kl(logits(w4))
    emit("table3.logit_kl.w4a8_mxint4", 0.0,
         f"{kl4:.5f} (paper: ppl 18.22 vs 17.97 W8A8 - small gap)")
    # naive int4: quantize every master to per-tensor int4
    def naive(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = naive(v)
            elif k == "w":
                m, s = mx.quantize_int4_naive(v)
                out[k] = mx.dequantize_int4_naive(m, s).astype(v.dtype)
            else:
                out[k] = v
        return out

    kln = kl(logits(InferenceEngine.from_config(
        cfg, EngineSpec(quantize=False), params=naive(fp.params))))
    emit("table3.logit_kl.int4_naive", 0.0,
         f"{kln:.5f} (paper: V3Q-style collapse, ppl 1e35)")
    ordering_ok = kl8 <= kl4 * 1.5 and kl4 * 3 < kln
    emit("table3.ordering_w8a8<=mxint4<<naive", 0.0, str(ordering_ok))


def kv_cache_quality() -> None:
    """Quantized-KV residency legs (the flash-decode tentpole's cache side).

    Fp *weights* throughout so the cache encoding is the only variable:
      * next-step logit KL of a decode step reading an int8_tok / mxint4_blk
        cache vs the same step reading the fp cache it was encoded from,
      * greedy-decode agreement of full generates under each residency.
    Expected ordering mirrors the weight table: int8_tok ~ fp (per-token
    scales) > mxint4_blk (shared block exponents) >> nothing collapses —
    both stay usable, that is the EMA trade the paper's DRAM rung buys.
    """
    from repro.models import lm
    from repro.serving import GenerationConfig

    eng = InferenceEngine.from_config(
        "qwen3-8b", EngineSpec(reduced=True, quantize=False))
    cfg = eng.cfg
    toks = jax.random.randint(jax.random.key(2), (2, 32), 1, cfg.vocab_size,
                              dtype=jnp.int32)
    n_new = 24
    lg, cache = eng.prefill(toks, cache_len=32 + n_new)
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    ref_lg, _ = eng.decode_step(tok, cache)
    ref = jax.nn.log_softmax(ref_lg.astype(jnp.float32), -1)

    base = eng.generate(toks, GenerationConfig(max_new_tokens=n_new))
    kls = {}
    for fmt in ("int8_tok", "mxint4_blk"):
        qlg, _ = eng.decode_step(tok, lm.quantize_cache(cache, cfg, fmt))
        q = jax.nn.log_softmax(qlg.astype(jnp.float32), -1)
        kls[fmt] = float(jnp.mean(jnp.sum(jnp.exp(ref) * (ref - q), -1)))
        emit(f"table3.kv_cache_kl.{fmt}", 0.0, f"{kls[fmt]:.6f}")
        res = eng.generate(toks, GenerationConfig(max_new_tokens=n_new,
                                                  cache_format=fmt))
        agree = float(jnp.mean(res.tokens == base.tokens))
        # On the reduced random-weight model one flipped argmax derails the
        # whole greedy tail, so also report the agreed prefix (steps until
        # first divergence) — the trained-model-relevant number.
        prefix = float(jnp.mean(jnp.argmin(
            jnp.pad(res.tokens == base.tokens, ((0, 0), (0, 1))), axis=1)))
        emit(f"table3.kv_greedy_agreement.{fmt}", 0.0,
             f"{agree:.3f} (agreed prefix {prefix:.1f}/{n_new})")
    emit("table3.kv_ordering_int8_tok<=mxint4", 0.0,
         str(kls["int8_tok"] <= kls["mxint4_blk"]))


def run() -> None:
    weight_mse()
    logit_kl()
    kv_cache_quality()


if __name__ == "__main__":
    run()
