"""Fig. 1(b): op/latency/energy breakdown of RetNet-1.3B on a Jetson-class
edge reference, LISO vs SILO."""

from repro.core import edge_model as em
from repro.core.hsa import HSA

from benchmarks.bench_lib import emit

SPEC = em.retnet_model_spec(params=1.34e9, n_layers=24, d_model=2048,
                            n_heads=8, name="retnet-1.3b")


def run() -> None:
    for scen in (em.LISO, em.SILO):
        r = em.run_scenario(SPEC, em.JETSON_ORIN_NANO, HSA, scen,
                            prefill_bits=16.0, decode_bits=16.0)
        dec_lat = r.decode.latency_s / r.latency_s
        dec_en = r.decode.energy_j / r.energy_j
        emit(f"fig1.{scen.name}.decode_latency_share", 0.0,
             f"{dec_lat:.3f} (paper: >0.8 LISO incl. framework overhead)")
        emit(f"fig1.{scen.name}.decode_energy_share", 0.0, f"{dec_en:.3f}")
        util = (SPEC.macs_per_token * scen.tokens_out
                / r.decode.latency_s / em.JETSON_ORIN_NANO.peak_mac_per_s)
        emit(f"fig1.{scen.name}.decode_peak_utilization", 0.0,
             f"{util:.4f} (paper: ~0.017)")
        emit(f"fig1.{scen.name}.prefill_bound", 0.0, r.prefill.bound)
        emit(f"fig1.{scen.name}.decode_bound", 0.0, r.decode.bound)


if __name__ == "__main__":
    run()
