"""Table V: dequantization-scaling hardware overhead, 4-bit shift vs INT8 vs
FP16 scales.

The paper's numbers are silicon area/power (shift register vs multiplier):
4b-shift 1.0x, INT8 10.33x area / 7.19x power, FP16 15.96x / 9.60x.  We
report those constants alongside what this framework *can* measure: per-format
dequant op counts/bytes in the kernel's dataflow and measured dequant wall
time on CPU (directionally consistent: shifts are cheapest).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mxint4 as mx

from benchmarks.bench_lib import emit, time_fn

PAPER = {"4bit_shift": (1.0, 1.0), "int8": (10.33, 7.19), "fp16": (15.96, 9.60)}


def run() -> None:
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4096, 4096)).astype(np.float32) * 0.02)

    q4 = mx.quantize_mxint4(w)
    t_shift = time_fn(jax.jit(lambda q: mx.dequantize_mxint4(q, jnp.float32)), q4)
    mant8, s8 = jnp.clip(jnp.round(w / 0.001), -127, 127).astype(jnp.int8), 0.001
    t_int8 = time_fn(jax.jit(lambda m: m.astype(jnp.float32) * s8), mant8)
    mant, sc = mx.quantize_int4_fp16_scale(w)
    t_fp16 = time_fn(jax.jit(mx.dequantize_int4_fp16_scale), mant, sc)

    for name, t in (("4bit_shift", t_shift), ("int8", t_int8), ("fp16", t_fp16)):
        a, p = PAPER[name]
        emit(f"table5.dequant.{name}", t,
             f"paper_area={a}x paper_power={p}x")
    # wire bytes per weight (the EMA side of the trade)
    emit("table5.bits_per_weight.mxint4", 0.0,
         f"{q4.nbytes_streamed() * 8 / w.size:.2f}")
    emit("table5.bits_per_weight.int4_fp16scale", 0.0,
         f"{(mant.size // 2 + sc.size * 2) * 8 / w.size:.2f}")


if __name__ == "__main__":
    run()
