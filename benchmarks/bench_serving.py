"""Serving-path benchmark: the chunked/bucketed admission path end to end,
plus the speculative multi-token decode path.

Drives the `RequestScheduler` (paged pool + chunk-granular admissions) over a
mixed LISO/SILO-ish request stream on the reduced RetNet config, then the
speculative draft/verify loop on a long-output prompt whose greedy
continuation saturates into repetition (the ngram drafter's best case — and
the regime the paper's EMA argument cares about: every accepted draft is one
fewer weight-stream read).  Each run *appends* to ``BENCH_serving.json`` so
successive PRs accumulate a perf trajectory instead of overwriting it:

    tokens_per_s          sustained prompt+output tokens / wall second
    prefill_compiles      distinct prefill shapes dispatched (ladder size —
                          the old admission path paid one per prompt length)
    decode_stall_steps    sequencer cycles that did admission work with no
                          resident lane emitting (ramp-up only, ideally)
    steps / prefill_chunks / emitted   raw sequencer counters
    speculative.tokens_per_step        committed tokens per verify step
                          (> 2.0 means > 1 accepted draft per weight read)
    speculative.acceptance_rate        accepted / drafted
    oversubscribed.*      the host-spill leg: requests > device lanes, a
                          high-priority burst preempting residents to host
                          memory (spills/fetches/bytes moved each way)
    decode_roofline.*     per-leg analytic decode-step roofline (modeled
                          bytes/token, step time, memory/compute bound) and
                          achieved_roofline_fraction = modeled / measured
                          decode wall (~0 on CPU CI — the model's constants
                          are the TPU chip — but trajectory-comparable)
    quantized_decode.*    the quantized-KV residency leg: the same greedy
                          generate with the cache fp32 vs int8_tok vs
                          mxint4_blk, with modeled + resident cache-byte
                          reduction ratios (the paper's EMA claim: >= 2x)
    sharded.*             the multi-chip leg: the same generate on a 2x2
                          (data, model) mesh of virtual host devices —
                          device count, axis shape, and per-device vs
                          global cache bytes per record (subprocess: the
                          XLA device-count flag must precede jax init)
    prefix_reuse.*        the shared-prefix leg: the same request stream at
                          0% / 50% / 100% repeated-system-prompt fractions
                          through a `prefix_cache=True` scheduler — per
                          fraction the prefix hit rate, prefill tokens
                          skipped (and the resulting prefill-token
                          reduction), prefill chunks dispatched, and p50
                          TTFT; the 100% leg also replays cold
                          (prefix_cache=False) to record the TTFT delta and
                          assert greedy outputs stay token-identical
    goodput_under_load.*  the open-loop front-end leg: seeded Poisson
                          arrivals through `ServingFrontend`'s SLO-aware
                          admission at >= 3 offered rates (multiples of a
                          calibrated closed-loop service rate, so the sweep
                          spans under- to over-load on any host) — per rate
                          the goodput (requests meeting the TTFT SLO per
                          second), shed rate, unexplained-shed count, and
                          the standard `latency` block; plus a greedy
                          token-identity check of the front end vs a direct
                          `RequestScheduler.run()` on the same request set
    latency.*             per-leg SLO block from the `repro.obs` registry:
                          p50/p95/p99 TTFT and inter-token latency, plus
                          queue-depth / cache-occupancy gauge summaries on
                          the scheduler-driven legs (every leg carries one)
    git_rev               short rev of the checkout, so trajectory points
                          correlate with PRs

    PYTHONPATH=src python -m benchmarks.bench_serving [out.json]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp

from benchmarks.roofline import decode_step_model
from repro.obs import Observability
from repro.serving import (EngineSpec, FrontendConfig, GenerationConfig,
                           InferenceEngine, LengthMix, MonotonicClock,
                           PoissonArrivals, Request, RequestScheduler,
                           ServingFrontend, SpeculativeConfig, Workload,
                           run_open_loop)

N_REQUESTS = 12
PROMPT_LENGTHS = [6, 11, 23, 37, 48, 75]     # mixed LISO/SILO-ish, 6 distinct
MAX_NEW_TOKENS = 12
CHUNK_SIZE = 16

# Oversubscribed leg: more requests than device lanes, resolved by the host
# spill tier + priority preemption instead of hard queueing.
OVER_REQUESTS = 6
OVER_LANES = 2
OVER_PROMPT = 16
OVER_NEW_TOKENS = 8


def git_rev() -> str:
    """Short git rev of the working tree, so trajectory points correlate
    with PRs; 'unknown' outside a checkout (e.g. an sdist install)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"

def decode_roofline(cfg, *, cache_len: int, n_tokens: int, wall_s: float,
                    batch: int = 1, cache_format: str | None = None) -> dict:
    """Modeled decode-step roofline + achieved fraction for one measured leg.

    The model (`benchmarks.roofline.decode_step_model`) prices the leg's
    *exact* config instance — the reduced CPU-scale one being benched, not
    the paper-scale arch — with MXINT4 weight streaming and the leg's cache
    residency format (None = the engine's fp32 cache).
    ``achieved_roofline_fraction`` = modeled decode wall / measured decode
    wall; ~0 on CPU CI (the model's peak/BW constants are the TPU chip) and
    meaningful on device, but its *trajectory* is comparable either way.
    """
    fmt = cache_format or "float32"
    m = decode_step_model(cfg, cache_len=cache_len, batch=batch,
                          cache_format=fmt)
    modeled_wall = m["step_s"] * (n_tokens / max(batch, 1))
    return {
        "cache_format": fmt,
        "modeled_step_s": m["step_s"],
        "modeled_bytes_per_token": round(m["bytes_per_token"], 1),
        "modeled_cache_bytes": round(m["cache_bytes"], 1),
        "bound": m["bound"],
        "achieved_roofline_fraction":
            round(modeled_wall / wall_s, 6) if wall_s > 0 else 0.0,
    }


def _round_stats(d: dict, nd: int = 6) -> dict:
    return {k: (round(v, nd) if isinstance(v, float) else v)
            for k, v in d.items()}


def latency_summary(obs: Observability, prefix: str) -> dict:
    """One leg's SLO latency block: p50/p95/p99 TTFT + inter-token latency
    (seconds, from the leg's `repro.obs` registry — ``prefix`` is ``sched``
    for scheduler-driven legs, ``engine`` for direct-generate legs) plus the
    queue-depth / occupancy gauge summaries the leg accumulated."""
    snap = obs.metrics.snapshot()
    hists, gauges = snap["histograms"], snap["gauges"]
    out = {
        "ttft_s": _round_stats(hists.get(f"{prefix}.ttft_s", {"count": 0})),
        "inter_token_s": _round_stats(
            hists.get(f"{prefix}.inter_token_s", {"count": 0})),
    }
    if gauges:
        out["occupancy"] = {name: _round_stats(g)
                            for name, g in sorted(gauges.items())}
    return out


# Speculative leg: reduced starcoder2's greedy continuation of this seed
# saturates into a repeating tail — the "long repetitive output" regime where
# prompt-lookup drafting pays (code generation / extraction analogue).
SPEC_ARCH = "starcoder2-15b"
SPEC_SEED = 9
SPEC_MAX_NEW = 96
SPEC_K = 4


def run_scheduler() -> dict:
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=MAX_NEW_TOKENS)
    small = max(l for l in PROMPT_LENGTHS if l <= 24) + MAX_NEW_TOKENS
    large = max(PROMPT_LENGTHS) + MAX_NEW_TOKENS
    obs = Observability()
    sched = RequestScheduler(engine, classes=[(2, small), (2, large)],
                             gen=gen, chunk_size=CHUNK_SIZE,
                             key=jax.random.key(0), obs=obs)

    lengths = [PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)]
               for i in range(N_REQUESTS)]
    for uid, s in enumerate(lengths):
        prompt = jax.random.randint(
            jax.random.fold_in(jax.random.key(1), uid), (s,), 1,
            engine.cfg.vocab_size, dtype=jnp.int32)
        sched.submit(Request(uid=uid, prompt=prompt.tolist()))

    t0 = time.perf_counter()
    results = sched.run()
    wall_s = time.perf_counter() - t0

    total_tokens = (sum(lengths)
                    + sum(len(r.tokens) for r in results.values()))
    return {
        "bench": "serving",
        "arch": engine.cfg.name,
        "n_requests": N_REQUESTS,
        "distinct_prompt_lengths": len(set(lengths)),
        "chunk_size": CHUNK_SIZE,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(total_tokens / wall_s, 2),
        "prefill_compiles": engine.prefill_compiles,
        "decode_stall_steps": sched.stats["decode_stall_steps"],
        "steps": sched.stats["steps"],
        "prefill_chunks": sched.stats["prefill_chunks"],
        "emitted": sched.stats["emitted"],
        # Compiled-signature census per jit entry point (engine roots +
        # the scheduler's pool steps) — the raw numbers behind the
        # `python -m repro.analysis audit` recompile bound, kept in the
        # trajectory so a signature-count regression shows up PR-over-PR.
        "compiled_signatures": {**engine.compile_counts(),
                                **sched.compile_counts()},
        # Conservative: wall_s includes admission/prefill work, so this
        # under-reports the pure-decode fraction.
        "decode_roofline": decode_roofline(
            engine.cfg, cache_len=large, n_tokens=sched.stats["emitted"],
            wall_s=wall_s),
        "latency": latency_summary(obs, "sched"),
    }


def run_speculative() -> dict:
    engine = InferenceEngine.from_config(SPEC_ARCH, EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=SPEC_MAX_NEW)
    prompt = jax.random.randint(jax.random.key(SPEC_SEED), (1, 10), 1,
                                engine.cfg.vocab_size, dtype=jnp.int32)
    spec_cfg = SpeculativeConfig(k=SPEC_K)
    # Warm both programs first: the plain while_loop and the speculative
    # loop compile separately, and on the reduced model trace+compile is a
    # large fraction of the decode walls being compared.
    engine.generate(prompt, gen)
    engine.generate(prompt, gen, speculative=spec_cfg)
    # Fresh bundle after warmup so the percentiles cover measured runs only
    # (the warm calls' walls are dominated by trace+compile).
    engine.obs = Observability()
    base = engine.generate(prompt, gen)
    spec = engine.generate(prompt, gen, speculative=spec_cfg)
    return {
        "arch": engine.cfg.name,
        "drafter": "ngram",
        "k": SPEC_K,
        "max_new_tokens": SPEC_MAX_NEW,
        "verify_steps": spec.verify_steps,
        "accepted_drafts": spec.accepted_drafts,
        "tokens_per_step": round(spec.tokens_per_step, 3),
        "acceptance_rate": round(spec.acceptance_rate, 3),
        "baseline_decode_s": round(base.decode_s, 3),
        "decode_s": round(spec.decode_s, 3),
        "decode_roofline": decode_roofline(
            engine.cfg, cache_len=10 + SPEC_MAX_NEW, n_tokens=SPEC_MAX_NEW,
            wall_s=base.decode_s),
        "latency": latency_summary(engine.obs, "engine"),
    }


def run_oversubscribed() -> dict:
    """Host-spill leg: OVER_REQUESTS requests over OVER_LANES device lanes.

    The default-priority residents fill the pool, then a high-priority burst
    preempts them into the host tier; everything drains (spilled lanes
    resume bit-exactly), and the record carries the spill/fetch/bytes-moved
    stats so the trajectory shows the host tier's traffic.
    """
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=OVER_NEW_TOKENS)
    clen = OVER_PROMPT + OVER_NEW_TOKENS
    obs = Observability()
    sched = RequestScheduler(engine, classes=[(OVER_LANES, clen)], gen=gen,
                             chunk_size=CHUNK_SIZE, host_spill=True,
                             key=jax.random.key(0), obs=obs)

    def submit(uid, priority=0):
        prompt = jax.random.randint(
            jax.random.fold_in(jax.random.key(3), uid), (OVER_PROMPT,), 1,
            engine.cfg.vocab_size, dtype=jnp.int32)
        sched.submit(Request(uid=uid, prompt=prompt.tolist()),
                     priority=priority)

    t0 = time.perf_counter()
    for uid in range(OVER_LANES):
        submit(uid)
    while sched.stats["admitted"] < OVER_LANES:    # residents in place
        sched.step()
    for uid in range(OVER_LANES, OVER_REQUESTS):   # the high-priority burst
        submit(uid, priority=1)
    results = sched.run()
    wall_s = time.perf_counter() - t0

    assert len(results) == OVER_REQUESTS
    assert all(len(r.tokens) == OVER_NEW_TOKENS for r in results.values())
    total = OVER_REQUESTS * (OVER_PROMPT + OVER_NEW_TOKENS)
    return {
        "n_requests": OVER_REQUESTS,
        "device_lanes": OVER_LANES,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(total / wall_s, 2),
        "preempted": sched.stats["preempted"],
        "resumed": sched.stats["resumed"],
        **sched.pool.spill_stats,
        "decode_roofline": decode_roofline(
            engine.cfg, cache_len=clen,
            n_tokens=OVER_REQUESTS * OVER_NEW_TOKENS, wall_s=wall_s),
        "latency": latency_summary(obs, "sched"),
    }


# Quantized-KV decode leg: the same greedy generate on a GQA arch with the
# decode-residency cache fp32 vs int8_tok vs MXINT4 — the tentpole's EMA
# claim, measured.  Modeled cache bytes/token must drop >= 2x quantized vs
# fp (the record carries the ratio so the trajectory proves it PR-over-PR).
QUANT_ARCH = "qwen3-8b"
QUANT_PROMPT = 12
QUANT_NEW = 16


def run_quantized_decode() -> dict:
    engine = InferenceEngine.from_config(QUANT_ARCH, EngineSpec(reduced=True))
    prompt = jax.random.randint(jax.random.key(7), (1, QUANT_PROMPT), 1,
                                engine.cfg.vocab_size, dtype=jnp.int32)
    clen = QUANT_PROMPT + QUANT_NEW
    legs: dict[str, dict] = {}
    base = None
    for fmt in (None, "int8_tok", "mxint4_blk"):
        gen = GenerationConfig(max_new_tokens=QUANT_NEW, cache_format=fmt)
        engine.generate(prompt, gen)                 # warm/compile
        engine.obs = Observability()                 # per-format latency leg
        res = engine.generate(prompt, gen)
        leg = decode_roofline(engine.cfg, cache_len=clen, n_tokens=QUANT_NEW,
                              wall_s=res.decode_s, cache_format=fmt)
        leg["latency"] = latency_summary(engine.obs, "engine")
        leg["decode_s"] = round(res.decode_s, 3)
        leg["resident_cache_nbytes"] = engine.cache_nbytes(
            clen, dtype=fmt or jnp.float32)
        base = base or leg
        leg["cache_bytes_reduction_x"] = round(
            base["modeled_cache_bytes"] / leg["modeled_cache_bytes"], 2)
        leg["resident_reduction_x"] = round(
            base["resident_cache_nbytes"] / leg["resident_cache_nbytes"], 2)
        legs[fmt or "fp32"] = leg
    return legs


# Shared-prefix leg: a repeated "system prompt" workload on the paged GQA
# arch.  The shared prefix is long relative to the per-request suffix (the
# system-prompt regime the radix tier targets): at PREFIX_SHARED tokens the
# prefill chunks an adoption skips cost far more than the page gather-copy
# that replaces them, so the TTFT trend is visible even on reduced CPU runs.
# PREFIX_SHARED is chunk-aligned so the hit length is format-independent.
PREFIX_ARCH = "qwen3-8b"
PREFIX_SHARED = 96
PREFIX_SUFFIX = 8
PREFIX_REQUESTS = 8
PREFIX_NEW_TOKENS = 8
PREFIX_CHUNK = 8
PREFIX_REPS = 3


def run_prefix_reuse() -> dict:
    """Shared-prefix reuse leg: sweep the fraction of requests that repeat
    one system prompt and record how hit rate buys back prefill work.

    Every fraction runs the same scheduler shape with ``prefix_cache=True``;
    the 100%-shared point additionally replays the identical stream cold
    (``prefix_cache=False``) to record ``ttft_p50_delta_s`` (warm − cold,
    negative is a win) and assert the greedy outputs are token-identical —
    adoption must be a pure prefill shortcut, never a sampling change.
    """
    engine = InferenceEngine.from_config(PREFIX_ARCH, EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=PREFIX_NEW_TOKENS)
    clen = PREFIX_SHARED + PREFIX_SUFFIX + PREFIX_NEW_TOKENS

    def prompts_for(frac: float, base: int) -> list[list[int]]:
        shared = jax.random.randint(jax.random.key(base), (PREFIX_SHARED,),
                                    1, engine.cfg.vocab_size,
                                    dtype=jnp.int32).tolist()
        n_shared = round(PREFIX_REQUESTS * frac)
        out = []
        for uid in range(PREFIX_REQUESTS):
            head = shared if uid < n_shared else jax.random.randint(
                jax.random.fold_in(jax.random.key(base + 2), uid),
                (PREFIX_SHARED,), 1, engine.cfg.vocab_size,
                dtype=jnp.int32).tolist()
            tail = jax.random.randint(
                jax.random.fold_in(jax.random.key(base + 6), uid),
                (PREFIX_SUFFIX,), 1, engine.cfg.vocab_size,
                dtype=jnp.int32).tolist()
            out.append(head + tail)
        return out

    def drain(frac: float, prefix_cache: bool):
        """One measured leg.  The scheduler's decode dispatch is jitted per
        instance, so a warmup stream over a *disjoint* shared prefix (base
        seed 41: same lengths and adoption shapes, zero radix overlap) pays
        every trace/compile first; the registry then resets, and PREFIX_REPS
        back-to-back streams — each repeating its *own* fresh system prompt —
        accumulate clean counters and latency samples on the warm instance.
        """
        obs = Observability()
        sched = RequestScheduler(engine, classes=[(2, clen)], gen=gen,
                                 chunk_size=PREFIX_CHUNK,
                                 key=jax.random.key(0),
                                 prefix_cache=prefix_cache, obs=obs)
        for uid, p in enumerate(prompts_for(1.0, base=41)):
            sched.submit(Request(uid=1000 + uid, prompt=p))
        sched.run()
        obs.metrics.reset()
        results: dict[int, object] = {}
        wall_s = 0.0
        for rep in range(PREFIX_REPS):
            prompts = prompts_for(frac, base=11 + 13 * rep)
            for i, p in enumerate(prompts):
                sched.submit(Request(uid=100 * rep + i, prompt=p))
            t0 = time.perf_counter()
            results.update(sched.run())
            wall_s += time.perf_counter() - t0
        return results, sched, obs, wall_s

    total_prompt = PREFIX_REPS * PREFIX_REQUESTS * (PREFIX_SHARED
                                                    + PREFIX_SUFFIX)
    legs: dict[str, dict] = {}
    for frac in (0.0, 0.5, 1.0):
        results, sched, obs, wall_s = drain(frac, True)
        stats = sched.pool.prefix.stats
        skipped = stats["prefix_hit_tokens"]
        leg = {
            "shared_fraction": frac,
            "n_requests": PREFIX_REQUESTS,
            "reps": PREFIX_REPS,
            "wall_s": round(wall_s, 3),
            "prefix_lookups": stats["prefix_lookups"],
            "prefix_hits": stats["prefix_hits"],
            "hit_rate": round(stats["prefix_hits"]
                              / max(stats["prefix_lookups"], 1), 3),
            "prefill_tokens_total": total_prompt,
            "prefill_tokens_skipped": skipped,
            "prefill_token_reduction": round(skipped / total_prompt, 3),
            "prefill_chunks": sched.stats["prefill_chunks"],
            "cow_copies": stats["cow_copies"],
            "pages_inserted": stats["prefix_insert_pages"],
            "latency": latency_summary(obs, "sched"),
        }
        if frac == 1.0:
            cold_results, _, cold_obs, cold_wall = drain(1.0, False)
            leg["token_identical_vs_cold"] = all(
                results[u].tokens == cold_results[u].tokens
                for u in cold_results)
            warm_p50 = leg["latency"]["ttft_s"].get("p50", 0.0)
            cold_p50 = latency_summary(
                cold_obs, "sched")["ttft_s"].get("p50", 0.0)
            leg["cold_wall_s"] = round(cold_wall, 3)
            leg["ttft_p50_delta_s"] = round(warm_p50 - cold_p50, 6)
        legs[f"shared_{int(frac * 100)}"] = leg
    return legs


# Goodput-under-load leg: the open-loop front end on the REAL clock (wall
# time is the point of this leg; the virtual clock belongs to tests and the
# CI smoke).  Offered rates are multiples of a calibrated closed-loop
# service rate, so the sweep spans under- to over-load regardless of how
# fast the host is.
GOODPUT_ARCH = "retnet-1.3b"
GOODPUT_REQUESTS = 8
GOODPUT_PROMPT_MIN = 6
GOODPUT_PROMPT_MAX = 24
GOODPUT_NEW = 8
GOODPUT_LANES = 2
GOODPUT_CHUNK = 8
GOODPUT_RATE_MULTS = (0.5, 1.5, 4.0)


def run_goodput_under_load() -> dict:
    """Open-loop goodput sweep through `ServingFrontend`.

    Calibrates a closed-loop drain first (also the compile warmup shape),
    derives the TTFT SLO target and the base offered rate from it, then
    sweeps `GOODPUT_RATE_MULTS` x base with seeded Poisson arrivals — each
    leg on a fresh scheduler warmed and registry-reset before measurement.
    A final check drives the same request set through the front end and a
    direct ``RequestScheduler.run()`` and records greedy token identity.
    """
    engine = InferenceEngine.from_config(GOODPUT_ARCH, EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=GOODPUT_NEW)
    mix = LengthMix(prompt_min=GOODPUT_PROMPT_MIN,
                    prompt_max=GOODPUT_PROMPT_MAX,
                    new_min=GOODPUT_NEW, new_max=GOODPUT_NEW)
    clen = GOODPUT_PROMPT_MAX + GOODPUT_NEW
    # One request-set shape for everything: calibration, warmups, the sweep
    # (per-leg arrival times differ; sizes/prompts are re-derived per seed).
    warm_wl = Workload(arrivals=PoissonArrivals(1.0), lengths=mix,
                       n_requests=GOODPUT_REQUESTS,
                       vocab_size=engine.cfg.vocab_size, seed=29)

    def make_sched(obs, clock):
        return RequestScheduler(engine, classes=[(GOODPUT_LANES, clen)],
                                gen=gen, chunk_size=GOODPUT_CHUNK,
                                key=jax.random.key(0), obs=obs,
                                clock=clock.now)

    def closed_drain(sched, uid_base=5000):
        for i, r in enumerate(warm_wl.requests()):
            sched.submit(Request(uid=uid_base + i, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens))
        return sched.run()

    # Calibration: a warmed closed-loop drain of the request-set shape.
    obs = Observability()
    clock = MonotonicClock()
    sched = make_sched(obs, clock)
    closed_drain(sched, uid_base=5000)            # trace/compile warmup
    obs.metrics.reset()
    t0 = time.perf_counter()
    closed_drain(sched, uid_base=6000)
    calib_wall = max(time.perf_counter() - t0, 1e-6)
    base_rate = GOODPUT_REQUESTS / calib_wall
    calib = latency_summary(obs, "sched")
    # SLO target: 2x the calibrated closed-loop p50 TTFT (which already
    # includes queueing GOODPUT_REQUESTS over GOODPUT_LANES lanes) — met
    # comfortably under-load, breached under hard overload.
    slo_s = max(2.0 * calib["ttft_s"].get("p50", 0.05), 0.02)

    cfg = FrontendConfig(ttft_slo_s=slo_s, slo_window_s=max(4 * calib_wall,
                                                            1.0),
                         min_slo_samples=4, guaranteed_admit=GOODPUT_LANES)
    rates = []
    for mult in GOODPUT_RATE_MULTS:
        rate = base_rate * mult
        leg_obs = Observability()
        leg_clock = MonotonicClock()
        leg_sched = make_sched(leg_obs, leg_clock)
        closed_drain(leg_sched, uid_base=7000)    # warm this instance's jits
        leg_obs.metrics.reset()
        frontend = ServingFrontend(leg_sched, config=cfg, clock=leg_clock)
        workload = Workload(arrivals=PoissonArrivals(rate), lengths=mix,
                            n_requests=GOODPUT_REQUESTS,
                            vocab_size=engine.cfg.vocab_size, seed=13)

        async def drive():
            async with frontend:
                return await run_open_loop(frontend, workload)

        report = leg_clock.run(drive())
        rates.append({
            "rate_mult": mult,
            **{k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in report.to_dict().items()},
            "latency": latency_summary(leg_obs, "sched"),
        })

    # Greedy token identity: the same request set through the front end
    # (admission policy off — identity needs every request admitted,
    # arrivals paced open-loop so the interleaving differs from closed
    # loop) vs a direct `RequestScheduler.run()` with the same key.
    import asyncio

    fe_sched = make_sched(Observability(), MonotonicClock())
    fe_clock = MonotonicClock(fe_sched._now)
    frontend = ServingFrontend(
        fe_sched, config=FrontendConfig(ttft_slo_s=slo_s, shed_action="off"),
        clock=fe_clock)
    id_wl = Workload(arrivals=PoissonArrivals(base_rate), lengths=mix,
                     n_requests=GOODPUT_REQUESTS,
                     vocab_size=engine.cfg.vocab_size, seed=17)
    id_requests = id_wl.requests()

    async def drive_identity() -> dict[int, list[int]]:
        tokens: dict[int, list[int]] = {}

        async def consume(stream):
            tokens[stream.uid] = [tok async for tok in stream]

        async with frontend:
            tasks = []
            t0 = fe_clock.now()
            for r in id_requests:
                await fe_clock.sleep(t0 + r.at_s - fe_clock.now())
                stream = frontend.submit(r.prompt, uid=r.uid,
                                         max_new_tokens=r.max_new_tokens)
                tasks.append(asyncio.ensure_future(consume(stream)))
            await asyncio.gather(*tasks)
        return tokens

    fe_tokens = fe_clock.run(drive_identity())
    direct = make_sched(Observability(), MonotonicClock())
    for r in id_requests:
        direct.submit(Request(uid=r.uid, prompt=list(r.prompt),
                              max_new_tokens=r.max_new_tokens))
    direct_results = direct.run()
    identical = (set(fe_tokens) == set(direct_results) and all(
        fe_tokens[uid] == direct_results[uid].tokens
        for uid in direct_results))

    return {
        "arch": engine.cfg.name,
        "n_requests": GOODPUT_REQUESTS,
        "device_lanes": GOODPUT_LANES,
        "arrival": "poisson",
        "calibrated_service_rps": round(base_rate, 3),
        "ttft_slo_s": round(slo_s, 5),
        "token_identical_vs_run": identical,
        "rates": rates,
    }


SHARDED_MESH = "2,2"
SHARDED_DEVICES = 4
SHARDED_PROMPT = 16
SHARDED_NEW_TOKENS = 8


def run_sharded() -> dict:
    """Multi-chip leg: a warm sharded generate on a 2x2 virtual-device mesh.

    Subprocess because ``--xla_force_host_platform_device_count`` must be
    set before any jax initialization (this process already holds a
    single-device jax).  Failure degrades to an ``error`` record instead of
    sinking the whole trajectory append.
    """
    code = textwrap.dedent(f"""
        import json, time
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import (EngineSpec, GenerationConfig,
                                   InferenceEngine)

        mesh = make_serving_mesh({SHARDED_MESH!r})
        eng = InferenceEngine.from_config("retnet-1.3b",
                                          EngineSpec(reduced=True), mesh=mesh)
        prompts = jax.random.randint(jax.random.key(1),
                                     (1, {SHARDED_PROMPT}), 1,
                                     eng.cfg.vocab_size, dtype=jnp.int32)
        gen = GenerationConfig(max_new_tokens={SHARDED_NEW_TOKENS})
        eng.generate(prompts, gen)                       # warm/compile
        from repro.obs import Observability
        eng.obs = Observability()           # measured-run latency only
        t0 = time.perf_counter()
        eng.generate(prompts, gen)
        wall = time.perf_counter() - t0
        clen = {SHARDED_PROMPT} + {SHARDED_NEW_TOKENS}
        hists = eng.obs.metrics.snapshot()["histograms"]
        print("BENCH_SHARDED " + json.dumps({{
            "devices": jax.device_count(),
            "mesh_axes": {{a: int(n) for a, n in
                           zip(mesh.axis_names, mesh.devices.shape)}},
            "wall_s": round(wall, 3),
            "tokens_per_s": round(
                ({SHARDED_PROMPT} + {SHARDED_NEW_TOKENS}) / wall, 2),
            "cache_nbytes_global": eng.cache_nbytes(clen),
            "latency": {{
                "ttft_s": hists.get("engine.ttft_s", {{"count": 0}}),
                "inter_token_s": hists.get("engine.inter_token_s",
                                           {{"count": 0}}),
            }},
        }}))
    """)
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={SHARDED_DEVICES}",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "src")]
            + ([os.environ["PYTHONPATH"]] if os.environ.get("PYTHONPATH")
               else [])))
    try:
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=1200)
    except subprocess.SubprocessError as e:
        return {"error": repr(e)}
    for line in out.stdout.splitlines():
        if line.startswith("BENCH_SHARDED "):
            return json.loads(line[len("BENCH_SHARDED "):])
    return {"error": (out.stderr or "no output")[-500:]}


def run(out_path: str = "BENCH_serving.json") -> dict:
    record = run_scheduler()
    record["git_rev"] = git_rev()
    record["speculative"] = run_speculative()
    record["oversubscribed"] = run_oversubscribed()
    record["quantized_decode"] = run_quantized_decode()
    record["sharded"] = run_sharded()
    record["prefix_reuse"] = run_prefix_reuse()
    record["goodput_under_load"] = run_goodput_under_load()

    # Append to the trajectory (older single-record files become entry 0).
    history: list = []
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                prev = json.load(f)
            history = prev if isinstance(prev, list) else [prev]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(record)
    with open(out_path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json")
