"""Serving-path benchmark: the chunked/bucketed admission path end to end.

Drives the `RequestScheduler` (paged pool + chunk-granular admissions) over a
mixed LISO/SILO-ish request stream on the reduced RetNet config and writes
``BENCH_serving.json`` so successive PRs accumulate a perf trajectory:

    tokens_per_s          sustained prompt+output tokens / wall second
    prefill_compiles      distinct prefill shapes dispatched (ladder size —
                          the old admission path paid one per prompt length)
    decode_stall_steps    sequencer cycles that did admission work with no
                          resident lane emitting (ramp-up only, ideally)
    steps / prefill_chunks / emitted   raw sequencer counters

    PYTHONPATH=src python -m benchmarks.bench_serving [out.json]
"""

from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.serving import (EngineSpec, GenerationConfig, InferenceEngine,
                           Request, RequestScheduler)

N_REQUESTS = 12
PROMPT_LENGTHS = [6, 11, 23, 37, 48, 75]     # mixed LISO/SILO-ish, 6 distinct
MAX_NEW_TOKENS = 12
CHUNK_SIZE = 16


def run(out_path: str = "BENCH_serving.json") -> dict:
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=MAX_NEW_TOKENS)
    small = max(l for l in PROMPT_LENGTHS if l <= 24) + MAX_NEW_TOKENS
    large = max(PROMPT_LENGTHS) + MAX_NEW_TOKENS
    sched = RequestScheduler(engine, classes=[(2, small), (2, large)],
                             gen=gen, chunk_size=CHUNK_SIZE,
                             key=jax.random.key(0))

    lengths = [PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)]
               for i in range(N_REQUESTS)]
    for uid, s in enumerate(lengths):
        prompt = jax.random.randint(
            jax.random.fold_in(jax.random.key(1), uid), (s,), 1,
            engine.cfg.vocab_size, dtype=jnp.int32)
        sched.submit(Request(uid=uid, prompt=prompt.tolist()))

    t0 = time.perf_counter()
    results = sched.run()
    wall_s = time.perf_counter() - t0

    total_tokens = (sum(lengths)
                    + sum(len(r.tokens) for r in results.values()))
    record = {
        "bench": "serving",
        "arch": engine.cfg.name,
        "n_requests": N_REQUESTS,
        "distinct_prompt_lengths": len(set(lengths)),
        "chunk_size": CHUNK_SIZE,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(total_tokens / wall_s, 2),
        "prefill_compiles": engine.prefill_compiles,
        "decode_stall_steps": sched.stats["decode_stall_steps"],
        "steps": sched.stats["steps"],
        "prefill_chunks": sched.stats["prefill_chunks"],
        "emitted": sched.stats["emitted"],
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json")
