"""Serving-path benchmark: the chunked/bucketed admission path end to end,
plus the speculative multi-token decode path.

Drives the `RequestScheduler` (paged pool + chunk-granular admissions) over a
mixed LISO/SILO-ish request stream on the reduced RetNet config, then the
speculative draft/verify loop on a long-output prompt whose greedy
continuation saturates into repetition (the ngram drafter's best case — and
the regime the paper's EMA argument cares about: every accepted draft is one
fewer weight-stream read).  Each run *appends* to ``BENCH_serving.json`` so
successive PRs accumulate a perf trajectory instead of overwriting it:

    tokens_per_s          sustained prompt+output tokens / wall second
    prefill_compiles      distinct prefill shapes dispatched (ladder size —
                          the old admission path paid one per prompt length)
    decode_stall_steps    sequencer cycles that did admission work with no
                          resident lane emitting (ramp-up only, ideally)
    steps / prefill_chunks / emitted   raw sequencer counters
    speculative.tokens_per_step        committed tokens per verify step
                          (> 2.0 means > 1 accepted draft per weight read)
    speculative.acceptance_rate        accepted / drafted

    PYTHONPATH=src python -m benchmarks.bench_serving [out.json]
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.serving import (EngineSpec, GenerationConfig, InferenceEngine,
                           Request, RequestScheduler, SpeculativeConfig)

N_REQUESTS = 12
PROMPT_LENGTHS = [6, 11, 23, 37, 48, 75]     # mixed LISO/SILO-ish, 6 distinct
MAX_NEW_TOKENS = 12
CHUNK_SIZE = 16

# Speculative leg: reduced starcoder2's greedy continuation of this seed
# saturates into a repeating tail — the "long repetitive output" regime where
# prompt-lookup drafting pays (code generation / extraction analogue).
SPEC_ARCH = "starcoder2-15b"
SPEC_SEED = 9
SPEC_MAX_NEW = 96
SPEC_K = 4


def run_scheduler() -> dict:
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=MAX_NEW_TOKENS)
    small = max(l for l in PROMPT_LENGTHS if l <= 24) + MAX_NEW_TOKENS
    large = max(PROMPT_LENGTHS) + MAX_NEW_TOKENS
    sched = RequestScheduler(engine, classes=[(2, small), (2, large)],
                             gen=gen, chunk_size=CHUNK_SIZE,
                             key=jax.random.key(0))

    lengths = [PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)]
               for i in range(N_REQUESTS)]
    for uid, s in enumerate(lengths):
        prompt = jax.random.randint(
            jax.random.fold_in(jax.random.key(1), uid), (s,), 1,
            engine.cfg.vocab_size, dtype=jnp.int32)
        sched.submit(Request(uid=uid, prompt=prompt.tolist()))

    t0 = time.perf_counter()
    results = sched.run()
    wall_s = time.perf_counter() - t0

    total_tokens = (sum(lengths)
                    + sum(len(r.tokens) for r in results.values()))
    return {
        "bench": "serving",
        "arch": engine.cfg.name,
        "n_requests": N_REQUESTS,
        "distinct_prompt_lengths": len(set(lengths)),
        "chunk_size": CHUNK_SIZE,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(total_tokens / wall_s, 2),
        "prefill_compiles": engine.prefill_compiles,
        "decode_stall_steps": sched.stats["decode_stall_steps"],
        "steps": sched.stats["steps"],
        "prefill_chunks": sched.stats["prefill_chunks"],
        "emitted": sched.stats["emitted"],
    }


def run_speculative() -> dict:
    engine = InferenceEngine.from_config(SPEC_ARCH, EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=SPEC_MAX_NEW)
    prompt = jax.random.randint(jax.random.key(SPEC_SEED), (1, 10), 1,
                                engine.cfg.vocab_size, dtype=jnp.int32)
    spec_cfg = SpeculativeConfig(k=SPEC_K)
    # Warm both programs first: the plain while_loop and the speculative
    # loop compile separately, and on the reduced model trace+compile is a
    # large fraction of the decode walls being compared.
    engine.generate(prompt, gen)
    engine.generate(prompt, gen, speculative=spec_cfg)
    base = engine.generate(prompt, gen)
    spec = engine.generate(prompt, gen, speculative=spec_cfg)
    return {
        "arch": engine.cfg.name,
        "drafter": "ngram",
        "k": SPEC_K,
        "max_new_tokens": SPEC_MAX_NEW,
        "verify_steps": spec.verify_steps,
        "accepted_drafts": spec.accepted_drafts,
        "tokens_per_step": round(spec.tokens_per_step, 3),
        "acceptance_rate": round(spec.acceptance_rate, 3),
        "baseline_decode_s": round(base.decode_s, 3),
        "decode_s": round(spec.decode_s, 3),
    }


def run(out_path: str = "BENCH_serving.json") -> dict:
    record = run_scheduler()
    record["speculative"] = run_speculative()

    # Append to the trajectory (older single-record files become entry 0).
    history: list = []
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                prev = json.load(f)
            history = prev if isinstance(prev, list) else [prev]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(record)
    with open(out_path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json")
