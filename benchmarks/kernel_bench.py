"""Pallas kernel microbench: block-config sweep of the MXINT4 dequant-matmul
plus the flash-decode (split-KV) attention kernel.

No TPU in this container, so per-config wall time is interpret-mode (slow,
relative only); the *structural* numbers — HBM bytes per output tile,
arithmetic intensity, VMEM working set per BlockSpec — are exact and are what
the §Perf block-shape choices were made from.
"""

import numpy as np

from repro.core import mxint4 as mx
from repro.core.mxint4 import GROUP_SIZE

from benchmarks.bench_lib import emit, time_fn


def analyze(m, k, n, bm, bn, bk) -> dict:
    w_bytes = k * n * mx_bits() / 8
    x_bytes = m * k * 4
    out_bytes = m * n * 4
    flops = 2 * m * k * n + k * n * 2          # dot + dequant muls
    vmem = (bm * bk * 4) + (bk * bn // 2) + (bk * bn // (2 * GROUP_SIZE)) \
        + bm * bn * 4                          # x + packed + exps + acc
    return {
        "hbm_bytes": w_bytes + x_bytes + out_bytes,
        "intensity": flops / (w_bytes + x_bytes + out_bytes),
        "vmem_bytes": vmem,
    }


def mx_bits() -> float:
    return 4.25


def run() -> None:
    # decode matvec shapes (the paper's MVM) across block configs
    for (m, k, n) in ((8, 4096, 4096), (8, 4096, 14336), (128, 7168, 2048)):
        for (bm, bn, bk) in ((8, 128, 512), (8, 256, 512), (8, 512, 1024)):
            a = analyze(m, k, n, bm, bn, bk)
            emit(f"kernel.mxint4[{m}x{k}x{n}]b{bm}_{bn}_{bk}", 0.0,
                 f"AI={a['intensity']:.2f}flops/B "
                 f"vmem={a['vmem_bytes']/1024:.0f}KiB "
                 f"hbm={a['hbm_bytes']/1e6:.1f}MB")
    # memory-bound check: decode AI << v5e ridge (197e12/819e9 ~ 240)
    a = analyze(8, 4096, 4096, 8, 256, 512)
    emit("kernel.decode_is_memory_bound", 0.0,
         f"AI={a['intensity']:.1f} << ridge 240 -> HBM-bound, "
         "EMA cut = speedup (C2)")
    run_flash_decode()


def analyze_flash_decode(b, kv, g, d, c, fmt) -> dict:
    """Structural bytes/flops of one flash-decode dispatch: the whole cache
    streams once (split across KV grid blocks), q/out are noise."""
    from repro.core import kvq
    cache_bytes = b * c * kv * 2 * kvq.nbytes_per_row(fmt, d)
    io_bytes = 2 * b * kv * g * d * 4            # q in + out
    flops = 4 * b * kv * g * c * d               # scores + weighted sum
    return {"hbm_bytes": cache_bytes + io_bytes,
            "intensity": flops / (cache_bytes + io_bytes)}


def run_flash_decode() -> None:
    """Flash-decode leg: the byte ladder per cache format at serving context
    lengths, plus an interpret-mode wall cross-check of the kernel vs the
    jnp reference on a tiny shape (relative only — no TPU here)."""
    import jax
    import jax.numpy as jnp

    from repro.core import kvq
    from repro.kernels import ops as kops

    b, kv, g, d = 1, 8, 4, 128                   # GQA decode matvec shape
    for c in (1024, 8192):
        for fmt in ("float32", "int8_tok", "mxint4_blk"):
            a = analyze_flash_decode(b, kv, g, d, c, fmt)
            emit(f"kernel.flash_decode[c{c}]{fmt}", 0.0,
                 f"AI={a['intensity']:.2f}flops/B "
                 f"hbm={a['hbm_bytes']/1e6:.2f}MB")

    c = 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, kv, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, c, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, c, kv, d), jnp.float32)
    kv_len = jnp.int32(c - 7)
    for fmt, kk, vv in (("fp32", k, v),
                        ("int8_tok", kvq.encode(k, "int8_tok"),
                         kvq.encode(v, "int8_tok"))):
        for impl, kw in (("ref", {}), ("pallas-interp",
                                       {"interpret": True})):
            us = time_fn(lambda: kops.flash_decode(
                q, kk, vv, kv_len,
                impl="ref" if impl == "ref" else "pallas", **kw))
            emit(f"kernel.flash_decode[c{c}]{fmt}.{impl}", us,
                 "interpret-mode wall, relative only")


if __name__ == "__main__":
    run()
