"""Pallas kernel microbench: block-config sweep of the MXINT4 dequant-matmul.

No TPU in this container, so per-config wall time is interpret-mode (slow,
relative only); the *structural* numbers — HBM bytes per output tile,
arithmetic intensity, VMEM working set per BlockSpec — are exact and are what
the §Perf block-shape choices were made from.
"""

import numpy as np

from repro.core import mxint4 as mx
from repro.core.mxint4 import GROUP_SIZE

from benchmarks.bench_lib import emit


def analyze(m, k, n, bm, bn, bk) -> dict:
    w_bytes = k * n * mx_bits() / 8
    x_bytes = m * k * 4
    out_bytes = m * n * 4
    flops = 2 * m * k * n + k * n * 2          # dot + dequant muls
    vmem = (bm * bk * 4) + (bk * bn // 2) + (bk * bn // (2 * GROUP_SIZE)) \
        + bm * bn * 4                          # x + packed + exps + acc
    return {
        "hbm_bytes": w_bytes + x_bytes + out_bytes,
        "intensity": flops / (w_bytes + x_bytes + out_bytes),
        "vmem_bytes": vmem,
    }


def mx_bits() -> float:
    return 4.25


def run() -> None:
    # decode matvec shapes (the paper's MVM) across block configs
    for (m, k, n) in ((8, 4096, 4096), (8, 4096, 14336), (128, 7168, 2048)):
        for (bm, bn, bk) in ((8, 128, 512), (8, 256, 512), (8, 512, 1024)):
            a = analyze(m, k, n, bm, bn, bk)
            emit(f"kernel.mxint4[{m}x{k}x{n}]b{bm}_{bn}_{bk}", 0.0,
                 f"AI={a['intensity']:.2f}flops/B "
                 f"vmem={a['vmem_bytes']/1024:.0f}KiB "
                 f"hbm={a['hbm_bytes']/1e6:.1f}MB")
    # memory-bound check: decode AI << v5e ridge (197e12/819e9 ~ 240)
    a = analyze(8, 4096, 4096, 8, 256, 512)
    emit("kernel.decode_is_memory_bound", 0.0,
         f"AI={a['intensity']:.1f} << ridge 240 -> HBM-bound, "
         "EMA cut = speedup (C2)")


if __name__ == "__main__":
    run()
