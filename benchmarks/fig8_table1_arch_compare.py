"""Fig. 8 + Table I: conv-SA vs vector-unit vs HSA on the paper's accelerator
(256 PEs @ 500 MHz, DDR5 51.2 GB/s), end-to-end RetNet-1.3B.

Table I values (paper): tokens/s LISO 90.2/138.3/138.3, SILO 11.8/37.6/37.6;
tokens/J LISO 1060.7/719.1/1060.7, SILO 21.83/21.6/21.83.  Table I isolates
the *architecture* (all three at INT8 decode); HSA's MXINT4 shows up in
Table II.  Calibration: EXPERIMENTS.md §Paper-claims.
"""

from repro.core import edge_model as em
from repro.core.hsa import CONV_SA, HSA, VECTOR_UNIT

from benchmarks.bench_lib import emit

SPEC = em.retnet_model_spec(params=1.34e9, n_layers=24, d_model=2048,
                            n_heads=8, name="retnet-1.3b")
PAPER = {
    ("conv_sa", "LISO"): (90.2, 1060.7), ("conv_sa", "SILO"): (11.8, 21.83),
    ("vector_unit", "LISO"): (138.3, 719.1), ("vector_unit", "SILO"): (37.6, 21.6),
    ("hsa", "LISO"): (138.3, 1060.7), ("hsa", "SILO"): (37.6, 21.83),
}


def run() -> None:
    for arch in (CONV_SA, VECTOR_UNIT, HSA):
        for scen in (em.LISO, em.SILO):
            r = em.run_scenario(SPEC, em.PAPER_ACCEL, arch, scen,
                                decode_bits=8.0)   # Table I: int8 for all
            ts, tj = PAPER[(arch.name, scen.name)]
            emit(f"table1.{arch.name}.{scen.name}.tokens_per_s", 0.0,
                 f"{r.tokens_per_s:.1f} (paper {ts})")
            emit(f"table1.{arch.name}.{scen.name}.tokens_per_J", 0.0,
                 f"{r.tokens_per_j:.1f} (paper {tj})")
        # Fig. 8 energy story: prefill energy LISO
        r = em.run_scenario(SPEC, em.PAPER_ACCEL, arch, em.LISO,
                            decode_bits=8.0)
        emit(f"fig8.{arch.name}.prefill_energy_J", 0.0,
             f"{r.prefill.energy_j:.3f}")
        emit(f"fig8.{arch.name}.decode_latency_s_SILO", 0.0,
             f"{em.run_scenario(SPEC, em.PAPER_ACCEL, arch, em.SILO, decode_bits=8.0).decode.latency_s:.2f}")


if __name__ == "__main__":
    run()
