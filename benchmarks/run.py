"""Benchmark entrypoint: one function per paper table/figure, CSV output
``name,us_per_call,derived`` (+ the roofline table if dry-run artifacts
exist).

    PYTHONPATH=src python -m benchmarks.run            # all paper tables
    PYTHONPATH=src python -m benchmarks.run roofline   # roofline only
"""

import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from benchmarks import (fig1_breakdown, fig3_footprint,
                            fig8_table1_arch_compare, kernel_bench, roofline,
                            table2_sota, table3_quant_quality, table5_dequant)

    suites = {
        "fig1": fig1_breakdown.run,
        "fig3": fig3_footprint.run,
        "fig8_table1": fig8_table1_arch_compare.run,
        "table2": table2_sota.run,
        "table3": table3_quant_quality.run,
        "table5": table5_dequant.run,
        "kernels": kernel_bench.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and only not in (name, "all"):
            continue
        fn()

    if only in (None, "all", "roofline"):
        art = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "dryrun")
        if glob.glob(os.path.join(art, "*__single.json")):
            print("\n# roofline (single-pod 16x16, baseline policy)")
            roofline.run("single")
        else:
            print("\n# roofline: no dry-run artifacts yet "
                  "(python -m repro.launch.dryrun --all)")


if __name__ == "__main__":
    main()
