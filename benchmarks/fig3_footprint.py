"""Fig. 3: Llama-2 7B vs RetNet 6.7B normalized latency/energy vs output
length — O(n) KV cache vs O(1) retention state."""

from repro.core import edge_model as em
from repro.core.hsa import HSA

from benchmarks.bench_lib import emit

LLAMA = em.attention_model_spec(params=6.7e9, n_layers=32, d_model=4096,
                                n_kv_heads=32, head_dim=128, avg_context=1024,
                                name="llama2-7b")
RETNET = em.retnet_model_spec(params=6.7e9, n_layers=32, d_model=4096,
                              n_heads=16, name="retnet-6.7b")


def run() -> None:
    for n_out in (128, 512, 2048):
        scen = em.Scenario(f"gen{n_out}", 64, n_out)
        import dataclasses
        llama_ctx = dataclasses.replace(
            LLAMA, state_bytes_per_token=LLAMA.kv_growth_bytes_per_token
            * (64 + n_out / 2))
        rl = em.run_scenario(llama_ctx, em.JETSON_ORIN_NANO, HSA, scen,
                             prefill_bits=16.0, decode_bits=16.0)
        rr = em.run_scenario(RETNET, em.JETSON_ORIN_NANO, HSA, scen,
                             prefill_bits=16.0, decode_bits=16.0)
        emit(f"fig3.latency_ratio_llama_over_retnet.n{n_out}", 0.0,
             f"{rl.latency_s / rr.latency_s:.3f}")
        emit(f"fig3.energy_ratio_llama_over_retnet.n{n_out}", 0.0,
             f"{rl.energy_j / rr.energy_j:.3f}")
    emit("fig3.retnet_state_bytes", 0.0, f"{RETNET.state_bytes_per_token:.3e}")
    emit("fig3.llama_kv_read_at_1k_ctx", 0.0,
         f"{LLAMA.state_bytes_per_token:.3e}")


if __name__ == "__main__":
    run()
