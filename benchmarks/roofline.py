"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run.

    compute term    = MODEL/HLO FLOPs / (chip peak 197 TF/s)
    memory term     = HBM bytes / (819 GB/s)
    collective term = per-chip wire bytes / (50 GB/s ICI link)

FLOPs/bytes: analytic (runtime/analysis.py — exact trip counts; XLA's
cost_analysis counts scan bodies once, verified) with the raw HLO numbers as
a cross-check column.  Collectives: parsed from the partitioned HLO with
while-loop trip-count correction (launch/dryrun.py).

Also reports MODEL_FLOPS / HLO_FLOPS_corrected-ish via the hlo column, the
dominant term, and a roofline fraction:

    projected_step  = max(compute, memory, collective)   (perfect overlap)
    bound_step      = max(compute term, ideal-memory term)
    fraction        = bound_step / projected_step

`decode_step_model` is the single-token (MVM-phase) specialization: per
decode step the whole active weight set streams at MXINT4 bits and the
resident cache is read once, so the step is memory-bound and the bytes side
— weights at 4.25 bits + cache rows priced by `core.kvq.nbytes_per_row` for
the selected residency format — IS the model.  `decode_table` prints the
fp32 / int8_tok / mxint4_blk bytes-per-token ladder per arch
(``python -m benchmarks.roofline decode [arch ...]``), and
`bench_serving.py` divides each measured decode leg by the modeled step time
to report an achieved-fraction-of-roofline trajectory.
"""

import glob
import json
import os

from repro import configs
from repro.models.config import InputShape
from repro.runtime import analysis as an

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: str = "single", policy: str = "baseline"):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("policy", "baseline") != policy:
            continue
        cells.append(rec)
    return cells


def roofline_row(rec: dict) -> dict | None:
    if rec["status"] != "ok":
        return None
    cfg = configs.get_config(rec["arch"])
    shape = configs.SHAPES[rec["shape"]]
    n = rec["n_devices"]
    wl = an.cell_workload(cfg, shape, n)

    compute = wl.compute_term()
    memory = wl.memory_term()
    wire = sum(v["wire_bytes"] for v in rec.get("collectives", {}).values())
    coll = wire / an.ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    # Cross-pod (DCN) term, multi-pod train cells: the pod axis carries the
    # DP gradient reduction — 2*(g-1)/g * sharded grad bytes per chip.
    # int8 error-feedback compression (optim/compression.py) divides by 4.
    dcn = 0.0
    if rec["mesh"] != "single" and shape.kind == "train":
        pc = an.param_counts(cfg)
        grad_bytes_per_chip = pc.total * 4.0 / n
        dcn = 2 * grad_bytes_per_chip * 0.5 / an.DCN_BW
        terms["dcn"] = dcn
    dominant = max(terms, key=terms.get)
    projected = max(terms.values())

    # ideal memory bound: weights(+cache) only once, no resharding waste
    if shape.kind == "decode":
        bound = max(compute, memory)       # analytic memory is already ideal
    else:
        bound = compute
    fraction = min(1.0, bound / projected) if projected else 0.0

    hlo_flops = rec["cost"].get("flops", 0.0)
    hlo_bytes = rec["cost"].get("bytes accessed", 0.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant, "fraction": fraction,
        "model_flops": wl.model_flops,
        "hlo_flops_raw": hlo_flops,
        "hlo_bytes_raw": hlo_bytes,
        "mem_gb_per_dev": rec["memory"].get("total_size_in_bytes", 0) / 1e9,
        "collectives": {k: round(v["wire_bytes"] / 1e9, 3)
                        for k, v in rec.get("collectives", {}).items()},
    }


def run(mesh: str = "single", policy: str = "baseline") -> list[dict]:
    rows = []
    print("arch,shape,compute_ms,memory_ms,collective_ms,dominant,"
          "fraction,mem_GB/dev")
    for rec in load_cells(mesh, policy):
        if rec["status"] == "skipped":
            print(f"{rec['arch']},{rec['shape']},skipped:"
                  f" {rec['reason'][:60]}")
            continue
        row = roofline_row(rec)
        if row is None:
            print(f"{rec['arch']},{rec['shape']},ERROR")
            continue
        rows.append(row)
        print(f"{row['arch']},{row['shape']},"
              f"{row['compute_s']*1e3:.2f},{row['memory_s']*1e3:.2f},"
              f"{row['collective_s']*1e3:.2f},{row['dominant']},"
              f"{row['fraction']:.3f},{row['mem_gb_per_dev']:.2f}")
    return rows


DECODE_FORMATS = ("float32", "int8_tok", "mxint4_blk")


def decode_step_model(cfg, *, cache_len: int, batch: int = 1,
                      n_chips: int = 1,
                      cache_format: str | None = None) -> dict:
    """Analytic one-decode-step roofline for a concrete config instance.

    ``cfg`` is a `ModelConfig` (pass `engine.cfg` to model the exact engine
    being benched, reduced or full).  ``cache_format`` prices the resident
    cache rows: a `core.kvq` format name ('int8_tok' | 'mxint4_blk'), any
    dtype name ('float32' matches the engine's fp cache), or None for the
    paper's bf16 default.  Weights always stream at MXINT4 (4.25 bits) —
    the C2 deploy the decode path uses regardless of cache format.
    """
    shape = InputShape("decode_model", cache_len, batch, "decode")
    wl = an.cell_workload(cfg, shape, n_chips, cache_format=cache_format)
    cache_b = an._cache_bytes(cfg, cache_len, batch,
                              cache_format=cache_format) / n_chips
    step_s = max(wl.compute_term(), wl.memory_term())
    return {
        "cache_format": cache_format or "bf16",
        "cache_len": cache_len, "batch": batch, "n_chips": n_chips,
        "flops": wl.model_flops,
        "weight_bytes": wl.hbm_bytes - cache_b,
        "cache_bytes": cache_b,
        "bytes_per_token": wl.hbm_bytes / max(wl.tokens, 1e-12),
        "compute_s": wl.compute_term(),
        "memory_s": wl.memory_term(),
        "step_s": step_s,
        "bound": "memory" if wl.memory_term() >= wl.compute_term()
                 else "compute",
    }


def decode_table(archs=None, *, cache_len: int = 4096,
                 batch: int = 1) -> list[dict]:
    """Decode-step bytes ladder: fp32 cache vs the two kvq formats, with the
    bytes-per-token reduction ratio the EMA argument claims."""
    rows = []
    print("arch,cache_format,weight_MB,cache_MB,bytes/token_MB,step_ms,"
          "bound,cache_reduction_x")
    for arch in archs or configs.REGISTRY:
        cfg = configs.get_config(arch)
        base = None
        for fmt in DECODE_FORMATS:
            row = decode_step_model(cfg, cache_len=cache_len, batch=batch,
                                    cache_format=fmt)
            row["arch"] = arch
            if fmt == "float32":
                base = row
            red = (base["cache_bytes"] / row["cache_bytes"]
                   if row["cache_bytes"] else 1.0)
            row["cache_reduction_x"] = round(red, 2)
            rows.append(row)
            print(f"{arch},{fmt},{row['weight_bytes']/1e6:.1f},"
                  f"{row['cache_bytes']/1e6:.2f},"
                  f"{row['bytes_per_token']/1e6:.1f},"
                  f"{row['step_s']*1e3:.3f},{row['bound']},{red:.2f}")
    return rows


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "decode":
        decode_table(sys.argv[2:] or None)
    else:
        run(mesh=sys.argv[1] if len(sys.argv) > 1 else "single")
