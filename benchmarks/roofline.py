"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run.

    compute term    = MODEL/HLO FLOPs / (chip peak 197 TF/s)
    memory term     = HBM bytes / (819 GB/s)
    collective term = per-chip wire bytes / (50 GB/s ICI link)

FLOPs/bytes: analytic (runtime/analysis.py — exact trip counts; XLA's
cost_analysis counts scan bodies once, verified) with the raw HLO numbers as
a cross-check column.  Collectives: parsed from the partitioned HLO with
while-loop trip-count correction (launch/dryrun.py).

Also reports MODEL_FLOPS / HLO_FLOPS_corrected-ish via the hlo column, the
dominant term, and a roofline fraction:

    projected_step  = max(compute, memory, collective)   (perfect overlap)
    bound_step      = max(compute term, ideal-memory term)
    fraction        = bound_step / projected_step
"""

import glob
import json
import os

from repro import configs
from repro.runtime import analysis as an

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: str = "single", policy: str = "baseline"):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("policy", "baseline") != policy:
            continue
        cells.append(rec)
    return cells


def roofline_row(rec: dict) -> dict | None:
    if rec["status"] != "ok":
        return None
    cfg = configs.get_config(rec["arch"])
    shape = configs.SHAPES[rec["shape"]]
    n = rec["n_devices"]
    wl = an.cell_workload(cfg, shape, n)

    compute = wl.compute_term()
    memory = wl.memory_term()
    wire = sum(v["wire_bytes"] for v in rec.get("collectives", {}).values())
    coll = wire / an.ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    # Cross-pod (DCN) term, multi-pod train cells: the pod axis carries the
    # DP gradient reduction — 2*(g-1)/g * sharded grad bytes per chip.
    # int8 error-feedback compression (optim/compression.py) divides by 4.
    dcn = 0.0
    if rec["mesh"] != "single" and shape.kind == "train":
        pc = an.param_counts(cfg)
        grad_bytes_per_chip = pc.total * 4.0 / n
        dcn = 2 * grad_bytes_per_chip * 0.5 / an.DCN_BW
        terms["dcn"] = dcn
    dominant = max(terms, key=terms.get)
    projected = max(terms.values())

    # ideal memory bound: weights(+cache) only once, no resharding waste
    if shape.kind == "decode":
        bound = max(compute, memory)       # analytic memory is already ideal
    else:
        bound = compute
    fraction = min(1.0, bound / projected) if projected else 0.0

    hlo_flops = rec["cost"].get("flops", 0.0)
    hlo_bytes = rec["cost"].get("bytes accessed", 0.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant, "fraction": fraction,
        "model_flops": wl.model_flops,
        "hlo_flops_raw": hlo_flops,
        "hlo_bytes_raw": hlo_bytes,
        "mem_gb_per_dev": rec["memory"].get("total_size_in_bytes", 0) / 1e9,
        "collectives": {k: round(v["wire_bytes"] / 1e9, 3)
                        for k, v in rec.get("collectives", {}).items()},
    }


def run(mesh: str = "single", policy: str = "baseline") -> list[dict]:
    rows = []
    print("arch,shape,compute_ms,memory_ms,collective_ms,dominant,"
          "fraction,mem_GB/dev")
    for rec in load_cells(mesh, policy):
        if rec["status"] == "skipped":
            print(f"{rec['arch']},{rec['shape']},skipped:"
                  f" {rec['reason'][:60]}")
            continue
        row = roofline_row(rec)
        if row is None:
            print(f"{rec['arch']},{rec['shape']},ERROR")
            continue
        rows.append(row)
        print(f"{row['arch']},{row['shape']},"
              f"{row['compute_s']*1e3:.2f},{row['memory_s']*1e3:.2f},"
              f"{row['collective_s']*1e3:.2f},{row['dominant']},"
              f"{row['fraction']:.3f},{row['mem_gb_per_dev']:.2f}")
    return rows


if __name__ == "__main__":
    import sys
    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "single")
