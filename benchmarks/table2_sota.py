"""Table II "This work" column: area efficiency + energy under DDR5 51.2 GB/s.

Paper values: LISO 247.38 / SILO 116.55 token/s/mm^2; prefill 0.773 /
decode 24.06 mJ/token; 0.256 TOPS peak; 0.636 mm^2.
"""

from repro.core import edge_model as em
from repro.core.hsa import HSA

from benchmarks.bench_lib import emit

SPEC = em.retnet_model_spec(params=1.34e9, n_layers=24, d_model=2048,
                            n_heads=8, name="retnet-1.3b")


def run() -> None:
    for scen, paper in ((em.LISO, 247.38), (em.SILO, 116.55)):
        r = em.run_scenario(SPEC, em.PAPER_ACCEL, HSA, scen)
        got = r.tokens_per_s_per_mm2(em.PAPER_ACCEL)
        emit(f"table2.this_work.{scen.name}.area_eff_tok_s_mm2", 0.0,
             f"{got:.1f} (paper {paper}; {100 * (got - paper) / paper:+.1f}%)")
    r = em.run_scenario(SPEC, em.PAPER_ACCEL, HSA, em.SILO)
    emit("table2.this_work.decode_mJ_per_token", 0.0,
         f"{r.decode_mj_per_token:.2f} (paper 24.06)")
    r = em.run_scenario(SPEC, em.PAPER_ACCEL, HSA, em.LISO)
    emit("table2.this_work.prefill_mJ_per_token", 0.0,
         f"{r.prefill_mj_per_token:.3f} (paper 0.773)")
    emit("table2.this_work.peak_TOPS", 0.0,
         f"{em.PAPER_ACCEL.peak_mac_per_s / 1e12:.3f} MAC-TOPS (paper 0.256)")
    # improvement factors vs the strongest published area-eff baselines
    best_liso, best_silo = 100.82, 8.63     # MECLA (28nm, Table II)
    liso = em.run_scenario(SPEC, em.PAPER_ACCEL, HSA, em.LISO)
    silo = em.run_scenario(SPEC, em.PAPER_ACCEL, HSA, em.SILO)
    emit("table2.improvement.LISO_vs_MECLA", 0.0,
         f"{liso.tokens_per_s_per_mm2(em.PAPER_ACCEL) / best_liso:.2f}x "
         "(paper >=2.45x)")
    emit("table2.improvement.SILO_vs_MECLA", 0.0,
         f"{silo.tokens_per_s_per_mm2(em.PAPER_ACCEL) / best_silo:.2f}x "
         "(paper >=13.5x)")


if __name__ == "__main__":
    run()
