"""Property tests for `repro.serving.loadgen` + the SLO admission policy.

Skips cleanly without hypothesis (CI installs it via the test extra).
Everything here is host-side arithmetic — no engine, no jax dispatch — so
the suite stays fast under hypothesis' example sweep.
"""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs import Observability  # noqa: E402
from repro.serving.frontend import (FrontendConfig,  # noqa: E402
                                    SLOAdmissionPolicy)
from repro.serving.loadgen import (BurstyArrivals, LengthMix,  # noqa: E402
                                   PoissonArrivals, Workload)

SETTINGS = dict(max_examples=30, deadline=None)


def _arrivals(rate: float, bursty: bool):
    return BurstyArrivals(rate) if bursty else PoissonArrivals(rate)


@given(seed=st.integers(0, 2**32 - 1), rate=st.floats(0.5, 50.0),
       bursty=st.booleans())
@settings(**SETTINGS)
def test_seeded_arrival_streams_reproducible(seed, rate, bursty):
    arr = _arrivals(rate, bursty)
    a = arr.times(64, np.random.default_rng(seed))
    b = arr.times(64, np.random.default_rng(seed))
    assert a == b
    assert all(t2 > t1 for t1, t2 in zip(a, a[1:])), "times must increase"
    assert all(t > 0 for t in a)


@given(rate=st.floats(0.5, 40.0), bursty=st.booleans())
@settings(max_examples=20, deadline=None)
def test_interarrival_mean_converges_to_rate(rate, bursty):
    # Both processes scale their draws by 1/rate, so with the rng seed held
    # fixed the normalized deviation is rate-independent — this is a
    # deterministic check, not a flaky statistical one.  n=4000 puts the
    # standard error of the mean around 1.6% (Poisson) / ~5% (bursty, the
    # geometric dwell correlates neighbors); 20% is many sigmas of margin.
    arr = _arrivals(rate, bursty)
    dts = arr.interarrivals(4000, np.random.default_rng(0))
    mean = sum(dts) / len(dts)
    assert abs(mean - 1.0 / rate) <= 0.20 / rate


@given(seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_bursty_matches_poisson_offered_load(seed):
    # The MMPP parametrization holds the stationary mean rate at rate_rps:
    # over many arrivals the bursty stream's span tracks the Poisson one.
    rng_a, rng_b = np.random.default_rng(seed), np.random.default_rng(seed)
    span_p = PoissonArrivals(8.0).times(4000, rng_a)[-1]
    span_b = BurstyArrivals(8.0).times(4000, rng_b)[-1]
    assert span_b == pytest.approx(span_p, rel=0.25)


@given(pmin=st.integers(1, 20), pspan=st.integers(0, 200),
       nmin=st.integers(1, 8), nspan=st.integers(0, 30),
       sigma=st.floats(0.0, 2.0), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_length_mix_stays_within_support(pmin, pspan, nmin, nspan, sigma,
                                         seed):
    mix = LengthMix(prompt_min=pmin, prompt_max=pmin + pspan,
                    new_min=nmin, new_max=nmin + nspan, sigma=sigma)
    rng = np.random.default_rng(seed)
    for plen, budget in mix.sample(200, rng):
        assert pmin <= plen <= pmin + pspan
        assert nmin <= budget <= nmin + nspan


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_workload_reproducible_and_in_vocab(seed):
    wl = Workload(arrivals=PoissonArrivals(5.0), lengths=LengthMix(2, 9, 1, 3),
                  n_requests=12, vocab_size=101, seed=seed)
    a, b = wl.requests(), wl.requests()
    assert a == b
    assert [r.uid for r in a] == list(range(12))
    for r in a:
        assert all(1 <= t < 101 for t in r.prompt)
        assert 2 <= len(r.prompt) <= 9 and 1 <= r.max_new_tokens <= 3


@given(samples=st.lists(st.floats(0.001, 100.0), min_size=0, max_size=64),
       floor=st.integers(1, 8), inflight=st.integers(0, 7),
       slo=st.floats(1e-5, 9e-4), quantile=st.floats(0.0, 100.0))
@settings(**SETTINGS)
def test_policy_never_sheds_below_guaranteed_admit_floor(samples, floor,
                                                         inflight, slo,
                                                         quantile):
    # Every recorded TTFT breaches the (tiny) SLO, the evidence threshold is
    # zero — the only gate left is the floor, and below it the policy must
    # admit no matter what.
    if inflight >= floor:
        inflight = floor - 1
    obs = Observability()
    hist = obs.metrics.histogram("sched.ttft_s")
    for i, v in enumerate(samples):
        hist.record(v, t=float(i))
    now = float(len(samples))
    policy = SLOAdmissionPolicy(
        FrontendConfig(ttft_slo_s=slo, slo_quantile=quantile,
                       slo_window_s=1e9, min_slo_samples=0,
                       guaranteed_admit=floor),
        obs.metrics, now=lambda: now)
    assert policy.decide(inflight).action == "admit"
    # ... and at/above the floor, with evidence present, the same breach
    # does shed (the floor is the *only* thing that was holding it back).
    if samples:
        assert policy.decide(floor).action == "shed"
