"""The unified `repro.serving` API: fused decode loop, sampling, stop tokens,
and the continuous-batching scheduler's slot-based cache pool."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.serving import (EngineSpec, GenerationConfig, InferenceEngine,
                           Request, RequestScheduler, SamplingParams, sample)


@pytest.fixture(scope="module")
def engine():
    return InferenceEngine.from_config("retnet-1.3b",
                                       EngineSpec(reduced=True))


def _prompts(engine, batch, s_in, seed=1):
    return jax.random.randint(jax.random.key(seed), (batch, s_in), 1,
                              engine.cfg.vocab_size, dtype=jnp.int32)


def test_fused_loop_matches_python_loop(engine):
    """The single jitted while_loop must be token-identical to the seed's
    per-token Python dispatch under greedy decoding."""
    n_out = 8
    prompts = _prompts(engine, 2, 5)
    res = engine.generate(prompts, GenerationConfig(max_new_tokens=n_out))

    logits, cache = engine.prefill(prompts, cache_len=5 + n_out)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = []
    for _ in range(n_out):
        outs.append(tok)
        logits, cache = engine.decode_step(tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    ref = jnp.concatenate(outs, axis=1)

    np.testing.assert_array_equal(np.asarray(res.tokens), np.asarray(ref))
    assert res.lengths.tolist() == [n_out, n_out]


def test_sampling_deterministic_under_fixed_key(engine):
    gen = GenerationConfig(max_new_tokens=8,
                           sampling=SamplingParams(temperature=0.8,
                                                   top_k=50, top_p=0.95))
    prompts = _prompts(engine, 2, 4)
    a = engine.generate(prompts, gen, key=jax.random.key(7)).tokens
    b = engine.generate(prompts, gen, key=jax.random.key(7)).tokens
    c = engine.generate(prompts, gen, key=jax.random.key(8)).tokens
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a reduced random-init model has near-flat logits: 8 draws from a
    # different stream virtually never coincide across the whole batch
    assert not bool(jnp.all(a == c))


def test_stop_token_early_exit(engine):
    """Tokens after the stop token are pad; lengths include the stop token."""
    prompts = _prompts(engine, 1, 5)
    free = engine.generate(prompts, GenerationConfig(max_new_tokens=8))
    stop = int(free.tokens[0, 3])          # greedy emits this at step 3
    pad = -1
    gen = GenerationConfig(max_new_tokens=8, stop_tokens=(stop,),
                           pad_token_id=pad)
    res = engine.generate(prompts, gen)
    toks = res.tokens[0].tolist()
    k = free.tokens[0].tolist().index(stop)    # first occurrence overall
    assert toks[:k + 1] == free.tokens[0].tolist()[:k + 1]
    assert toks[k + 1:] == [pad] * (8 - k - 1)
    assert res.lengths.tolist() == [k + 1]


def test_top_k_restricts_support(engine):
    """top_k=1 must reduce stochastic sampling to greedy."""
    gen1 = GenerationConfig(max_new_tokens=6,
                            sampling=SamplingParams(temperature=1.3, top_k=1))
    gen0 = GenerationConfig(max_new_tokens=6)
    prompts = _prompts(engine, 2, 4)
    np.testing.assert_array_equal(
        np.asarray(engine.generate(prompts, gen1, key=jax.random.key(3)).tokens),
        np.asarray(engine.generate(prompts, gen0).tokens))


def test_sample_top_p_masks_tail():
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    params = SamplingParams(temperature=1.0, top_p=0.75)
    draws = {int(sample(logits, params, jax.random.key(i))[0])
             for i in range(64)}
    # 0.5 + 0.3 crosses p=0.75, so support is {0, 1}
    assert draws <= {0, 1} and len(draws) == 2


def test_scheduler_slot_reuse_across_staggered_requests(engine):
    """3 requests through 2 slots: the third is admitted only when a slot
    frees (continuous batching), runs in a *reused* slot, and every request's
    tokens equal a dedicated engine.generate run."""
    gen = GenerationConfig(max_new_tokens=5)
    sched = RequestScheduler(engine, n_slots=2, cache_len=16, gen=gen)
    prompts = {uid: list(range(2 + uid, 6 + uid)) for uid in range(3)}
    for uid, p in prompts.items():
        sched.submit(Request(uid=uid, prompt=p))

    # admissions are chunk-granular (one prefill chunk per cycle): after two
    # cycles both slots are claimed and request 2 is still queued
    sched.step()
    sched.step()
    assert sched.pool.free_slots == 0 and len(sched._queue) == 1
    res = sched.run()

    assert sorted(res) == [0, 1, 2]
    # Slot ids are request-lifetime handles: request 2 gets a fresh id, but
    # it necessarily ran in one of the two freed device lanes.
    assert res[2].slot not in (res[0].slot, res[1].slot)
    assert sched.pool.n_slots == 2 and sched.pool.free_slots == 2
    for uid, fin in res.items():
        want = engine.generate(
            jnp.asarray([prompts[uid]], jnp.int32), gen).tokens[0].tolist()
        assert fin.tokens == want, (uid, fin.tokens, want)


def test_scheduler_respects_per_request_budget(engine):
    gen = GenerationConfig(max_new_tokens=6)
    sched = RequestScheduler(engine, n_slots=2, cache_len=16, gen=gen)
    sched.submit(Request(uid=0, prompt=[3, 4, 5]))
    sched.submit(Request(uid=1, prompt=[5, 6, 7], max_new_tokens=2))
    res = sched.run()
    assert len(res[0].tokens) == 6
    assert len(res[1].tokens) == 2


def test_cache_pool_matches_make_decode_cache_structure(engine):
    from repro.serving import CachePool
    pool = CachePool(engine.cfg, n_slots=3, cache_len=16)
    one = lm.make_decode_cache(engine.cfg, 1, 16, jnp.float32)
    flat_pool = jax.tree_util.tree_leaves_with_path(pool.store)
    flat_one = jax.tree_util.tree_leaves_with_path(one)
    assert [p for p, _ in flat_pool] == [p for p, _ in flat_one]
    for (_, lp), (_, lo) in zip(flat_pool, flat_one):
        assert lp.shape == (3,) + lo.shape


@pytest.mark.parametrize("arch", ["retnet-1.3b", "hymba-1.5b"])
def test_pool_slots_accept_prefill_caches(arch):
    """Pool template shapes must equal prefill cache shapes for every cache
    kind — incl. sliding-window rings when cache_len < window (the layout
    prefill pads short prompts to).  eval_shape only; no compute."""
    from repro import configs
    from repro.core.hsa import HSAEngine
    cfg = configs.get_config(arch).reduced()
    cache_len = 12
    params_abs, _, _ = lm.init(cfg, jax.random.key(0), abstract=True)
    toks = jax.ShapeDtypeStruct((1, 5), jnp.int32)
    _, cache_abs = jax.eval_shape(
        lambda p, t: lm.forward_prefill(p, {"tokens": t}, cfg, HSAEngine(),
                                        cache_len=cache_len),
        params_abs, toks)
    pool_abs = lm.make_decode_cache(cfg, 1, cache_len, jnp.float32)
    flat_prefill = jax.tree_util.tree_leaves_with_path(cache_abs)
    flat_pool = jax.tree_util.tree_leaves_with_path(pool_abs)
    assert [p for p, _ in flat_prefill] == [p for p, _ in flat_pool]
    for (path, lc), (_, lp) in zip(flat_prefill, flat_pool):
        assert lc.shape == lp.shape, (path, lc.shape, lp.shape)


def test_serve_cell_typed_and_legacy_access():
    from repro.serving import ServeCell, serving_engine
    cell = ServeCell(engine=serving_engine("ref"), prefill=None, decode=None,
                     param_shapes={}, param_axes={}, param_shardings={},
                     cache_shapes={}, cache_shardings={}, policy=None)
    assert cell["engine"] is cell.engine           # legacy dict access
    with pytest.raises(KeyError):
        cell["nope"]
