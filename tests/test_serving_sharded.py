"""Sharded multi-chip serving: cross-arch identity vs. the single-device
engine.

`InferenceEngine.from_config(mesh=...)` must be a pure *distribution* change:
on a 2x2 (data, model) mesh of virtual host devices, every generate path —
plain fused loop, chunked prefill, speculative draft/verify, and scheduler
preempt/resume through the host spill tier — is greedy token-identical to
the single-device engine per cache architecture, while params stay under the
`ServeCell` shardings and cache leaves stay under `cache_shardings`
throughout decode (is_equivalent_to checks on every leaf, the
`jax.debug.visualize_array_sharding` assertion made mechanical).

All tests run in subprocesses (`conftest.run_in_devices`): the
``--xla_force_host_platform_device_count`` flag must precede jax init, and
the main pytest process keeps its single device.  One subprocess per arch
covers every path, so the two engines (and jax itself) are built once per
arch instead of once per (arch, path).  The identity loop itself lives in
`tests/conftest.py` — the same harness the in-process serving modules use —
imported by the subprocess via PYTHONPATH.
"""

_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
import conftest
from repro.launch.mesh import make_serving_mesh
from repro.runtime import sharding as shd
from repro.serving import GenerationConfig, Request, RequestScheduler, \
    SpeculativeConfig

mesh = make_serving_mesh("2,2")
assert mesh.size == 4


def engines(arch):
    return conftest.fp_engine(arch), conftest.fp_engine(arch, mesh=mesh)


def assert_on_mesh(engine, cache, what):
    bad = shd.sharding_mismatches(cache, engine.cache_shardings(cache))
    assert not bad, (what, bad)
"""


def test_sharded_identity_all_paths(cache_arch):
    """Per cache arch: plain generate, chunked prefill, bucketed prefill,
    speculative decode, and scheduler preempt/resume are all greedy
    token-identical between the sharded and the single-device engine, with
    params/cache pinned on-mesh throughout."""
    from conftest import run_in_devices
    out = run_in_devices(_PRELUDE + f"""
arch = {cache_arch!r}
single, shardy = engines(arch)
gen = GenerationConfig(max_new_tokens=6)
prompts = conftest.prompt_ids(single, 11)

# -- plain fused-loop generate + the on-mesh invariant ----------------------
conftest.assert_tokens_identical(shardy.generate(prompts, gen),
                                 single.generate(prompts, gen), arch)
bad = shd.sharding_mismatches(shardy.params, shardy.param_shardings)
assert not bad, bad                       # params under ServeCell shardings
assert shardy.cell is not None and shardy.cell.mesh is mesh
logits, cache = shardy.prefill(prompts, cache_len=11 + 6)
assert_on_mesh(shardy, cache, "prefill")
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for i in range(3):                        # cache stays on-mesh while decoding
    logits, cache = shardy.decode_step(tok, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert_on_mesh(shardy, cache, f"decode step {{i}}")
print("PLAIN_OK", arch)

# -- chunked prefill (same ladder both sides; MoE chunk boundaries match) ---
p2 = conftest.prompt_ids(single, 11, seed=2)
lg_s, cache_s = single.prefill_chunked(p2, cache_len=17, chunk_size=4)
lg_m, cache_m = shardy.prefill_chunked(p2, cache_len=17, chunk_size=4)
assert_on_mesh(shardy, cache_m, "chunked prefill")
conftest.assert_tokens_identical(
    conftest.greedy_continue(shardy, lg_m, cache_m, 6),
    conftest.greedy_continue(single, lg_s, cache_s, 6), arch)
print("CHUNKED_OK", arch)

# -- bucketed prefill (pad-and-mask ladder, traced prompt_len) --------------
lg_s, cache_s = single.prefill(p2, cache_len=17, bucket=True)
lg_m, cache_m = shardy.prefill(p2, cache_len=17, bucket=True)
assert_on_mesh(shardy, cache_m, "bucketed prefill")
conftest.assert_tokens_identical(
    conftest.greedy_continue(shardy, lg_m, cache_m, 6),
    conftest.greedy_continue(single, lg_s, cache_s, 6), arch)
print("BUCKET_OK", arch)

# -- speculative draft/verify over the sharded cache ------------------------
sgen = GenerationConfig(max_new_tokens=10)
spec = SpeculativeConfig(k=2)
for seed, sp in [(0, jnp.asarray([[5, 9, 13] * 4], jnp.int32)),
                 (1, conftest.prompt_ids(single, 7))]:
    a = single.generate(sp, sgen, speculative=spec)
    b = shardy.generate(sp, sgen, speculative=spec)
    conftest.assert_tokens_identical(b, a, f"{{arch}} seed {{seed}}")
    assert b.verify_steps >= 1
print("SPEC_OK", arch)

# -- scheduler preempt/resume through the host spill tier -------------------
p0 = conftest.prompt_list(single, 8, seed=11)
p1 = conftest.prompt_list(single, 8, seed=12)


def drain(engine, preempt):
    sched = RequestScheduler(engine, classes=[(1, 8 + 6)], gen=gen,
                             chunk_size=8, host_spill=preempt)
    sched.submit(Request(uid=0, prompt=p0))
    if preempt:
        while not sched._active:
            sched.step()
        sched.step()
        sched.submit(Request(uid=1, prompt=p1), priority=5)
    else:
        sched.submit(Request(uid=1, prompt=p1))
    res = sched.run()
    return {{u: r.tokens for u, r in res.items()}}, sched


base, _ = drain(single, False)
pre, sched = drain(shardy, True)
assert sched.stats["preempted"] >= 1
assert sched.stats["resumed"] == sched.stats["preempted"]
assert sched.pool.host_resident == 0
assert pre == base, (arch, pre, base)
for clen in dict(sched.pool.classes).values():
    bad = shd.sharding_mismatches(sched.pool.get_store(clen),
                                  sched.pool._store_shardings[clen])
    assert not bad, (arch, bad)           # pool stores still on-mesh
print("PREEMPT_OK", arch)
""")
    for mark in ("PLAIN_OK", "CHUNKED_OK", "BUCKET_OK", "SPEC_OK",
                 "PREEMPT_OK"):
        assert mark in out, (cache_arch, mark, out[-2000:])


def test_sharded_resume_generate_warm_identity():
    """`resume_generate` re-enters the sharded fused loop from a pending
    token + warm on-mesh cache: same stream as the single-device generate,
    no new prefill shapes."""
    from conftest import run_in_devices
    out = run_in_devices(_PRELUDE + """
single, shardy = engines("retnet-1.3b")
gen = GenerationConfig(max_new_tokens=6)
prompts = conftest.prompt_ids(single, 9, seed=31)
want = single.generate(prompts, gen)
logits, cache = shardy.prefill(prompts, cache_len=9 + 6)
shapes_before = set(shardy.prefill_shape_keys)
tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
got = shardy.resume_generate(tok0, cache, gen)
conftest.assert_tokens_identical(got, want)
assert shardy.prefill_shape_keys == shapes_before
print("SHARDED_RESUME_OK")
""")
    assert "SHARDED_RESUME_OK" in out


def test_sharded_quantized_deployment_serves():
    """The paper deployment (W8A8 prefill / MXINT4 decode) also runs on the
    mesh: deployed-quantized param tree placed under the cell's (deployed)
    shardings, sharded generate == single-device quantized generate."""
    from conftest import run_in_devices
    out = run_in_devices(_PRELUDE + """
from repro.serving import EngineSpec, InferenceEngine
single = InferenceEngine.from_config("retnet-1.3b", EngineSpec(reduced=True))
shardy = InferenceEngine.from_config("retnet-1.3b", EngineSpec(reduced=True),
                                     mesh=mesh)
bad = shd.sharding_mismatches(shardy.params, shardy.param_shardings)
assert not bad, bad
gen = GenerationConfig(max_new_tokens=6)
prompts = conftest.prompt_ids(single, 11)
conftest.assert_tokens_identical(shardy.generate(prompts, gen),
                                 single.generate(prompts, gen))
print("SHARDED_QUANT_OK")
""")
    assert "SHARDED_QUANT_OK" in out
