"""Optimizer + gradient compression properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, compression

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_adamw_optimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=2, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = adamw.init(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(jnp.asarray(s), cfg)) for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[9]                  # warming up
    assert abs(lrs[9] - 1.0) < 0.05                  # peak
    assert lrs[99] < 0.15                            # decayed to min ratio
    assert min(lrs[10:]) >= 0.1 * 0.99


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                            total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(huge, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5         # reported pre-clip


def test_moment_dtype_bfloat16():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = adamw.init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    new_p, new_s, _ = adamw.update({"w": jnp.ones((4, 4))}, state, params, cfg)
    assert new_s["v"]["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1))
def test_compress_identity(seed):
    """dequant(q) + residual' == g + residual (exact bookkeeping)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(32,)).astype(np.float32) * 0.01)
    qv, scale, r2 = compression.compress(g, r)
    np.testing.assert_allclose(
        np.asarray(compression.decompress(qv, scale) + r2),
        np.asarray(g + r), rtol=1e-5, atol=1e-6)


def test_error_feedback_tracks_sum():
    """EF property: sum of dequantized updates tracks the true gradient sum
    with bounded (non-accumulating) error."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
             for _ in range(50)]
    res = {"g": jnp.zeros(64)}
    sent_sum = jnp.zeros(64)
    true_sum = jnp.zeros(64)
    for g in grads:
        deq, res, wire = compression.compressed_grads({"g": g}, res)
        sent_sum = sent_sum + deq["g"]
        true_sum = true_sum + g
        # instantaneous error bounded by one quantization step
        step = float(jnp.max(jnp.abs(res["g"])))
        assert step <= float(jnp.max(jnp.abs(g + res["g"]))) / 127 + 1e-5
    err = float(jnp.max(jnp.abs(sent_sum - true_sum)))
    naive_err = 50 * float(jnp.max(jnp.abs(grads[0]))) / 127
    assert err < naive_err  # EF: error does NOT grow linearly with steps


def test_wire_bytes_4x_smaller():
    g = {"a": jnp.zeros((1024,)), "b": jnp.zeros((256, 4))}
    _, _, wire = compression.compressed_grads(g, compression.init_residuals(g))
    f32_bytes = (1024 + 1024) * 4
    assert wire < f32_bytes / 3.5
