"""Property tests for the flash-decode split-KV combine: the online-softmax
chunk accumulation equals the full softmax for arbitrary partitions, score
magnitudes, cache lengths, and block sizes."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ops as kops
from repro.kernels.flash_decode import flash_decode_pallas
from test_flash_decode import _gqa_case


@settings(deadline=None, max_examples=50)
@given(st.data())
def test_online_softmax_combine_matches_full_softmax(data):
    """The split-KV combine — carrying (m, l, acc) across chunks exactly as
    the kernel does — equals the unsplit softmax-weighted sum for any chunk
    partition and any score magnitudes (incl. large offsets: the rescaling
    by exp(m_prev - m_new) is what makes the split numerically safe)."""
    n = data.draw(st.integers(1, 48))
    d = data.draw(st.integers(1, 6))
    offset = data.draw(st.floats(-300.0, 300.0))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    s = (rng.standard_normal(n) * 3 + offset).astype(np.float32)
    v = rng.standard_normal((n, d)).astype(np.float32)

    # arbitrary partition of [0, n) into chunks
    cuts = data.draw(st.lists(st.integers(1, max(1, n - 1)),
                              max_size=4)) if n > 1 else []
    bounds = [0] + sorted(set(cuts)) + [n]

    m, l, acc = -np.inf, 0.0, np.zeros(d, np.float64)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if lo == hi:
            continue
        sc = s[lo:hi].astype(np.float64)
        m_new = max(m, sc.max())
        corr = np.exp(m - m_new) if np.isfinite(m) else 0.0
        p = np.exp(sc - m_new)
        l = l * corr + p.sum()
        acc = acc * corr + p @ v[lo:hi]
        m = m_new
    got = acc / l

    p_full = np.exp(s.astype(np.float64) - s.max())
    want = (p_full / p_full.sum()) @ v
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


@settings(deadline=None, max_examples=10)
@given(c=st.integers(1, 40), frac=st.floats(0.05, 1.0),
       block_c=st.integers(1, 48), seed=st.integers(0, 99))
def test_kernel_split_invariance_property(c, frac, block_c, seed):
    """Same invariance, through the kernel itself: random cache length,
    valid prefix, and block size all reproduce the ref oracle."""
    kv_len = max(1, int(c * frac))
    q, k, v, _ = _gqa_case(seed=seed, b=1, kv=1, g=2, d=8, c=c)
    want = kops.flash_decode(q, k, v, jnp.int32(kv_len), impl="ref")
    got = flash_decode_pallas(q, k, v, jnp.int32(kv_len), block_c=block_c,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
