"""End-to-end serving: PTQ deploy -> LISO/SILO generation on reduced configs
(the paper's edge inference flow, contribution C1+C2+C3+C4 together)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.hsa import HSAConfig, HSAEngine
from repro.launch.serve import generate
from repro.models import deploy, lm


@pytest.fixture(scope="module")
def served_retnet():
    cfg = configs.get_config("retnet-1.3b").reduced()
    params, _, paths = lm.init(cfg, jax.random.key(0))
    served = deploy.deploy_quantize(params, paths)
    return cfg, params, served


def test_silo_generation_runs(served_retnet):
    cfg, params, served = served_retnet
    engine = HSAEngine(HSAConfig())
    prompts = jax.random.randint(jax.random.key(1), (2, 5), 1, cfg.vocab_size,
                                 dtype=jnp.int32)
    toks, t_p, t_d = generate(cfg, served, engine, prompts, n_out=8)
    assert toks.shape == (2, 8)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.padded_vocab


def test_quantized_matches_fp_generation_mostly(served_retnet):
    """W4A8 decode should track the fp model closely (the Table III/IV
    'minimal accuracy loss' claim, proxy form).  A random-init reduced model
    has near-flat logits, so we check logit correlation rather than greedy
    agreement (argmax of a flat distribution is quantization-noise lottery)."""
    cfg, params, served = served_retnet
    fp = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp"))
    q = HSAEngine(HSAConfig())
    prompts = jax.random.randint(jax.random.key(2), (4, 6), 1, cfg.vocab_size,
                                 dtype=jnp.int32)
    lg_fp, _ = lm.forward_prefill(params, {"tokens": prompts}, cfg, fp,
                                  cache_len=8)
    lg_q, _ = lm.forward_prefill(served, {"tokens": prompts}, cfg, q,
                                 cache_len=8)
    a = np.asarray(lg_fp, np.float64).ravel()
    b = np.asarray(lg_q, np.float64).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.95, corr


def test_unfused_norm_ablation_equivalent_fp(served_retnet):
    """C3 ablation: fused vs unfused RMSNorm give the same fp forward."""
    cfg, params, _ = served_retnet
    fused = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp",
                                fuse_rmsnorm=True))
    unfused = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp",
                                  fuse_rmsnorm=False))
    batch = {"tokens": jax.random.randint(jax.random.key(3), (2, 10), 1,
                                          cfg.vocab_size, dtype=jnp.int32)}
    lg_f, _ = lm.forward_prefill(params, batch, cfg, fused, cache_len=12)
    lg_u, _ = lm.forward_prefill(params, batch, cfg, unfused, cache_len=12)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_u),
                               rtol=1e-4, atol=1e-4)


def test_streamed_weight_bytes_ratio(served_retnet):
    """Decode streams ~4.25/8 of prefill's weight bytes (EMA halved, C2)."""
    cfg, params, served = served_retnet
    totals = {"mx": 0, "w8": 0}

    def walk(tree):
        for v in tree.values():
            if isinstance(v, dict):
                if "mx_packed" in v:
                    totals["mx"] += v["mx_packed"].size + v["mx_exps"].size
                    totals["w8"] += v["w8_vals"].size
                else:
                    walk(v)

    walk(served)
    mx_bytes, w8_bytes = totals["mx"], totals["w8"]
    assert mx_bytes > 0
    ratio = mx_bytes / w8_bytes
    assert abs(ratio - 4.25 / 8) < 0.01, ratio
