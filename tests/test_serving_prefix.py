"""Shared-prefix cache reuse (serving/paging.py): greedy warm-vs-cold
token-identity per cache architecture across admission modes (chunked
scheduler, engine-level adoption, speculative, preempt/resume), COW and MoE
chunk-alignment semantics, page budgets (LRU eviction + proactive host
migration), lease hygiene, and quantized-page residency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (CHUNKED_ARCHS, assert_tokens_identical, fp_engine,
                      greedy_continue, prompt_ids,
                      prompt_list as _prompt_list)

from repro.models import lm
from repro.serving import (GenerationConfig, PrefixCache, Request,
                           RequestScheduler, SpeculativeConfig)
from repro.serving.paging import PageLeaseError, RadixPageIndex, token_key


def _run_sched(arch, prompts, *, prefix_cache, gen=None, chunk_size=8,
               n_slots=2, cache_len=48, **kw):
    """Drain ``prompts`` through a fresh scheduler; {uid: tokens} + sched."""
    engine = fp_engine(arch)
    gen = gen or GenerationConfig(max_new_tokens=6)
    sched = RequestScheduler(engine, n_slots=n_slots, cache_len=cache_len,
                             gen=gen, chunk_size=chunk_size,
                             prefix_cache=prefix_cache, **kw)
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p))
    out = sched.run()
    return {u: f.tokens for u, f in out.items()}, sched


def _shared_prompts(arch, n_ext=3, shared=16, ext=8):
    """The repeated-system-prompt shape: the shared prefix alone (so the
    snapshot tier has an exact boundary to register), then extensions."""
    engine = fp_engine(arch)
    base = _prompt_list(engine, shared, seed=3)
    return [base] + [base + _prompt_list(engine, ext, seed=10 + i)
                     for i in range(n_ext)]


# -- warm vs cold greedy identity, per cache arch ----------------------------


def test_warm_vs_cold_identity_chunked(cache_arch):
    """THE contract: prefix adoption changes nothing the user can see.
    Every cache arch — paged (dense/MoE) or snapshot (ring/recurrent) —
    yields greedy tokens identical to a cold-start scheduler, while
    actually hitting the prefix index."""
    prompts = _shared_prompts(cache_arch)
    cold, _ = _run_sched(cache_arch, prompts, prefix_cache=False)
    warm, sched = _run_sched(cache_arch, prompts, prefix_cache=True)
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid],
                                f"{cache_arch} uid {uid} warm != cold")
    st = sched.pool.prefix.stats
    assert st["prefix_hits"] >= 2
    assert st["prefix_hit_tokens"] >= 2 * 8
    assert sched.pool.prefix.leased_slots == 0       # all leases dropped


def test_warm_vs_cold_identity_speculative(cache_arch):
    """Adoption composes with the scheduler's speculative decode path
    (ngram drafter, per-lane verify + exact rollback)."""
    gen = GenerationConfig(max_new_tokens=6,
                           speculative=SpeculativeConfig(k=2))
    prompts = _shared_prompts(cache_arch)
    cold, _ = _run_sched(cache_arch, prompts, prefix_cache=False, gen=gen)
    warm, sched = _run_sched(cache_arch, prompts, prefix_cache=True, gen=gen)
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid],
                                f"{cache_arch} uid {uid} spec warm != cold")
    assert sched.pool.prefix.stats["prefix_hits"] >= 2


def test_warm_vs_cold_identity_preempt_resume(cache_arch):
    """Adoption composes with host-spill preemption: a high-priority burst
    bumps residents to the host tier mid-decode; outputs still match the
    cold (also-preempting) run, and cancelled/retired leases never leak."""
    prompts = _shared_prompts(cache_arch, n_ext=3)

    def run(prefix_cache):
        engine = fp_engine(cache_arch)
        sched = RequestScheduler(engine, n_slots=2, cache_len=48,
                                 gen=GenerationConfig(max_new_tokens=6),
                                 chunk_size=8, host_spill=True,
                                 prefix_cache=prefix_cache)
        sched.submit(Request(uid=0, prompt=prompts[0]))
        sched.submit(Request(uid=1, prompt=prompts[1]))
        sched.submit(Request(uid=2, prompt=prompts[2]))
        for _ in range(6):
            sched.step()
        sched.submit(Request(uid=3, prompt=prompts[3]), priority=5)
        out = sched.run()
        return {u: f.tokens for u, f in out.items()}, sched

    cold, _ = run(False)
    warm, sched = run(True)
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid],
                                f"{cache_arch} uid {uid} preempt warm != cold")
    assert sched.stats["preempted"] >= 1
    assert sched.pool.prefix.leased_slots == 0


# -- engine-level adoption (no scheduler) ------------------------------------


@pytest.mark.parametrize("arch", CHUNKED_ARCHS)
def test_engine_adopted_prefill_matches_cold(arch):
    """`ChunkedPrefill(start_offset=p, initial_cache=...)` over a
    `PrefixCache`-assembled warm prefix continues exactly where a cold
    chunked prefill of the same prompt would be (greedy continuation
    identical) — the engine-level seam, isolated from scheduler policy."""
    engine = fp_engine(arch)
    clen = 48
    donor = _prompt_list(engine, 16, seed=3)
    query = donor + _prompt_list(engine, 9, seed=11)

    _, donor_cache = engine.prefill_chunked(
        jnp.asarray([donor], jnp.int32), cache_len=clen, chunk_size=8)
    pc = PrefixCache(engine.cfg, jnp.float32, enabled=True, page_size=4)
    pc.register(donor, donor_cache, clen)

    p, warm = pc.lookup(query, clen, slot=0, chunk_size=8)
    assert p == len(donor)
    cp = engine.begin_chunked_prefill(
        jnp.asarray([query], jnp.int32), cache_len=clen, chunk_size=8,
        initial_cache=warm, start_offset=p)
    assert sum(cp.schedule) == len(query) - p
    while not cp.done:
        cp.advance()

    logits_cold, cache_cold = engine.prefill_chunked(
        jnp.asarray([query], jnp.int32), cache_len=clen, chunk_size=8)
    assert_tokens_identical(
        greedy_continue(engine, cp.logits, cp.cache, 6),
        greedy_continue(engine, logits_cold, cache_cold, 6),
        f"{arch}: adopted prefill diverged from cold")


def test_chunked_prefill_offset_validation():
    engine = fp_engine("qwen3-8b")
    toks = prompt_ids(engine, 8)
    with pytest.raises(ValueError, match="start_offset"):
        engine.begin_chunked_prefill(toks, cache_len=16, start_offset=8)
    with pytest.raises(ValueError, match="initial_cache"):
        engine.begin_chunked_prefill(toks, cache_len=16, start_offset=4)


# -- COW / alignment semantics ----------------------------------------------


def test_unaligned_adoption_cow_never_mutates_shared_pages():
    """An adoption boundary inside a page slices (copies) the tail page —
    the COW event — and the donor's registered pages are bit-identical
    afterwards; the adopter's output still matches a cold run."""
    arch = "qwen3-8b"
    engine = fp_engine(arch)
    donor = _prompt_list(engine, 16, seed=3)
    # Diverge 2 tokens into the donor's last page (page_size=4 below).
    query = donor[:14] + _prompt_list(engine, 10, seed=12)
    cold, _ = _run_sched(arch, [donor, query], prefix_cache=False)
    warm, sched = _run_sched(arch, [donor, query], prefix_cache=True,
                             prefix_page_size=4)

    pc = sched.pool.prefix
    st = pc.stats
    assert st["cow_copies"] >= 1
    assert st["prefix_hit_tokens"] == 14
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"uid {uid}")
    # The shared pages survived the COW un-mutated: a fresh lookup of the
    # donor's own prompt still reconstructs the same rows the donor's
    # prefill produced.
    p, again = pc.lookup(donor, 48, slot=999, chunk_size=1)
    assert p == len(donor) - 1
    _, donor_cache = engine.prefill_chunked(
        jnp.asarray([donor], jnp.int32), cache_len=48, chunk_size=8)
    for g in lm.prefix_page_groups(engine.cfg):
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a[:, :, :p]), np.asarray(b[:, :, :p])),
            again[g], donor_cache[g])
    pc.release(999)
    assert pc.leased_slots == 0


def test_moe_adoption_is_chunk_aligned():
    """MoE expert-capacity routing is per-dispatch: adoption boundaries
    must land on chunk boundaries so the suffix's dispatches are the ones
    the cold run compiled.  A 13-token shared prefix under chunk_size=8
    floors to 8 adopted tokens."""
    arch = "olmoe-1b-7b"
    engine = fp_engine(arch)
    donor = _prompt_list(engine, 13, seed=3)
    query = donor + _prompt_list(engine, 8, seed=11)
    cold, _ = _run_sched(arch, [donor, query], prefix_cache=False)
    warm, sched = _run_sched(arch, [donor, query], prefix_cache=True,
                             prefix_page_size=4)
    st = sched.pool.prefix.stats
    assert st["prefix_hits"] == 1 and st["prefix_hit_tokens"] == 8
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"uid {uid}")


def test_full_prompt_repeat_capped_at_last_token():
    """An exact repeat adopts len-1 tokens (one suffix token must still run
    so admission produces last-token logits) and stays identical.  page_size
    8 so the 15-token hit clears the one-full-page adoption floor."""
    arch = "qwen3-8b"
    prompt = _prompt_list(fp_engine(arch), 16, seed=3)
    cold, _ = _run_sched(arch, [prompt, prompt], prefix_cache=False)
    warm, sched = _run_sched(arch, [prompt, prompt], prefix_cache=True,
                             prefix_page_size=8)
    assert sched.pool.prefix.stats["prefix_hit_tokens"] == len(prompt) - 1
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"uid {uid}")


def test_sub_page_hit_is_a_miss():
    """An overlap shorter than one full page is not adopted: a tiny
    adoption's assembly copy plus its odd-offset suffix ladder entry cost
    more than the prefill it skips, so a chance few-token overlap between
    unrelated prompts must stay a plain cold admission (no lease, no hit)."""
    engine = fp_engine("qwen3-8b")
    donor = _prompt_list(engine, 12, seed=3)
    _, cache = engine.prefill_chunked(jnp.asarray([donor], jnp.int32),
                                      cache_len=24, chunk_size=8)
    pc = PrefixCache(engine.cfg, jnp.float32, enabled=True, page_size=8)
    pc.register(donor, cache, 24)
    query = donor[:4] + _prompt_list(engine, 8, seed=9)
    p, warm = pc.lookup(query, 24, slot=0, chunk_size=1)
    assert (p, warm) == (0, None)
    assert pc.leased_slots == 0
    assert pc.stats["prefix_lookups"] == 1 and pc.stats["prefix_hits"] == 0


def test_divergent_prompts_build_siblings_and_stay_exact():
    """Prompts diverging mid-page register sibling pages (no edge split);
    each prompt's own lookup still reconstructs only its own rows."""
    arch = "qwen3-8b"
    engine = fp_engine(arch)
    base = _prompt_list(engine, 10, seed=3)
    a = base + _prompt_list(engine, 6, seed=21)
    b = base + _prompt_list(engine, 6, seed=22)
    cold, _ = _run_sched(arch, [a, b, a, b], prefix_cache=False)
    warm, sched = _run_sched(arch, [a, b, a, b], prefix_cache=True,
                             prefix_page_size=4)
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"uid {uid}")
    st = sched.pool.prefix.stats
    assert st["prefix_hits"] >= 2       # the repeats hit their own prefixes


# -- quantized page residency ------------------------------------------------


@pytest.mark.parametrize("fmt", ["int8_tok", "mxint4_blk"])
def test_quantized_pages_share_like_fp(fmt):
    """Quantized cache residency pages share identically: encoded planes
    slice on the cache axis like fp leaves, and warm greedy output matches
    the cold quantized run token-for-token."""
    arch = "qwen3-8b"
    gen = GenerationConfig(max_new_tokens=6, cache_format=fmt)
    prompts = _shared_prompts(arch, n_ext=2)
    cold, _ = _run_sched(arch, prompts, prefix_cache=False, gen=gen)
    warm, sched = _run_sched(arch, prompts, prefix_cache=True, gen=gen)
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"{fmt} uid {uid}")
    assert sched.pool.prefix.stats["prefix_hits"] >= 2


# -- budgets: LRU eviction + proactive host migration ------------------------


def test_page_budget_evicts_lru_unreferenced_only():
    """`max_prefix_pages` bounds the index; eviction is LRU over
    unreferenced leaves, and a fresh prompt still registers and hits."""
    arch = "qwen3-8b"
    engine = fp_engine(arch)
    prompts = [_prompt_list(engine, 16, seed=s) for s in range(3, 8)]
    _, sched = _run_sched(arch, prompts, prefix_cache=True,
                          max_prefix_pages=2)
    pc = sched.pool.prefix
    assert pc.n_pages <= 2
    assert pc.stats["page_evictions"] >= 1
    assert all(n.refs == 0 for n in pc._index.nodes())


def test_cold_pages_migrate_to_host_and_fetch_back():
    """`device_prefix_pages` proactively spills cold unreferenced pages to
    host DRAM (before capacity pressure); adoption fetches them back and
    stays token-identical."""
    arch = "qwen3-8b"
    prompts = _shared_prompts(arch, n_ext=2)
    cold, _ = _run_sched(arch, prompts, prefix_cache=False)
    warm, sched = _run_sched(arch, prompts, prefix_cache=True,
                             prefix_page_size=4, device_prefix_pages=0)
    pc = sched.pool.prefix
    st = pc.stats
    assert st["page_spills"] >= 1 and st["page_fetches"] >= 1
    assert st["prefix_hits"] >= 2
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"uid {uid}")
    sched.pool.prefix_maintain()
    assert pc.device_resident_pages == 0
    assert pc.host_pages == pc.n_pages


def test_snapshot_tier_budgets():
    """Budgets work on the snapshot tier too: eviction and host migration
    count snapshots, and adoption after migration stays exact."""
    arch = "retnet-1.3b"
    prompts = _shared_prompts(arch, n_ext=2)
    cold, _ = _run_sched(arch, prompts, prefix_cache=False)
    warm, sched = _run_sched(arch, prompts, prefix_cache=True,
                             device_prefix_pages=0)
    st = sched.pool.prefix.stats
    assert st["page_spills"] >= 1 and st["prefix_hits"] >= 2
    for uid in cold:
        assert_tokens_identical(warm[uid], cold[uid], f"uid {uid}")


# -- observability / hygiene -------------------------------------------------


def test_prefix_metrics_and_gauges_surface():
    arch = "qwen3-8b"
    prompts = _shared_prompts(arch, n_ext=2)
    _, sched = _run_sched(arch, prompts, prefix_cache=True)
    snap = sched.obs.metrics.snapshot()
    assert snap["counters"]["pool.prefix_lookups"] == len(prompts)
    assert snap["counters"]["pool.prefix_hits"] >= 2
    assert (snap["gauges"]["pool.pages_free"]["value"]
            == sched.pool.prefix.n_pages)
    assert snap["gauges"]["pool.prefix_bytes"]["value"] > 0


def test_prefix_cache_default_off():
    """Opt-in: a scheduler built without ``prefix_cache`` never touches the
    index (lookups included — the disabled facade is inert)."""
    arch = "qwen3-8b"
    prompts = _shared_prompts(arch, n_ext=1)
    _, sched = _run_sched(arch, prompts, prefix_cache=False)
    pc = sched.pool.prefix
    assert not pc.enabled and pc.n_pages == 0
    assert pc.stats["prefix_lookups"] == 0


def test_cancel_mid_admission_releases_leases():
    """Cancelling the in-flight admission drops its page leases (the pool
    release path), so the pages stay evictable."""
    arch = "qwen3-8b"
    engine = fp_engine(arch)
    donor = _prompt_list(engine, 16, seed=3)
    query = donor + _prompt_list(engine, 8, seed=11)
    sched = RequestScheduler(engine, n_slots=2, cache_len=48,
                             gen=GenerationConfig(max_new_tokens=4),
                             chunk_size=8, prefix_cache=True)
    sched.submit(Request(uid=0, prompt=donor))
    out = sched.run()
    assert 0 in out
    sched.submit(Request(uid=1, prompt=query))
    sched.step()                       # admission in flight, lease held
    assert sched.pool.prefix.leased_slots == 1
    sched.cancel(1)
    assert sched.pool.prefix.leased_slots == 0
    assert all(n.refs == 0 for n in sched.pool.prefix._index.nodes())


def test_lease_release_misuse_raises():
    ix = RadixPageIndex(page_size=2)
    created = ix.insert(token_key([1, 2, 3]), lambda a, b: {"x": None},
                        nbytes_of=lambda r: 0)
    ix.lease(created)
    ix.release(created)
    with pytest.raises(PageLeaseError):
        ix.release(created)
