"""Rules engine: divisibility fallback, composite axes, cache specs."""

import dataclasses

from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import ShardingPolicy, spec_for_tensor


@dataclasses.dataclass
class FakeMesh:
    shape: dict


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})
POLICY = ShardingPolicy()


def test_divisible_head_dim_shards():
    # deepseek: 128 heads over model=16
    spec = spec_for_tensor((7168, 128 * 128), ("embed", "heads"), SINGLE, POLICY)
    assert spec == P("data", "model")


def test_nondivisible_heads_fall_through():
    # hymba: 25 heads * 64 = 1600 -> 1600 % 16 == 0, shards; but 25 alone no:
    spec = spec_for_tensor((64, 25), (None, "heads"), SINGLE, POLICY)
    assert spec == P(None, None)


def test_fsdp_composite_on_multipod():
    spec = spec_for_tensor((1024, 4096), (None, "embed"), MULTI, POLICY)
    assert spec == P(None, ("pod", "data"))


def test_fsdp_single_pod_falls_to_data():
    spec = spec_for_tensor((1024, 4096), (None, "embed"), SINGLE, POLICY)
    assert spec == P(None, "data")


def test_axis_used_once_per_tensor():
    # both dims want 'model': second falls through
    spec = spec_for_tensor((256, 512), ("heads", "mlp"), SINGLE, POLICY)
    assert spec == P("model", None)


def test_batch_one_falls_through_then_cache_takes_data():
    # long_500k: batch=1 unshardable; cache length takes 'data'
    spec = spec_for_tensor((4, 1, 524288, 5, 64),
                           ("layers", "batch", "cache", "kv", None),
                           SINGLE, POLICY)
    assert spec == P(None, None, "data", None, None)


def test_decode32k_batch_takes_dp_cache_takes_model():
    spec = spec_for_tensor((36, 128, 32768, 8, 128),
                           ("layers", "batch", "cache", "kv", None),
                           MULTI, POLICY)
    assert spec == P(None, ("pod", "data"), "model", None, None)


def test_unknown_logical_replicates():
    spec = spec_for_tensor((8, 8), ("nonsense", None), SINGLE, POLICY)
    assert spec == P(None, None)


def test_with_rule_override():
    pol = POLICY.with_rule("embed", ())
    spec = spec_for_tensor((64, 4096), (None, "embed"), SINGLE, pol)
    assert spec == P(None, None)
