"""Analytic edge model (C6): reproduce the paper's own numbers and orderings.

Table II ("this work"): LISO 247.38 / SILO 116.55 token/s/mm^2, decode
24.06 mJ/token under DDR5 51.2 GB/s.  Table I: conv-SA vs vector-unit vs HSA.
The model is calibrated within +-15 % (EXPERIMENTS.md §Paper-claims); the
*orderings* — the paper's actual claims — must hold exactly.
"""

import pytest

from repro.core import edge_model as em
from repro.core.hsa import CONV_SA, HSA, VECTOR_UNIT

RETNET_13 = em.retnet_model_spec(params=1.34e9, n_layers=24, d_model=2048,
                                 n_heads=8, name="retnet-1.3b")


def _run(arch, scen, decode_bits=None):
    return em.run_scenario(RETNET_13, em.PAPER_ACCEL, arch, scen,
                           decode_bits=decode_bits)


def test_table2_liso_area_efficiency():
    r = _run(HSA, em.LISO)
    got = r.tokens_per_s_per_mm2(em.PAPER_ACCEL)
    assert abs(got - 247.38) / 247.38 < 0.15, got


def test_table2_silo_area_efficiency():
    r = _run(HSA, em.SILO)
    got = r.tokens_per_s_per_mm2(em.PAPER_ACCEL)
    assert abs(got - 116.55) / 116.55 < 0.15, got


def test_table2_decode_energy():
    r = _run(HSA, em.SILO)
    assert abs(r.decode_mj_per_token - 24.06) / 24.06 < 0.15


def test_table1_ordering_tokens_per_s():
    """conv SA slowest (low MVM utilization); vector == HSA at int8."""
    for scen in (em.LISO, em.SILO):
        sa = _run(CONV_SA, scen, decode_bits=8.0).tokens_per_s
        vec = _run(VECTOR_UNIT, scen, decode_bits=8.0).tokens_per_s
        hsa = _run(HSA, scen, decode_bits=8.0).tokens_per_s
        assert sa < vec
        assert abs(vec - hsa) / hsa < 1e-6


def test_table1_ordering_tokens_per_j():
    """vector unit pays SRAM refetch energy in prefill: worst tokens/J LISO."""
    sa = _run(CONV_SA, em.LISO, decode_bits=8.0)
    vec = _run(VECTOR_UNIT, em.LISO, decode_bits=8.0)
    hsa = _run(HSA, em.LISO, decode_bits=8.0)
    assert vec.tokens_per_j < hsa.tokens_per_j
    assert abs(sa.tokens_per_j - hsa.tokens_per_j) / hsa.tokens_per_j < 1e-6


def test_decode_is_memory_bound_prefill_compute_bound():
    """Fig. 1's observation — the premise of the whole paper."""
    r = _run(HSA, em.LISO)
    assert r.prefill.bound == "compute"
    assert r.decode.bound == "memory"


def test_decode_dominates_latency_even_in_liso():
    """Fig. 1(b): on the Jetson reference (fp16 weights), decode dominates
    LISO runtime despite the 15x longer input."""
    r = em.run_scenario(RETNET_13, em.JETSON_ORIN_NANO, HSA, em.LISO,
                        prefill_bits=16.0, decode_bits=16.0)
    assert r.decode.latency_s > 0.6 * r.latency_s


def test_mxint4_halves_decode_memory_time():
    r8 = _run(HSA, em.SILO, decode_bits=8.0)
    r4 = _run(HSA, em.SILO)   # 4.25 bits
    ratio = r4.decode.memory_time_s / r8.decode.memory_time_s
    assert 0.5 < ratio < 0.62          # 4.25/8 = 0.53 plus state traffic


def test_retnet_state_constant_vs_llama_kv_growth():
    """Fig. 3: RetNet decode traffic is O(1); attention grows with context."""
    llama = em.attention_model_spec(params=6.7e9, n_layers=32, d_model=4096,
                                    n_kv_heads=32, head_dim=128,
                                    avg_context=2000, name="llama7b")
    ret = em.retnet_model_spec(params=6.7e9, n_layers=32, d_model=4096,
                               n_heads=16, name="retnet-6.7b")
    assert ret.state_bytes_per_token < 0.3 * llama.state_bytes_per_token
    # and the retnet state does not grow with context
    assert ret.kv_growth_bytes_per_token == 0.0
    assert llama.kv_growth_bytes_per_token > 0


def test_jetson_decode_utilization_matches_fig1():
    """Fig. 1: Jetson decode runs at ~1.7 % of peak — order-of-magnitude
    check that decode utilization collapses under the bandwidth bound."""
    r = em.decode(RETNET_13, em.JETSON_ORIN_NANO, HSA, 100, weight_bits=16.0)
    ach = RETNET_13.macs_per_token * 100 / r.latency_s
    util = ach / em.JETSON_ORIN_NANO.peak_mac_per_s
    assert 0.0005 < util < 0.05
