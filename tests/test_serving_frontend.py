"""Deterministic virtual-clock tests for the asyncio serving front end.

Every async path here — arrival pacing, SLO shedding, mid-stream
cancellation, the stepper's idle parking — runs on
`repro.serving.clock.VirtualClock`: ``asyncio.sleep`` and timeouts resolve
by *jumping* virtual time, so the module is wall-clock-free (no real-clock
sleeps anywhere) and two consecutive runs are event-for-event identical,
timestamps included.  The scheduler's latency histograms record on the same
virtual timebase (``clock=clock.now``), which is what makes the windowed
SLO policy assertable to the sample.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import jax
import pytest

from conftest import fp_engine, prompt_list
from repro.obs import Observability
from repro.serving import (FinishedRequest, FrontendConfig, GenerationConfig,
                           LengthMix, MonotonicClock, PoissonArrivals,
                           Request, RequestScheduler, RequestShed,
                           ServingFrontend, VirtualClock, Workload,
                           BurstyArrivals, run_open_loop)

pytestmark = pytest.mark.virtual_clock


def make_stack(arch: str = "retnet-1.3b", *, classes=((2, 48),),
               chunk_size: int = 8, max_new: int = 4,
               config: FrontendConfig | None = None, **sched_kw):
    engine = fp_engine(arch)
    clock = VirtualClock()
    sched = RequestScheduler(engine, classes=[tuple(c) for c in classes],
                             gen=GenerationConfig(max_new_tokens=max_new),
                             chunk_size=chunk_size, key=jax.random.key(0),
                             obs=Observability(), clock=clock.now, **sched_kw)
    frontend = ServingFrontend(
        sched, config=config if config is not None
        else FrontendConfig(journal=True), clock=clock)
    return engine, sched, frontend, clock


# -- the virtual clock itself -------------------------------------------------

def test_virtual_clock_orders_timers_without_wall_time():
    clock = VirtualClock()
    log = []

    async def sleeper(dt, name):
        await clock.sleep(dt)
        log.append((clock.now(), name))

    async def main():
        await asyncio.gather(sleeper(120.0, "b"), sleeper(60.0, "a"),
                             sleeper(120.0, "c"))

    t0 = time.perf_counter()
    clock.run(main())
    wall = time.perf_counter() - t0
    # 4 simulated minutes; ties resolve in creation order, deterministically.
    assert log == [(60.0, "a"), (120.0, "b"), (120.0, "c")]
    assert wall < 5.0, f"virtual sleeps burned {wall:.1f}s of wall clock"


def test_virtual_clock_deadlock_raises():
    clock = VirtualClock()

    async def hang():
        await asyncio.Event().wait()      # nothing will ever set it

    with pytest.raises(RuntimeError, match="deadlock"):
        clock.run(hang())


def test_frontend_rejects_mismatched_clock():
    engine = fp_engine("retnet-1.3b")
    sched = RequestScheduler(engine, classes=[(1, 32)],
                             gen=GenerationConfig(max_new_tokens=2),
                             chunk_size=8, key=jax.random.key(0))
    with pytest.raises(ValueError, match="timebase"):
        ServingFrontend(sched, clock=VirtualClock())


# -- (a) greedy token identity frontend vs direct run() per cache arch -------

PROMPT_LENS = [5, 9, 14]
IDENTITY_MAX_NEW = 4


def test_frontend_tokens_match_direct_run(cache_arch):
    engine = fp_engine(cache_arch)
    prompts = {uid: prompt_list(engine, s, seed=2 + uid)
               for uid, s in enumerate(PROMPT_LENS)}

    def sched_for(clock=None):
        return RequestScheduler(
            engine, classes=[(2, 32)],
            gen=GenerationConfig(max_new_tokens=IDENTITY_MAX_NEW),
            chunk_size=8, key=jax.random.key(0), obs=Observability(),
            clock=clock.now if clock else None)

    direct = sched_for()
    for uid, p in prompts.items():
        direct.submit(Request(uid=uid, prompt=p))
    want = direct.run()

    clock = VirtualClock()
    frontend = ServingFrontend(sched_for(clock), clock=clock)

    async def main():
        got: dict[int, list[int]] = {}

        async def consume(stream):
            got[stream.uid] = [tok async for tok in stream]

        async with frontend:
            tasks = []
            for uid, p in prompts.items():
                # Staggered arrivals: the interleaving differs from the
                # closed-loop drain, the tokens must not.
                await clock.sleep(0.05 * (uid + 1))
                tasks.append(asyncio.ensure_future(
                    consume(frontend.submit(p, uid=uid))))
            await asyncio.gather(*tasks)
        return got

    got = clock.run(main())
    assert set(got) == set(want)
    for uid in want:
        assert got[uid] == want[uid].tokens, (
            f"{cache_arch} uid {uid}: frontend stream diverged from "
            f"direct run()")


# -- (b) shed fires exactly at the windowed p99 crossing ----------------------

def _shed_config(**kw) -> FrontendConfig:
    base = dict(ttft_slo_s=0.5, slo_window_s=10.0, min_slo_samples=4,
                guaranteed_admit=0, journal=True)
    base.update(kw)
    return FrontendConfig(**base)


def test_shed_fires_exactly_at_p99_breach():
    engine, sched, frontend, clock = make_stack(config=_shed_config())
    hist = sched.obs.metrics.histogram("sched.ttft_s")
    prompt = prompt_list(engine, 5)

    async def main():
        async with frontend:
            # Below target: p99 of these == 0.49 < 0.5 -> admit.
            for v in (0.40, 0.45, 0.40, 0.49):
                hist.record(v, t=clock.now())
            s0 = frontend.submit(prompt, uid=0)
            assert [t async for t in s0] != []

            # Exactly AT the target: strict inequality -> still admit.
            for v in (0.50, 0.50, 0.50, 0.50):
                hist.record(v, t=clock.now())
            s1 = frontend.submit(prompt, uid=1)
            await s1.result()

            # Crossing: one tail sample pushes the windowed p99 over.
            hist.record(0.70, t=clock.now())
            with pytest.raises(RequestShed) as exc:
                frontend.submit(prompt, uid=2)
            assert exc.value.p99 is not None and exc.value.p99 > 0.5

            # Window expiry: advance past the window; the breach evidence
            # ages out, admission resumes (and it's an admit, not a shed).
            await clock.sleep(frontend.config.slo_window_s + 1.0)
            s3 = frontend.submit(prompt, uid=3)
            await s3.result()

    clock.run(main())
    assert frontend.stats["shed"] == 1
    assert frontend.stats["shed_unexplained"] == 0
    assert frontend.stats["admitted"] == 3
    assert any(" shed uid=2" in line for line in frontend.journal)


def test_shed_respects_min_samples_floor():
    engine, sched, frontend, clock = make_stack(
        config=_shed_config(min_slo_samples=6))
    hist = sched.obs.metrics.histogram("sched.ttft_s")
    prompt = prompt_list(engine, 5)

    async def main():
        async with frontend:
            for v in (9.0, 9.0, 9.0):       # wildly over target, 3 < 6
                hist.record(v, t=clock.now())
            s = frontend.submit(prompt, uid=0)     # thin evidence -> admit
            await s.result()

    clock.run(main())
    assert frontend.stats["shed"] == 0


def test_deprioritize_action_admits_at_lower_priority():
    engine, sched, frontend, clock = make_stack(
        config=_shed_config(shed_action="deprioritize",
                            deprioritize_level=-3))
    hist = sched.obs.metrics.histogram("sched.ttft_s")
    prompt = prompt_list(engine, 5)
    seen: dict[int, int] = {}
    orig_submit = sched.submit
    sched.submit = lambda req, priority=None: (
        seen.__setitem__(req.uid, req.priority), orig_submit(req, priority))[1]

    async def main():
        async with frontend:
            for v in (0.9,) * 5:
                hist.record(v, t=clock.now())
            s = frontend.submit(prompt, uid=0)      # breached -> deprioritize
            await s.result()

    clock.run(main())
    assert frontend.stats["deprioritized"] == 1
    assert frontend.stats["shed"] == 0
    assert seen[0] == -3


# -- (c) mid-stream cancel releases the slot and drops prefix leases ----------

def test_midstream_cancel_releases_slot_and_prefix_leases():
    engine, sched, frontend, clock = make_stack(
        "qwen3-8b", classes=((2, 64),), max_new=6,
        prefix_cache=True, prefix_page_size=8)
    prompt = prompt_list(engine, 40, seed=3)

    async def main():
        async with frontend:
            # First pass registers the prompt's pages in the prefix index.
            s0 = frontend.submit(prompt, uid=0)
            async for _ in s0:
                pass
            await s0.result()

            # Second pass adopts the cached prefix (leases pages), then is
            # cancelled two tokens into its stream.
            s1 = frontend.submit(prompt, uid=1)
            got = []
            async for tok in s1:
                got.append(tok)
                if len(got) == 2:
                    break
            await s1.aclose()
            fin = await s1.result()
            assert fin.cancelled
            assert fin.tokens[:2] == got

            # Slot back in the pool, leases dropped with it.
            assert sched.pool.free_slots == 2
            assert not sched.pool.prefix._leases
            assert sched.pool.prefix.stats["prefix_hits"] >= 1

            # The pool is actually reusable: a third request drains clean.
            s2 = frontend.submit(prompt_list(engine, 12, seed=5), uid=2)
            async for _ in s2:
                pass
            assert not (await s2.result()).cancelled

    clock.run(main())
    assert frontend.stats["cancelled"] == 1
    assert frontend.stats["completed"] == 2


def test_cancel_mid_chunked_prefill_reports_and_frees():
    """Regression: cancelling a request whose chunked prefill is mid-flight
    (the `_admitting` state) used to free the slot but record NO
    `FinishedRequest` — `run()` forgot the request existed and a frontend
    awaiting its stream would hang forever.  The fix routes it through the
    same `_finish` sink as every other terminal path."""
    engine = fp_engine("qwen3-8b")
    finished: list[FinishedRequest] = []
    sched = RequestScheduler(engine, classes=[(2, 64)],
                             gen=GenerationConfig(max_new_tokens=4),
                             chunk_size=8, key=jax.random.key(0),
                             prefix_cache=True, prefix_page_size=8,
                             on_finish=finished.append)
    base = prompt_list(engine, 40, seed=2)

    # Register a full prefix first, so the cancelled admission below holds
    # page leases when it dies (prefix head + distinct multi-chunk tail).
    sched.submit(Request(uid=0, prompt=base))
    sched.run()
    assert sched.pool.free_slots == 2
    tail = prompt_list(engine, 24, seed=9)
    sched.submit(Request(uid=1, prompt=base[:16] + tail))
    sched.step()                               # starts chunk 1 of the tail
    assert sched._admitting is not None and not sched._admitting["prefill"].done
    assert sched.pool.free_slots == 1
    assert sched.pool.prefix._leases          # adoption leased pages

    assert sched.cancel(1)
    assert sched.pool.free_slots == 2, "cancel leaked the admitting slot"
    assert not sched.pool.prefix._leases, "cancel leaked prefix leases"
    # The terminal record exists, immediately and after the drain.
    assert [f.uid for f in finished] == [0, 1]
    assert finished[1].cancelled and finished[1].tokens == []
    results = sched.run()
    assert 1 in results and results[1].cancelled


def test_queued_cancel_resolves_stream():
    # More requests than lanes: uid 2 is still queued when cancelled; the
    # scheduler records nothing for it (it never held a slot) and the
    # frontend synthesizes the terminal record.
    engine, sched, frontend, clock = make_stack(classes=((1, 48),))
    prompt = prompt_list(engine, 30)

    async def main():
        async with frontend:
            s0 = frontend.submit(prompt, uid=0)
            s2 = frontend.submit(prompt_list(engine, 20, seed=4), uid=2)
            await asyncio.sleep(0)           # let the stepper start uid 0
            assert await frontend.cancel(2)
            fin = await s2.result()
            assert fin.cancelled and fin.tokens == [] and fin.slot == -1
            async for _ in s0:
                pass

    clock.run(main())
    assert frontend.stats["cancelled"] == 1


# -- (d) two seeded runs produce byte-identical event logs --------------------

def _seeded_run():
    engine, sched, frontend, clock = make_stack(max_new=4)
    workload = Workload(arrivals=BurstyArrivals(20.0),
                        lengths=LengthMix(4, 16, 2, 4), n_requests=6,
                        vocab_size=engine.cfg.vocab_size, seed=7)

    async def main():
        async with frontend:
            return await run_open_loop(frontend, workload)

    report = clock.run(main())
    return frontend.journal, report


def test_seeded_runs_byte_identical():
    journal1, report1 = _seeded_run()
    journal2, report2 = _seeded_run()
    assert journal1, "journal unexpectedly empty"
    assert ("\n".join(journal1)).encode() == ("\n".join(journal2)).encode()
    assert ([dataclasses.asdict(o) for o in report1.outcomes]
            == [dataclasses.asdict(o) for o in report2.outcomes])
    assert report1.elapsed_s == report2.elapsed_s
    assert report1.completed == 6 and report1.sheds_unexplained == 0
