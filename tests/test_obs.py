"""repro.obs: numpy-faithful percentiles, live counter views, span
nesting/ordering in the lifecycle tracer, and the zero-overhead contract —
greedy output is token-identical with the full observability stack enabled,
across every serving cache architecture.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fp_engine, prompt_list
from repro.obs import (CounterView, Histogram, MetricsRegistry, NullTracer,
                       Observability, Tracer, percentile, request_track)
from repro.serving import GenerationConfig, Request, RequestScheduler

# -- percentiles vs numpy -----------------------------------------------------


class TestPercentile:
    def test_matches_numpy_linear(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 7, 50, 501):
            xs = rng.normal(size=n).tolist()
            for q in (0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0):
                assert percentile(xs, q) == pytest.approx(
                    float(np.percentile(xs, q)), rel=1e-12, abs=1e-12)

    def test_empty_and_bad_q_raise(self):
        with pytest.raises(ValueError):
            percentile([], 50.0)
        with pytest.raises(ValueError):
            percentile([1.0], 101.0)

    def test_histogram_summary_matches_numpy(self):
        h = MetricsRegistry().histogram("t")
        xs = np.random.default_rng(1).exponential(size=257)
        for x in xs:
            h.record(float(x))
        s = h.summary()
        assert s["count"] == 257
        assert s["mean"] == pytest.approx(float(xs.mean()))
        for q in (50, 95, 99):
            assert s[f"p{q}"] == pytest.approx(float(np.percentile(xs, q)))

    def test_empty_histogram_summarizes_to_count_only(self):
        assert Histogram("idle").summary() == {"count": 0}

    def test_decimation_bounds_memory_exact_extremes(self):
        h = Histogram("x", max_samples=8)
        for i in range(1000):
            h.record(float(i))
        assert h.count == 1000
        assert (h.min, h.max) == (0.0, 999.0)
        assert len(h.samples) < 8
        # The retained subsample still estimates the median reasonably.
        assert 250.0 < h.percentile(50.0) < 750.0


class TestWindowedPercentile:
    """`Histogram.percentile(window_s=..., now=...)` — the live-SLO read
    the frontend's admission policy is built on."""

    def test_matches_numpy_on_window_slice(self):
        h = Histogram("ttft")
        rng = np.random.default_rng(2)
        vals = rng.exponential(size=64).tolist()
        for i, v in enumerate(vals):
            h.record(v, t=float(i))          # one sample per "second"
        now, window = 63.0, 20.0
        in_window = vals[43:]                # t in [43, 63]
        assert h.window_samples(window, now) == in_window
        for q in (0.0, 50.0, 95.0, 99.0, 100.0):
            assert h.percentile(q, window_s=window, now=now) == pytest.approx(
                float(np.percentile(in_window, q)), rel=1e-12, abs=1e-12)

    def test_full_window_equals_lifetime(self):
        h = Histogram("ttft")
        for i, v in enumerate((3.0, 1.0, 2.0, 5.0)):
            h.record(v, t=float(i))
        assert h.percentile(99.0, window_s=1e9, now=3.0) == h.percentile(99.0)

    def test_empty_window_raises(self):
        h = Histogram("ttft")
        h.record(1.0, t=0.0)
        with pytest.raises(ValueError):
            h.percentile(50.0, window_s=0.5, now=100.0)   # sample aged out

    def test_window_without_now_raises(self):
        h = Histogram("ttft")
        h.record(1.0, t=0.0)
        with pytest.raises(ValueError, match="explicit `now`"):
            h.percentile(50.0, window_s=1.0)

    def test_nonpositive_window_raises(self):
        h = Histogram("ttft")
        h.record(1.0, t=0.0)
        with pytest.raises(ValueError):
            h.window_samples(0.0, now=1.0)

    def test_untimed_records_stamp_monotonic(self):
        import time

        h = Histogram("ttft")
        before = time.monotonic()
        h.record(7.0)                        # no t= — stamps time.monotonic()
        after = time.monotonic()
        assert h.window_samples(1e9, now=after) == [7.0]
        # ...and the stamp really is from the monotonic clock, not zero.
        assert before <= h._times[0] <= after

    def test_decimation_keeps_times_and_values_paired(self):
        h = Histogram("ttft", max_samples=64)
        for i in range(10_000):
            h.record(float(i), t=float(i))   # value == timestamp
        assert len(h._times) == len(h._samples)
        # After heavy decimation a trailing window must return only samples
        # actually recorded inside it — pairing drift would leak old values.
        recent = h.window_samples(1000.0, now=9999.0)
        assert recent and all(v >= 9000.0 - 1e-9 for v in recent)


# -- registry + live counter views --------------------------------------------


class TestRegistry:
    def test_counter_view_is_live_both_ways(self):
        reg = MetricsRegistry()
        v = reg.counter_view("s.", ["a", "b"])
        v["a"] += 2                       # legacy dict spelling
        reg.counter("s.b").inc(3)         # registry-side increment
        assert reg.counter("s.a").value == 2
        assert v["b"] == 3
        assert dict(v) == {"a": 2, "b": 3}
        assert v == {"a": 2, "b": 3}

    def test_counter_view_fixed_keys(self):
        v = MetricsRegistry().counter_view("s.", ["a"])
        with pytest.raises(KeyError):
            v["typo"]
        with pytest.raises(KeyError):
            v["typo"] = 1
        with pytest.raises(TypeError):
            del v["a"]
        assert isinstance(v, CounterView) and len(v) == 1

    def test_type_collision_raises_get_or_create_shares(self):
        reg = MetricsRegistry()
        c = reg.counter("x")
        assert reg.counter("x") is c
        with pytest.raises(ValueError):
            reg.gauge("x")
        with pytest.raises(ValueError):
            reg.histogram("x")

    def test_gauge_watermarks(self):
        g = MetricsRegistry().gauge("g")
        for v in (5, 2, 9):
            g.set(v)
        assert (g.value, g.min, g.max) == (9, 2, 9)

    def test_snapshot_is_json_ready(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.gauge("g").set(1.5)
        reg.histogram("h").record(0.25)
        snap = json.loads(json.dumps(reg.snapshot()))
        assert snap["counters"] == {"c": 1}
        assert snap["gauges"]["g"]["max"] == 1.5
        assert snap["histograms"]["h"]["p50"] == 0.25


# -- tracer: span nesting / ordering / export ---------------------------------


class TestTracer:
    def test_mispaired_end_raises(self):
        tr = Tracer()
        tr.begin("a")
        tr.begin("b")
        with pytest.raises(ValueError):
            tr.end("a")                   # b is innermost
        tr.end("b")
        tr.end("a")
        assert tr.open_spans() == []
        with pytest.raises(ValueError):
            tr.end("a")                   # nothing open

    def test_event_order_and_monotone_timestamps(self):
        tr = Tracer()
        with tr.span("outer"):
            tr.instant("mark")
            with tr.span("inner"):
                pass
        evs = [e for e in tr.events if e["ph"] != "M"]
        assert [(e["ph"], e["name"]) for e in evs] == [
            ("B", "outer"), ("i", "mark"), ("B", "inner"),
            ("E", "inner"), ("E", "outer")]
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts) and all(t >= 0 for t in ts)

    def test_per_track_nesting_is_independent(self):
        tr = Tracer()
        tr.begin("a", "scheduler")
        tr.begin("b", "req 0")
        tr.end("a", "scheduler")          # fine: different track's stack
        tr.end("b", "req 0")

    def test_tracks_declare_thread_names_once(self):
        tr = Tracer()
        for _ in range(3):
            tr.instant("x", "engine")
        tr.counter("depth", 1, "scheduler")
        meta = [e for e in tr.events if e["ph"] == "M"]
        assert [m["args"]["name"] for m in meta] == ["engine", "scheduler"]
        assert len({m["tid"] for m in meta}) == 2

    def test_deferred_device_args_gathered_at_flush(self):
        tr = Tracer()
        tr.instant("done", "engine", lengths=jnp.arange(3), n=7)
        assert tr._pending_args           # recorded, not yet gathered
        d = tr.to_dict()
        ev = [e for e in d["traceEvents"] if e["name"] == "done"][0]
        assert ev["args"]["lengths"] == [0, 1, 2] and ev["args"]["n"] == 7
        assert not tr._pending_args       # one-shot gather

    def test_export_perfetto_shape(self, tmp_path):
        tr = Tracer()
        with tr.span("s", "scheduler", k=1):
            tr.counter("q", 2, "scheduler")
        path = tmp_path / "trace.json"
        tr.export(str(path))
        with open(path) as f:
            doc = json.load(f)
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        for ev in doc["traceEvents"]:
            assert {"ph", "pid", "tid", "name"} <= set(ev)

    def test_null_tracer_noops_everything(self):
        nt = NullTracer()
        with nt.span("a"):
            nt.instant("b")
            nt.counter("c", 1)
        nt.end("never-opened")            # no bookkeeping, no raise
        nt.flush()
        assert not nt.enabled


# -- scheduler lifecycle trace ------------------------------------------------


def _req_events(tracer: Tracer, uid: int) -> list[tuple[str, str]]:
    tids = {e["args"]["name"]: e["tid"] for e in tracer.events
            if e["ph"] == "M"}
    tid = tids[request_track(uid)]
    return [(e["ph"], e["name"]) for e in tracer.events
            if e["tid"] == tid and e["ph"] != "M"]


class TestSchedulerTrace:
    def test_request_lifecycle_ordering(self):
        engine = fp_engine("retnet-1.3b")
        obs = Observability(tracer=Tracer())
        sched = RequestScheduler(engine, n_slots=1, cache_len=32,
                                 gen=GenerationConfig(max_new_tokens=4),
                                 chunk_size=8, obs=obs)
        sched.submit(Request(uid=7, prompt=prompt_list(engine, 4)))
        sched.run()
        names = [n for _, n in _req_events(obs.tracer, 7)]
        assert names[0] == "request" and names[-1] == "request"
        order = [names.index(n) for n in
                 ("queued", "admit", "prefill_chunk", "decode",
                  "first_token", "finish")]
        assert order == sorted(order)
        assert obs.tracer.open_spans(request_track(7)) == []
        snap = obs.metrics.snapshot()
        assert snap["histograms"]["sched.ttft_s"]["count"] == 1
        assert snap["counters"]["sched.admitted"] == 1

    def test_preemption_reads_as_preempt_resume_pair(self):
        engine = fp_engine("retnet-1.3b")
        obs = Observability(tracer=Tracer())
        sched = RequestScheduler(engine, n_slots=1, cache_len=32,
                                 gen=GenerationConfig(max_new_tokens=6),
                                 chunk_size=8, host_spill=True, obs=obs)
        sched.submit(Request(uid=0, prompt=[2, 3, 4]))
        while not sched._active:
            sched.step()
        sched.submit(Request(uid=1, prompt=[3, 4, 5]), priority=2)
        res = sched.run()
        assert len(res) == 2 and sched.stats["preempted"] == 1
        names = [n for _, n in _req_events(obs.tracer, 0)]
        order = [names.index(n) for n in
                 ("admit", "preempt", "preempted", "resume", "finish")]
        assert order == sorted(order)
        assert obs.tracer.open_spans(request_track(0)) == []
        snap = obs.metrics.snapshot()
        assert snap["counters"]["pool.spills"] == 1
        assert snap["histograms"]["pool.spill_bytes"]["count"] == 1
        assert snap["histograms"]["pool.fetch_bytes"]["count"] == 1


# -- zero-overhead contract: token identity with obs on -----------------------


GEN = GenerationConfig(max_new_tokens=6)


def _drain(engine, obs=None):
    kw = {"obs": obs} if obs is not None else {}
    sched = RequestScheduler(engine, n_slots=2, cache_len=64, gen=GEN,
                             chunk_size=8, **kw)
    for uid in range(3):
        sched.submit(Request(uid=uid, prompt=prompt_list(engine, 5 + uid,
                                                         seed=uid + 1)))
    return {u: f.tokens for u, f in sched.run().items()}


def test_greedy_identity_with_observability(cache_arch):
    """Full stack on (live tracer + profiler annotations + shared metrics)
    vs off: greedy output must be token-identical — the behavioral half of
    the A7 compiled-program byte-identity audit."""
    engine = fp_engine(cache_arch)
    base = _drain(engine)
    obs = Observability(tracer=Tracer(), profile=True)
    saved = engine.obs
    engine.obs = obs                      # engine-side spans + annotations
    try:
        traced = _drain(engine, obs=obs)
    finally:
        engine.obs = saved
    assert base == traced
    for uid in range(3):
        assert obs.tracer.open_spans(request_track(uid)) == []
    snap = obs.metrics.snapshot()
    assert snap["histograms"]["sched.ttft_s"]["count"] == 3
    assert snap["counters"]["sched.emitted"] == sum(
        len(t) for t in traced.values())
