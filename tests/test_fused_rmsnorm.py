"""Eq. (4) layer-fused RMSNorm: exactness of the fusion (contribution C3)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.core import fused_rmsnorm as fr

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _data(seed, m, d, n):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    gamma = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    beta = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
    return y, gamma, beta, w


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 8),
       d=st.sampled_from([16, 64]), n=st.sampled_from([8, 32]))
def test_fusion_exact_rmsnorm(seed, m, d, n):
    """(RMSNorm(y) @ W) * S == (y*gamma @ W) * (sigma^-1 * S)  (Eq. 4)."""
    y, gamma, beta, w = _data(seed, m, d, n)
    s_next = 0.37
    unfused = (fr.rmsnorm(y, gamma) @ w) * s_next
    y_star, sig_inv = fr.fused_rmsnorm_emit(y, gamma)
    fused = (y_star @ w) * (sig_inv[:, None] * s_next)
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(fused),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_fusion_with_beta_bias_term(seed):
    """The B_{n+1} = (beta @ W) * S term of Eq. (4)."""
    y, gamma, beta, w = _data(seed, 4, 32, 16)
    s_next = 1.7
    unfused = (fr.rmsnorm(y, gamma, beta) @ w) * s_next
    y_star, sig_inv = fr.fused_rmsnorm_emit(y, gamma)
    b_next = fr.fused_bias(beta, w, s_next)
    fused = (y_star @ w) * (sig_inv[:, None] * s_next) + b_next
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(fused),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_fusion_exact_layernorm_variant(seed):
    """The centered (LayerNorm) extension used by starcoder2/seamless."""
    y, gamma, beta, w = _data(seed, 5, 32, 12)
    unfused = fr.layernorm(y, gamma) @ w
    y_star, sig_inv = fr.fused_layernorm_emit(y, gamma)
    fused = (y_star @ w) * sig_inv[:, None]
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(fused),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_sigma_inv_matches_definition(seed):
    y, *_ = _data(seed, 6, 64, 1)
    sig = np.asarray(fr.rms_sigma_inv(y))
    want = 1.0 / np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1) + 1e-6)
    np.testing.assert_allclose(sig, want, rtol=1e-5)


def test_rmsnorm_dtype_preserved():
    y = jnp.ones((2, 16), jnp.bfloat16)
    g = jnp.ones((16,), jnp.float32)
    assert fr.rmsnorm(y, g).dtype == jnp.bfloat16
    ys, si = fr.fused_rmsnorm_emit(y, g)
    assert ys.dtype == jnp.bfloat16 and si.dtype == jnp.float32
