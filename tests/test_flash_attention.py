"""Flash attention (custom VJP) vs dense reference: fwd + grads, all masks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention

RNG = np.random.default_rng(0)


def dense_ref(q, k, v, causal=True, window=0):
    b, sq, kv, g, hd = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    qpos = np.arange(sq)[:, None]
    kpos = np.arange(sk)[None, :]
    mask = np.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))


CASES = [
    # sq, sk, causal, window, q_chunk, kv_chunk
    (24, 24, True, 0, 8, 8),
    (32, 48, False, 0, 16, 16),        # cross/bidirectional
    (40, 40, True, 12, 16, 8),         # sliding window
    (33, 57, True, 0, 16, 16),         # non-divisible -> padding path
    (16, 16, True, 0, 16, 16),         # single tile
]


@pytest.mark.parametrize("sq,sk,causal,window,qc,kc", CASES)
def test_forward_matches_dense(sq, sk, causal, window, qc, kc):
    q = jnp.asarray(RNG.normal(size=(2, sq, 2, 3, 16)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, sk, 2, 16)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, sk, 2, 20)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=kc)
    want = dense_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-6)


@pytest.mark.parametrize("sq,sk,causal,window,qc,kc", CASES)
def test_gradients_match_dense(sq, sk, causal, window, qc, kc):
    q = jnp.asarray(RNG.normal(size=(1, sq, 2, 2, 8)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, sk, 2, 8)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, sk, 2, 8)).astype(np.float32))

    def f(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_chunk=qc, kv_chunk=kc).sum()

    def fr(q, k, v):
        return dense_ref(q, k, v, causal, window).sum()

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_traced_window_hybrid_flags():
    """window may be a traced scalar (hymba's per-layer full/SWA flags)."""
    q = jnp.asarray(RNG.normal(size=(1, 16, 1, 2, 8)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 16, 1, 8)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, 16, 1, 8)).astype(np.float32))

    @jax.jit
    def f(win):
        return flash_attention(q, k, v, causal=True, window=win,
                               q_chunk=8, kv_chunk=8)

    got_w4 = f(jnp.int32(4))
    want_w4 = dense_ref(q, k, v, True, 4)
    np.testing.assert_allclose(np.asarray(got_w4), np.asarray(want_w4),
                               rtol=1e-5, atol=1e-6)
    got_full = f(jnp.int32(16))
    want_full = dense_ref(q, k, v, True, 16)
    np.testing.assert_allclose(np.asarray(got_full), np.asarray(want_full),
                               rtol=1e-5, atol=1e-6)


def test_traced_q_offset_matches_full_slice():
    """Chunked prefill: queries at absolute offset `off` over a cache longer
    than the valid prefix must equal the same rows of one full flash call —
    with traced offsets, so every chunk offset shares one compile."""
    s, off, cap = 24, 16, 40
    q = jnp.asarray(RNG.normal(size=(2, s, 2, 2, 8)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, s, 2, 8)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, s, 2, 8)).astype(np.float32))
    full = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)

    k_cache = jnp.pad(k, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
    v_cache = jnp.pad(v, ((0, 0), (0, cap - s), (0, 0), (0, 0)))

    @jax.jit
    def chunk(q_blk, offset):
        return flash_attention(q_blk, k_cache, v_cache, causal=True,
                               q_offset=offset, kv_len=offset + q_blk.shape[1],
                               q_chunk=8, kv_chunk=8)

    got = chunk(q[:, off:], jnp.int32(off))
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, off:]),
                               rtol=1e-5, atol=2e-6)


def test_k_offset_masks_leading_garbage():
    """Ring linearization: keys handed over with a (possibly negative)
    k_offset — rows whose absolute position falls outside [0, kv_len) must
    not contribute, wherever they sit in the buffer."""
    s, lead = 16, 4
    q = jnp.asarray(RNG.normal(size=(1, s, 2, 2, 8)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, s, 2, 8)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, s, 2, 8)).astype(np.float32))
    full = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)

    junk = jnp.full((1, lead, 2, 8), 7.0, jnp.float32)
    got = flash_attention(q, jnp.concatenate([junk, k], axis=1),
                          jnp.concatenate([junk, v], axis=1), causal=True,
                          k_offset=jnp.int32(-lead), kv_len=jnp.int32(s),
                          q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-5, atol=2e-6)


def test_no_quadratic_buffer_in_grad():
    """The custom VJP must not save per-tile score tensors (the A-m1 fix):
    grad temp memory stays far below the dense [Sq, Sk] score matrix."""
    B, S, KV, G, HD = 1, 2048, 2, 2, 32

    def f(q, k, v):
        return flash_attention(q, k, v, q_chunk=256, kv_chunk=256).sum()

    comp = jax.jit(jax.grad(f, argnums=(0, 1, 2))).lower(
        jax.ShapeDtypeStruct((B, S, KV, G, HD), jnp.float32),
        jax.ShapeDtypeStruct((B, S, KV, HD), jnp.float32),
        jax.ShapeDtypeStruct((B, S, KV, HD), jnp.float32)).compile()
    temp = comp.memory_analysis().temp_size_in_bytes
    dense_scores = B * KV * G * S * S * 4
    assert temp < 0.75 * dense_scores, (temp, dense_scores)
