"""Distribution-path tests needing multiple devices: executed in subprocesses
with virtual CPU devices so the main pytest process keeps 1 device."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _run(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_tiny_dryrun_train_and_decode():
    """The dry-run path (lower+compile+analyses) on a 2x2 mesh."""
    out = _run("""
import sys
from repro.launch import dryrun
for shape in ("train_4k", "decode_32k"):
    rec = dryrun.run_cell("internlm2-1.8b", shape, "tiny",
                          out_dir="/tmp/dr_test", force=True, verbose=False)
    assert rec["status"] == "ok", rec
    assert rec["memory"]["total_size_in_bytes"] > 0
    assert rec["cost"].get("flops", 0) > 0
print("DRYRUN_OK")
""", devices=8, timeout=1800)
    assert "DRYRUN_OK" in out


def test_tiny_multipod_mesh_lowers():
    """The 'pod' axis shards: tiny multi-pod mesh compile."""
    out = _run("""
from repro.launch import dryrun
rec = dryrun.run_cell("retnet-1.3b", "train_4k", "tiny_multi",
                      out_dir="/tmp/dr_test2", force=True, verbose=False)
assert rec["status"] == "ok", rec
print("MULTIPOD_OK", rec["n_devices"])
""", devices=8, timeout=1800)
    assert "MULTIPOD_OK 8" in out


def test_moe_sharded_equals_local():
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import mlp
from repro.models.config import ModelConfig
from repro.models.modules import ParamBuilder
from repro.core.hsa import HSAEngine
from repro.runtime import sharding as shd
from repro.launch.mesh import make_tiny_mesh

mesh = make_tiny_mesh()
cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=0, vocab_size=64, n_experts=4, top_k=2,
                  moe_d_ff=32, capacity_factor=8.0, param_dtype="float32")
b = ParamBuilder(key=jax.random.key(0))
mlp.moe_init(b, cfg)
eng = HSAEngine()
x = jax.random.normal(jax.random.key(1), (4, 16, 64)) * 0.3
y_ref, _ = mlp.moe_apply(b.params, x, None, eng, "train", cfg)
p_sh = jax.device_put(b.params, NamedSharding(mesh, P()))
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
with shd.sharding_ctx(mesh, shd.ShardingPolicy()):
    y_sh, _ = jax.jit(lambda p, xx: mlp.moe_apply(p, xx, None, eng, "train", cfg))(p_sh, x_sh)
err = float(jnp.max(jnp.abs(y_ref - y_sh)))
assert err < 1e-5, err
print("MOE_SHARDED_OK", err)
""", devices=4)
    assert "MOE_SHARDED_OK" in out


def test_psum_compressed_gradients():
    out = _run("""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compression
from repro.compat import shard_map
from repro.launch.mesh import make_tiny_mesh

mesh = make_tiny_mesh()   # (data=2, model=2)
g_local = jnp.stack([jnp.full((16,), float(i)) for i in range(2)])  # per-shard
res = jnp.zeros((2, 16))

def f(g, r):
    return compression.psum_compressed({"g": g}, {"g": r}, "data")

fn = shard_map(lambda g, r: f(g[0], r[0]),
                   mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P(), P("data")), check_vma=False)
(summed, new_res) = fn(g_local, res)
want = np.full(16, 0.0 + 1.0)
np.testing.assert_allclose(np.asarray(summed["g"]), want, atol=0.02)
print("PSUM_COMPRESSED_OK")
""", devices=4)
    assert "PSUM_COMPRESSED_OK" in out


def test_train_loop_with_failure_injection():
    """End-to-end: train N steps, inject host failure, elastic re-mesh,
    resume from checkpoint, loss continues improving."""
    out = _run("""
import subprocess, sys, os
sys.argv = ["train", "--arch", "retnet-1.3b", "--reduced", "--steps", "12",
            "--batch", "4", "--seq", "64", "--ckpt-dir", "/tmp/ck_ft",
            "--ckpt-every", "4", "--fail-at", "6", "--mesh", "tiny"]
from repro.launch.train import main
main()
""", devices=8, timeout=1800)
    assert "elastic plan" in out
    assert "improved" in out


def test_checkpoint_resume_exact():
    out = _run("""
import sys, shutil
shutil.rmtree("/tmp/ck_resume", ignore_errors=True)
from repro.launch.train import main
def run(argv):
    sys.argv = argv
    main()
base = ["train", "--arch", "internlm2-1.8b", "--reduced", "--steps", "10",
        "--batch", "4", "--seq", "32", "--ckpt-dir", "/tmp/ck_resume",
        "--ckpt-every", "5"]
run(base)
run(base[:4] + ["--steps", "15"] + base[6:] + ["--resume"])
print("RESUME_OK")
""", devices=1, timeout=1800)
    assert "resumed from step" in out
    assert "RESUME_OK" in out


def test_pipeline_parallel_equals_sequential():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.pipeline_parallel import pipeline_forward
from repro.launch.mesh import _mesh

mesh = _mesh((4,), ("stage",))
L, M, MB, D = 8, 6, 4, 16
ws = jax.random.normal(jax.random.key(0), (L, D, D)) * (0.5 / D**0.5)
x = jax.random.normal(jax.random.key(1), (M, MB, D))

def block(w, h):
    return jnp.tanh(h @ w)

got = pipeline_forward(lambda w, h: block(w, h), ws, x, mesh, "stage")
want = x
for i in range(L):
    want = block(ws[i], want)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
print("PP_OK")
""", devices=4)
    assert "PP_OK" in out
