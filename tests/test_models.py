"""Model-zoo behaviour: prefill->decode consistency per family, train loss,
HSA phase formats, deployment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hsa import HSAConfig, HSAEngine
from repro.models import deploy, frontends, lm
from repro.models.config import ModelConfig

ENGINE = HSAEngine(HSAConfig())

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
            vocab_size=256, head_dim=16, vocab_pad_multiple=64,
            param_dtype="float32")

FAMILIES = {
    "dense": ModelConfig(name="dense", family="dense", **BASE),
    "qknorm_bias": ModelConfig(name="qkb", family="dense", qk_norm=True,
                               qkv_bias=True, **BASE),
    "layernorm": ModelConfig(name="ln", family="dense",
                             norm_type="layernorm", **BASE),
    "moe": ModelConfig(name="moe", family="moe", n_experts=4, top_k=2,
                       moe_d_ff=64, n_shared_experts=1, capacity_factor=8.0,
                       **BASE),
    "ssm": ModelConfig(name="ssm", family="ssm", rope=False, ssm_state=8,
                       d_inner=128, dt_rank=8, ssm_chunk=8,
                       **{**BASE, "d_ff": 0}),
    "retnet": ModelConfig(name="ret", family="retnet",
                          attn_type="retention", **BASE),
    "hybrid": ModelConfig(name="hyb", family="hybrid", sliding_window=16,
                          ssm_state=8, d_inner=128, dt_rank=8, ssm_chunk=8,
                          **BASE),
    "mla_moe": ModelConfig(name="mla", family="moe", attn_type="mla",
                           n_experts=4, top_k=2, moe_d_ff=64,
                           n_shared_experts=1, first_dense_layers=1,
                           capacity_factor=8.0, q_lora_rank=32,
                           kv_lora_rank=32, qk_nope_head_dim=16,
                           qk_rope_head_dim=8, v_head_dim=16,
                           **{**BASE, "n_layers": 3}),
    "vlm": ModelConfig(name="vlm", family="vlm", frontend="vision",
                       frontend_tokens=8, **BASE),
    "encdec": ModelConfig(name="ed", family="audio", encoder_layers=2,
                          rope=False, abs_pos_embed=True,
                          norm_type="layernorm", frontend="audio",
                          frontend_tokens=16, **BASE),
}


def _batch(cfg, S, B=2, seed=1):
    toks = jax.random.randint(jax.random.key(seed), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = frontends.synth_patch_embeds(cfg, B)
    if cfg.is_encdec:
        batch["src_embeds"] = frontends.synth_frame_embeds(cfg, B, 16)
    return batch


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_decode_consistency(fam):
    """prefill(S) + decode(token S) == prefill(S+1) last logits."""
    cfg = FAMILIES[fam]
    S = 12 if fam == "hybrid" else 20  # hybrid exact only inside the window
    params, _, _ = lm.init(cfg, jax.random.key(0))
    b_s = _batch(cfg, S + 1)
    batch = {k: (v[:, :S] if k in ("tokens", "labels") else v)
             for k, v in b_s.items()}
    _, cache = lm.forward_prefill(params, batch, cfg, ENGINE, cache_len=S + 4)
    lg_dec, _ = lm.forward_decode(params, b_s["tokens"][:, S:S + 1], cache,
                                  cfg, ENGINE)
    lg_ref, _ = lm.forward_prefill(params, b_s, cfg, ENGINE)
    rel = float(jnp.max(jnp.abs(lg_dec - lg_ref))) / (
        float(jnp.max(jnp.abs(lg_ref))) + 1e-9)
    assert rel < 1e-4, rel


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_train_step_finite(fam):
    cfg = FAMILIES[fam]
    params, _, _ = lm.init(cfg, jax.random.key(0))
    loss, metrics = lm.forward_train(params, _batch(cfg, 16), cfg, ENGINE)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.forward_train(p, _batch(cfg, 16), cfg,
                                                ENGINE)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


def test_multi_step_decode_matches_full_forward():
    cfg = FAMILIES["dense"]
    params, _, _ = lm.init(cfg, jax.random.key(0))
    S, EXTRA = 10, 4
    b_full = _batch(cfg, S + EXTRA)
    _, cache = lm.forward_prefill(
        params, {"tokens": b_full["tokens"][:, :S]}, cfg, ENGINE,
        cache_len=S + EXTRA)
    for i in range(EXTRA):
        lg, cache = lm.forward_decode(params, b_full["tokens"][:, S + i:S + i + 1],
                                      cache, cfg, ENGINE)
    lg_ref, _ = lm.forward_prefill(params, b_full, cfg, ENGINE)
    # after decoding token S+EXTRA-1 the logits predict position S+EXTRA
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-3, atol=1e-4)


def test_deployed_formats_behave(monkeypatch):
    """fp / w8a8 / mxint4 paths agree to quantization tolerance; decode
    streams 4.25-bit weights (the EMA claim)."""
    cfg = FAMILIES["dense"]
    params, _, paths = lm.init(cfg, jax.random.key(0))
    served = deploy.deploy_quantize(params, paths)
    batch = _batch(cfg, 8)

    fp = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp"))
    q = HSAEngine(HSAConfig())   # w8a8 prefill / mxint4 decode
    lg_fp, cache_fp = lm.forward_prefill(params, batch, cfg, fp, cache_len=10)
    lg_q, cache_q = lm.forward_prefill(served, batch, cfg, q, cache_len=10)
    # logits order mostly preserved under W8A8
    top_fp = np.asarray(jnp.argsort(lg_fp, axis=-1)[:, -5:])
    top_q = np.asarray(jnp.argsort(lg_q, axis=-1)[:, -5:])
    overlap = np.mean([len(set(a) & set(b)) / 5 for a, b in zip(top_fp, top_q)])
    assert overlap >= 0.4, overlap

    tok = jnp.argmax(lg_q, -1)[:, None]
    lg_d, _ = lm.forward_decode(served, tok, cache_q, cfg, q)
    assert bool(jnp.all(jnp.isfinite(lg_d)))


def test_deploy_drops_masters_except_mla_absorbed():
    cfg = FAMILIES["mla_moe"]
    params, _, paths = lm.init(cfg, jax.random.key(0))
    served = deploy.deploy_quantize(params, paths)
    blocks = served["blocks"]
    assert "w" not in blocks["attn"]["wq_a"]          # master dropped
    assert "w" in blocks["attn"]["wk_b"]              # absorbed-decode needs it
    assert "w" in blocks["attn"]["wv_b"]
    assert "mx_packed" in blocks["attn"]["wq_a"]
    # experts quantized to stacked MXINT4
    assert "wg_mx" in blocks["moe"]["experts"]
    assert "wg" not in blocks["moe"]["experts"]


def test_reduced_configs_are_small():
    from repro import configs
    for name in configs.ASSIGNED:
        red = configs.get_config(name).reduced()
        shapes = jax.eval_shape(lambda k: lm.init(red, k)[0],
                                jax.random.key(0))
        n = sum(x.size for x in jax.tree.leaves(shapes))
        assert n < 30e6, (name, n)
