"""Property tests for `RequestScheduler`/`CachePool` invariants: random
acquire/release/spill/fetch sequences never leak lanes or host copies,
admission never exceeds class capacity, and queue order is FIFO within a
priority level.  Skips without hypothesis (pip install -e .[test])."""

import jax
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.serving import (CachePool, EngineSpec, GenerationConfig,
                           InferenceEngine, Request, RequestScheduler)

SETTINGS = dict(max_examples=30, deadline=None)

# RetNet: O(1) retention state, so per-example pool construction is cheap.
_CFG = configs.get_config("retnet-1.3b").reduced()
_ENGINE: list = []


def engine():
    if not _ENGINE:
        _ENGINE.append(InferenceEngine.from_config(
            "retnet-1.3b", EngineSpec(reduced=True, quantize=False)))
    return _ENGINE[0]


# -- CachePool: slot accounting under random op sequences ---------------------

# An op is (kind, value): acquire with a min_len, or release/spill/fetch of
# the i-th live/spilled slot (modulo the current population).
_OPS = st.lists(
    st.tuples(st.sampled_from(["acquire", "release", "spill", "fetch"]),
              st.integers(min_value=0, max_value=40)),
    min_size=1, max_size=40)


@settings(**SETTINGS)
@given(ops=_OPS)
def test_pool_never_leaks_or_overadmits(ops):
    """After ANY op sequence: free lanes + device residents == n_slots per
    class, host residents match the model, `residency` agrees, and acquire
    never hands out a lane in a full or too-small class."""
    classes = [(2, 8), (1, 32)]
    pool = CachePool(_CFG, classes=classes)
    device: dict[int, int] = {}            # sid -> clen (model state)
    host: dict[int, int] = {}

    for kind, v in ops:
        if kind == "acquire":
            need = v
            sid = pool.acquire(need)
            fits = [c for n, c in classes if c >= need]
            expect_free = any(
                sum(1 for cl in device.values() if cl == c) < dict(
                    (cc, nn) for nn, cc in classes)[c]
                for c in fits)
            if sid is None:
                assert not expect_free      # only refuses when really full
            else:
                clen = pool.slot_len(sid)
                assert clen >= need and clen in fits
                device[sid] = clen
        elif kind == "release" and (device or host):
            sid = sorted(list(device) + list(host))[v % (len(device)
                                                         + len(host))]
            pool.release(sid)
            device.pop(sid, None)
            host.pop(sid, None)
        elif kind == "spill" and device:
            sid = sorted(device)[v % len(device)]
            pool.spill(sid)
            host[sid] = device.pop(sid)
        elif kind == "fetch" and host:
            sid = sorted(host)[v % len(host)]
            clen = host[sid]
            busy = sum(1 for cl in device.values() if cl == clen)
            cap = dict((c, n) for n, c in classes)[clen]
            if busy < cap:
                pool.fetch(sid)
                device[sid] = host.pop(sid)
            else:
                with pytest.raises(ValueError, match="no free lane"):
                    pool.fetch(sid)

        # Invariants after every op: residency sums match the model.
        by_class = {c: n for n, c in classes}
        assert pool.free_slots == pool.n_slots - len(device)
        assert pool.host_resident == len(host)
        for sid, clen in device.items():
            assert pool.residency(sid) == "device"
            assert pool.slot_len(sid) == clen
        for sid, clen in host.items():
            assert pool.residency(sid) == "host"
        for c, n in by_class.items():
            assert sum(1 for cl in device.values() if cl == c) <= n


@settings(**SETTINGS)
@given(needs=st.lists(st.integers(min_value=0, max_value=32),
                      min_size=1, max_size=12))
def test_pool_never_admits_over_capacity(needs):
    """Unbounded acquire pressure: per-class admissions never exceed the
    class's lane count, and every refusal is a genuine full-pool state."""
    classes = [(2, 8), (2, 32)]
    pool = CachePool(_CFG, classes=classes)
    admitted: list[int] = []
    for need in needs:
        sid = pool.acquire(need)
        if sid is not None:
            admitted.append(pool.slot_len(sid))
    for n, clen in classes:
        assert admitted.count(clen) <= n
    assert pool.free_slots == pool.n_slots - len(admitted)


# -- RequestScheduler: priority queue + drain invariants ----------------------


@settings(**SETTINGS)
@given(priorities=st.lists(st.integers(min_value=-2, max_value=2),
                           min_size=1, max_size=16))
def test_submit_is_fifo_within_priority(priorities):
    """Queue order after random submits: priorities non-increasing, and uids
    strictly increasing (arrival order) within each priority level."""
    sched = RequestScheduler(engine(), n_slots=1, cache_len=16,
                             gen=GenerationConfig(max_new_tokens=2))
    for uid, pri in enumerate(priorities):
        sched.submit(Request(uid=uid, prompt=[2, 3]), priority=pri)
    queue = [(r.priority, r.uid) for r in sched._queue]
    assert [p for p, _ in queue] == sorted((p for p, _ in queue),
                                           reverse=True)
    for level in set(priorities):
        uids = [u for p, u in queue if p == level]
        assert uids == sorted(uids)


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_scheduler_random_submit_cancel_preempt_never_leaks(data):
    """Random submit/cancel/priority/preempt traffic, then drain: every
    non-cancelled request finishes with its full budget, every lane is free,
    nothing stays parked in the host tier, and spills == fetches + dropped
    (cancelled-while-parked) entries."""
    n = data.draw(st.integers(min_value=2, max_value=5), label="n_requests")
    pris = data.draw(st.lists(st.integers(min_value=0, max_value=3),
                              min_size=n, max_size=n), label="priorities")
    cancel = data.draw(st.sets(st.integers(min_value=0, max_value=n - 1),
                               max_size=2), label="cancel")
    sched = RequestScheduler(engine(), classes=[(1, 8)],
                             gen=GenerationConfig(max_new_tokens=3),
                             chunk_size=4, host_spill=True)
    for uid in range(n):
        sched.submit(Request(uid=uid, prompt=[2 + uid, 3, 4]),
                     priority=pris[uid])
        sched.step()                     # interleave admission with arrivals
    for uid in cancel:
        sched.cancel(uid)
    res = sched.run()

    assert sched.pool.free_slots == sched.pool.n_slots      # no lane leak
    assert sched.pool.host_resident == 0                    # no parked leak
    assert sched.pending == 0
    for uid in range(n):
        if uid in cancel and uid not in res:
            continue                     # cancelled before any admission
        assert uid in res
        if not res[uid].cancelled:
            assert len(res[uid].tokens) == 3, uid
    st_ = sched.pool.spill_stats
    assert st_["fetches"] <= st_["spills"]
    assert sched.stats["resumed"] <= sched.stats["preempted"]
