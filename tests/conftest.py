"""Shared serving-test harness.

One place for the per-cache-architecture engine factory, the greedy
token-identity loop, and the prompt builders that the serving test modules
(`test_serving_chunked.py`, `test_serving_speculative.py`,
`test_serving_spill.py`, `test_serving_sharded.py`) previously each
copy-pasted.

Importable two ways:

  * as a pytest conftest — the ``cache_arch`` param fixture fans a test out
    over every serving cache kind;
  * as a plain module (``import conftest``) from the multi-device subprocess
    tests, which run with this directory on PYTHONPATH — so the sharded
    cross-arch identity checks reuse exactly the same loop instead of a
    third copy.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import EngineSpec, InferenceEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# One arch per serving cache kind the pool / spill / rollback machinery
# distinguishes: linear KV (dense GQA), sliding-window ring + mamba recurrent
# (hybrid), O(1) retention state, O(1) ssm state, MoE experts.
SERVING_ARCHS = ["qwen3-8b", "hymba-1.5b", "retnet-1.3b",
                 "falcon-mamba-7b", "olmoe-1b-7b"]
# Chunked-vs-monolithic identity excludes MoE: expert-capacity dropping is
# per-dispatch, so chunk boundaries legitimately change routing there.
CHUNKED_ARCHS = SERVING_ARCHS[:4]
# Speculative identity swaps MoE-only olmoe for deepseek (MLA latent cache +
# MoE + the MTP head the self-speculation drafter needs).
SPECULATIVE_ARCHS = CHUNKED_ARCHS + ["deepseek-v3-671b"]

_ENGINES: dict = {}


def fp_engine(arch: str, *, mesh=None) -> InferenceEngine:
    """Reduced fp-path engine, cached per (arch, mesh identity).

    fp weights so identity checks isolate the machinery under test (chunk
    boundaries, spill round trips, sharded dataflow) from per-tensor dynamic
    activation-quantization granularity — a legitimate, finer quantization
    difference, not an error (docs/serving.md).
    """
    key = (arch, id(mesh))
    if key not in _ENGINES:
        _ENGINES[key] = InferenceEngine.from_config(
            arch, EngineSpec(reduced=True, quantize=False), mesh=mesh)
    return _ENGINES[key]


def prompt_ids(engine: InferenceEngine, s: int, seed: int = 1) -> jax.Array:
    """Deterministic [1, S] i32 prompt in the engine's vocab."""
    return jax.random.randint(jax.random.key(seed), (1, s), 1,
                              engine.cfg.vocab_size, dtype=jnp.int32)


def prompt_list(engine: InferenceEngine, s: int, seed: int = 1) -> list[int]:
    """Deterministic length-S prompt as a python list (scheduler requests)."""
    return prompt_ids(engine, s, seed)[0].tolist()


def greedy_continue(engine: InferenceEngine, logits, cache, n: int
                    ) -> list[int]:
    """THE identity loop: greedy per-token decode from a warm
    (logits, cache) pair — the oracle every admission-path refactor
    (chunked, bucketed, sharded, spilled) is compared against."""
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(n):
        toks.append(int(tok[0, 0]))
        logits, cache = engine.decode_step(tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return toks


def _tokens_of(x):
    if hasattr(x, "tokens"):                       # GenerationResult
        x = x.tokens
    return np.asarray(jax.device_get(x))


def assert_tokens_identical(got, want, msg: str = "") -> None:
    """Greedy token-identity assertion over lists / arrays /
    `GenerationResult`s — the single spelling of "this refactor changed
    nothing the user can see"."""
    np.testing.assert_array_equal(_tokens_of(got), _tokens_of(want),
                                  err_msg=msg)


@pytest.fixture(params=SERVING_ARCHS)
def cache_arch(request) -> str:
    """Fan a test out over every serving cache architecture."""
    return request.param


def run_in_devices(code: str, devices: int = 4, timeout: int = 1800) -> str:
    """Run python code in a subprocess with N virtual CPU devices.

    The flag must be set before any jax init, so multi-device tests cannot
    run in the main pytest process (it keeps 1 device).  The subprocess gets
    this directory on PYTHONPATH so ``import conftest`` reuses this harness.
    """
    paths = [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
    if os.environ.get("PYTHONPATH"):
        paths.append(os.environ["PYTHONPATH"])
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.pathsep.join(paths))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout
