"""Pallas kernel validation: interpret-mode vs pure-jnp oracles (ref.py),
swept over shapes, dtypes and epilogue combinations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mxint4 as mx
from repro.core import retention as ret
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _w(k, n, scale=0.1):
    return jnp.asarray(RNG.normal(size=(k, n)).astype(np.float32) * scale)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (1, 64, 96, 8, 32, 32),       # matvec (decode MVM)
    (5, 64, 96, 8, 32, 32),       # non-divisible M -> padding path
    (16, 128, 256, 8, 64, 64),    # multi-block all dims
    (8, 256, 64, 8, 64, 128),     # K-major accumulation
    (3, 32, 32, 8, 32, 32),       # single block
])
def test_mxint4_matmul_shapes(m, k, n, bm, bn, bk):
    x = jnp.asarray(RNG.normal(size=(m, k)).astype(np.float32))
    q = mx.quantize_mxint4(_w(k, n))
    y_ref = ops.mxint4_matmul(x, q, impl="ref")
    y_pal = ops.mxint4_matmul(x, q, impl="pallas", interpret=True,
                              block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("x_dtype", [jnp.float32, jnp.bfloat16])
def test_mxint4_matmul_dtypes(x_dtype):
    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32)).astype(x_dtype)
    q = mx.quantize_mxint4(_w(64, 64))
    y_ref = ops.mxint4_matmul(x, q, impl="ref")
    y_pal = ops.mxint4_matmul(x, q, impl="pallas", interpret=True,
                              block_m=8, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=2e-2, atol=2e-2)


def test_mxint4_matmul_fused_epilogue():
    """The Eq. (4) epilogue: out_scale x row_scale + bias, fused in-kernel."""
    m, k, n = 6, 64, 96
    x = jnp.asarray(RNG.normal(size=(m, k)).astype(np.float32))
    q = mx.quantize_mxint4(_w(k, n))
    os_ = jnp.asarray(RNG.normal(size=(n,)).astype(np.float32))
    rs = jnp.asarray(RNG.normal(size=(m,)).astype(np.float32))
    bias = jnp.asarray(RNG.normal(size=(n,)).astype(np.float32))
    y_ref = ops.mxint4_matmul(x, q, os_, rs, bias, impl="ref")
    y_pal = ops.mxint4_matmul(x, q, os_, rs, bias, impl="pallas",
                              interpret=True, block_m=8, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)


def test_mxint4_matmul_batched_input():
    x = jnp.asarray(RNG.normal(size=(2, 3, 64)).astype(np.float32))
    q = mx.quantize_mxint4(_w(64, 64))
    y_ref = ops.mxint4_matmul(x, q, impl="ref")
    y_pal = ops.mxint4_matmul(x, q, impl="pallas", interpret=True,
                              block_m=8, block_n=32, block_k=32)
    assert y_pal.shape == (2, 3, 64)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,s,dk,dv,chunk", [
    (1, 2, 32, 16, 16, 8),
    (2, 3, 64, 16, 24, 16),
    (2, 1, 128, 32, 64, 32),
])
def test_retention_kernel_vs_oracle(b, h, s, dk, dv, chunk):
    q = jnp.asarray(RNG.normal(size=(b, h, s, dk)).astype(np.float32)) * 0.3
    k = jnp.asarray(RNG.normal(size=(b, h, s, dk)).astype(np.float32)) * 0.3
    v = jnp.asarray(RNG.normal(size=(b, h, s, dv)).astype(np.float32)) * 0.3
    gamma = ret.head_decays(h)
    y_ref, st_ref = ref.retention_chunkwise_ref(q, k, v, gamma, chunk=chunk)
    y_pal, st_pal = ops.retention_chunkwise(q, k, v, gamma, chunk=chunk,
                                            impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref), np.asarray(st_pal),
                               rtol=1e-4, atol=1e-4)


def test_retention_kernel_matches_parallel_form():
    b, h, s, dk, dv = 2, 4, 64, 16, 32
    q = jnp.asarray(RNG.normal(size=(b, h, s, dk)).astype(np.float32)) * 0.3
    k = jnp.asarray(RNG.normal(size=(b, h, s, dk)).astype(np.float32)) * 0.3
    v = jnp.asarray(RNG.normal(size=(b, h, s, dv)).astype(np.float32)) * 0.3
    gamma = ret.head_decays(h)
    y_par = ret.retention_parallel(q, k, v, gamma)
    y_pal, _ = ops.retention_chunkwise(q, k, v, gamma, chunk=16,
                                       impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,d", [(8, 64), (32, 512), (7, 96)])
def test_rmsnorm_stats_kernel(m, d):
    y = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32))
    got = ops.rmsnorm_stats(y, impl="pallas", interpret=True)
    want = ref.rmsnorm_stats_ref(y.reshape(-1, d))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_w8a8_matmul_scaled():
    x = jnp.asarray(RNG.integers(-127, 128, size=(4, 32)), jnp.int8)
    w = jnp.asarray(RNG.integers(-127, 128, size=(32, 16)), jnp.int8)
    y = ops.w8a8_matmul(x, w, jnp.float32(0.5))
    want = (x.astype(np.int32) @ w.astype(np.int32)).astype(np.float32) * 0.5
    np.testing.assert_allclose(np.asarray(y), np.asarray(want))


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (5, 64, 96, 8, 32, 32),       # padding path
    (16, 128, 64, 8, 64, 64),
    (1, 32, 32, 8, 32, 32),       # single-token prefill edge
])
def test_w8a8_kernel_vs_ref(m, k, n, bm, bn, bk):
    """The MMM (prefill) dataflow kernel — output-stationary int8, Eq. (4)
    drain epilogue — against the jnp oracle."""
    xq = jnp.asarray(RNG.integers(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(RNG.integers(-127, 128, (k, n)), jnp.int8)
    rs = jnp.asarray(RNG.normal(size=(m,)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    y_ref = ops.w8a8_matmul(xq, wq, jnp.float32(0.01), rs, b, impl="ref")
    y_pal = ops.w8a8_matmul(xq, wq, jnp.float32(0.01), rs, b, impl="pallas",
                            interpret=True, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)
