"""Property tests for the shared-prefix page index (serving/paging.py):
radix insert/match/evict correctness and page-refcount invariants — refs
never negative, leases never leaked, leased pages never evicted, shared
page rows never mutated by matching/COW, host-spilled pages stay
matchable.  Pure bookkeeping (fake numpy rows, no engine), so hundreds of
examples run in milliseconds.  Skips without hypothesis
(pip install -e .[test])."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.serving.paging import (PageLeaseError, RadixPageIndex,
                                  SnapshotPrefixIndex)

SETTINGS = dict(max_examples=60, deadline=None)

# Small alphabet + short keys => heavy prefix collision, which is the
# interesting regime for a radix tree.
_KEY = st.lists(st.integers(min_value=0, max_value=3), min_size=1,
                max_size=12).map(tuple)
_KEYS = st.lists(_KEY, min_size=1, max_size=8)


def _rows_of(key):
    """Fake page rows: the token ids themselves, so row content encodes
    exactly which positions a page claims to hold."""
    return lambda a, b: {"rows": np.asarray(key[a:b], np.int64)}


def _nbytes(rows) -> int:
    return int(rows["rows"].nbytes)


def _insert(ix, key):
    return ix.insert(key, _rows_of(key), nbytes_of=_nbytes)


def _matched_tokens(matched):
    out = []
    for node, m in matched:
        out.extend(node.tokens[:m])
    return tuple(out)


def _matched_rows(ix, matched):
    out = []
    for node, m in matched:
        rows = node.rows if node.rows is not None else node.host_rows
        out.extend(rows["rows"][:m].tolist())
    return tuple(out)


# -- radix insert/match ------------------------------------------------------


@settings(**SETTINGS)
@given(keys=_KEYS, page_size=st.integers(min_value=1, max_value=5),
       probe=_KEY)
def test_match_is_true_prefix_with_matching_rows(keys, page_size, probe):
    """For ANY insert sequence and ANY probe: the matched page run spells a
    true prefix of the probe, and the rows those pages carry are exactly
    the tokens they claim (no page ever serves another prefix's rows)."""
    ix = RadixPageIndex(page_size)
    for k in keys:
        _insert(ix, k)
    matched = ix.match(probe)
    toks = _matched_tokens(matched)
    assert toks == probe[:len(toks)]
    assert _matched_rows(ix, matched) == toks
    # Every matched page but the last is fully used (maximality of the walk).
    for node, m in matched[:-1]:
        assert m == len(node.tokens)


@settings(**SETTINGS)
@given(keys=_KEYS, page_size=st.integers(min_value=1, max_value=5))
def test_insert_then_match_covers_whole_key(keys, page_size):
    """After inserting a key, matching it back covers every token, and
    re-inserting creates nothing new (full dedup of registered prefixes)."""
    ix = RadixPageIndex(page_size)
    for k in keys:
        _insert(ix, k)
    for k in keys:
        assert _matched_tokens(ix.match(k)) == k
        assert _insert(ix, k) == []


@settings(**SETTINGS)
@given(keys=_KEYS, page_size=st.integers(min_value=1, max_value=5))
def test_pages_are_never_mutated(keys, page_size):
    """Registered page rows are immutable through any later inserts and
    matches — divergence creates siblings, never rewrites (the COW
    contract's index half)."""
    ix = RadixPageIndex(page_size)
    snapshots = []
    for k in keys:
        for node in _insert(ix, k):
            snapshots.append((node, node.tokens,
                              node.rows["rows"].copy()))
        for probe in keys:
            ix.match(probe)
    for node, toks, rows in snapshots:
        assert node.tokens == toks
        np.testing.assert_array_equal(node.rows["rows"], rows)


# -- refcount invariants -----------------------------------------------------


_OPS = st.lists(
    st.tuples(st.sampled_from(["insert", "lease", "release", "evict",
                               "spill"]),
              _KEY),
    min_size=1, max_size=30)


@settings(**SETTINGS)
@given(ops=_OPS, page_size=st.integers(min_value=1, max_value=4))
def test_refcounts_never_negative_never_leaked(ops, page_size):
    """Random insert/lease/release/evict/spill interleavings: refcounts
    match a model exactly, leased pages are never evicted or spilled, and
    releasing every outstanding lease returns every page to refs == 0 (no
    leaked or lost references)."""
    ix = RadixPageIndex(page_size)
    outstanding: list[list] = []            # model: one entry per live lease

    def spill(rows):
        return rows                          # host tier: same fake pytree

    for kind, key in ops:
        if kind == "insert":
            _insert(ix, key)
        elif kind == "lease":
            matched = ix.match(key)
            nodes = [n for n, _ in matched]
            ix.lease(nodes)
            outstanding.append(nodes)
        elif kind == "release" and outstanding:
            ix.release(outstanding.pop())
        elif kind == "evict":
            victim = ix.evict_lru()
            if victim is not None:
                assert victim.refs == 0 and not victim.children
        elif kind == "spill":
            victim = ix.spill_lru(spill)
            if victim is not None:
                assert victim.refs == 0
                assert not victim.on_device and victim.host_rows is not None
        # Global invariant after every op:
        model = {}
        for nodes in outstanding:
            for n in nodes:
                model[id(n)] = model.get(id(n), 0) + 1
        for n in ix.nodes():
            assert n.refs == model.get(id(n), 0) >= 0

    for nodes in outstanding:
        ix.release(nodes)
    assert all(n.refs == 0 for n in ix.nodes())
    # One extra release must raise, not underflow.
    leased = [n for n in ix.nodes()]
    if leased:
        with pytest.raises(PageLeaseError):
            ix.release([leased[0]])


@settings(**SETTINGS)
@given(keys=_KEYS, page_size=st.integers(min_value=1, max_value=4))
def test_evict_drains_everything_unreferenced(keys, page_size):
    """With no leases, repeated LRU eviction drains the whole tree (leaves
    first — an interior page is only evictable once its children went)."""
    ix = RadixPageIndex(page_size)
    for k in keys:
        _insert(ix, k)
    evicted = 0
    while ix.evict_lru() is not None:
        evicted += 1
    assert ix.n_pages == 0
    assert evicted >= len(set(keys)) > 0 or ix.n_pages == 0


@settings(**SETTINGS)
@given(keys=_KEYS, page_size=st.integers(min_value=1, max_value=4))
def test_spilled_pages_stay_matchable(keys, page_size):
    """Host-migrating every unreferenced page changes no match result."""
    ix = RadixPageIndex(page_size)
    for k in keys:
        _insert(ix, k)
    want = {k: _matched_tokens(ix.match(k)) for k in keys}
    while ix.spill_lru(lambda rows: rows) is not None:
        pass
    assert all(not n.on_device for n in ix.nodes())
    for k in keys:
        assert _matched_tokens(ix.match(k)) == want[k]


# -- snapshot tier -----------------------------------------------------------


@settings(**SETTINGS)
@given(keys=_KEYS, probe=_KEY)
def test_snapshot_match_is_longest_strict_prefix(keys, probe):
    """The snapshot index returns the longest registered key that strictly
    prefixes the probe in the same cache class — or nothing."""
    ix = SnapshotPrefixIndex()
    for k in keys:
        ix.insert(k, 32, {"cache": np.asarray(k, np.int64)})
        ix.insert(k, 64, {"cache": np.asarray(k, np.int64)})
    got = ix.match(probe, 32)
    want = [k for k in set(keys)
            if len(k) < len(probe) and probe[:len(k)] == k]
    if not want:
        assert got is None
    else:
        assert got.key == max(want, key=len)
        assert got.cache_len == 32


@settings(**SETTINGS)
@given(keys=_KEYS)
def test_snapshot_refcounts_and_eviction(keys):
    ix = SnapshotPrefixIndex()
    for k in keys:
        ix.insert(k, 16, {"cache": np.asarray(k, np.int64)})
    snaps = ix.nodes()
    ix.lease(snaps)
    assert ix.evict_lru() is None            # everything pinned
    ix.release(snaps)
    with pytest.raises(PageLeaseError):
        ix.release([snaps[0]])
    while ix.evict_lru() is not None:
        pass
    assert ix.n_pages == 0
