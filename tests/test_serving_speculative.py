"""Speculative multi-token decode: greedy token-identity vs. the plain fused
loop per drafter and per cache architecture (incl. rollback after rejected
drafts), the ngram drafter, cache rollback helpers, scheduler integration
with per-slot acceptance stats, and priority admission."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (SPECULATIVE_ARCHS as ARCHS, assert_tokens_identical,
                      fp_engine, prompt_ids as _prompt)

from repro.models import lm
from repro.serving import (EngineSpec, GenerationConfig, InferenceEngine,
                           Request, RequestScheduler, SpeculativeConfig,
                           ngram_propose)


@pytest.mark.parametrize("arch", ARCHS)
def test_greedy_token_identity_ngram(arch):
    """Greedy speculative decode == the plain fused loop for every cache
    architecture.  The repetitive prompt makes the ngram drafter propose
    real candidates, so both full rejections (rollback of all k) and
    partial/total acceptance paths are crossed."""
    engine = fp_engine(arch)
    gen = GenerationConfig(max_new_tokens=14)
    for seed, prompt in [(0, jnp.asarray([[5, 9, 13] * 4], jnp.int32)),
                         (1, _prompt(engine, 7))]:
        base = engine.generate(prompt, gen)
        spec = engine.generate(prompt, gen,
                               speculative=SpeculativeConfig(k=3))
        assert_tokens_identical(spec, base, arch)
        assert spec.lengths.tolist() == base.lengths.tolist()
        assert spec.verify_steps >= 1
        assert spec.drafted == spec.verify_steps * 3


def test_greedy_token_identity_mtp_drafter():
    """The deepseek-v3 MTP head, promoted from a training-only loss to a
    decode-time draft model, must preserve greedy identity (MLA latent
    cache + MoE no-drop verify dispatch)."""
    engine = fp_engine("deepseek-v3-671b")
    gen = GenerationConfig(max_new_tokens=10)
    prompts = _prompt(engine, 6)
    base = engine.generate(prompts, gen)
    spec = engine.generate(
        prompts, gen, speculative=SpeculativeConfig(k=2, drafter="mtp"))
    assert_tokens_identical(spec, base)


def test_mtp_drafter_requires_mtp_head():
    engine = fp_engine("retnet-1.3b")
    with pytest.raises(ValueError, match="MTP head"):
        engine.generate(_prompt(engine, 4),
                        GenerationConfig(max_new_tokens=2),
                        speculative=SpeculativeConfig(k=2, drafter="mtp"))


def test_verify_block_must_fit_sliding_window():
    engine = fp_engine("hymba-1.5b")
    w = engine.cfg.sliding_window
    with pytest.raises(ValueError, match="sliding window"):
        engine.generate(_prompt(engine, 4),
                        GenerationConfig(max_new_tokens=2),
                        speculative=SpeculativeConfig(k=w))


def test_greedy_identity_batched_lockstep():
    """Batch rows with different acceptance depths advance in lockstep
    (commit = min over rows) and still reproduce the baseline exactly."""
    engine = fp_engine("qwen3-8b")
    gen = GenerationConfig(max_new_tokens=12)
    prompts = jnp.concatenate(
        [jnp.asarray([[5, 9, 13] * 3], jnp.int32), _prompt(engine, 9)], 0)
    base = engine.generate(prompts, gen)
    spec = engine.generate(prompts, gen, speculative=SpeculativeConfig(k=3))
    assert_tokens_identical(spec, base)


def test_stop_token_inside_accepted_block():
    """A stop token that lands mid-block must end the row there: later block
    tokens become pad and lengths include the stop token."""
    engine = fp_engine("retnet-1.3b")
    prompts = _prompt(engine, 5)
    free = engine.generate(prompts, GenerationConfig(max_new_tokens=8))
    stop = int(free.tokens[0, 3])
    gen = GenerationConfig(max_new_tokens=8, stop_tokens=(stop,),
                           pad_token_id=-1)
    base = engine.generate(prompts, gen)
    spec = engine.generate(prompts, gen, speculative=SpeculativeConfig(k=4))
    assert_tokens_identical(spec, base)
    assert spec.lengths.tolist() == base.lengths.tolist()


def test_stochastic_speculative_is_deterministic_under_fixed_key():
    """Stochastic speculative sampling: per-key reproducible, and a
    different key gives a different stream (the distribution-preservation
    argument itself is analytic — docs/serving.md)."""
    engine = fp_engine("retnet-1.3b")
    from repro.serving import SamplingParams
    gen = GenerationConfig(
        max_new_tokens=10,
        sampling=SamplingParams(temperature=0.9, top_k=50),
        speculative=SpeculativeConfig(k=3))
    prompts = _prompt(engine, 5)
    a = engine.generate(prompts, gen, key=jax.random.key(7)).tokens
    b = engine.generate(prompts, gen, key=jax.random.key(7)).tokens
    c = engine.generate(prompts, gen, key=jax.random.key(8)).tokens
    assert_tokens_identical(a, b)
    assert not bool(jnp.all(a == c))


def test_ngram_propose_lookup_and_fallback():
    """The lookup n-gram is (last m-1 committed tokens, pending token)."""
    hist = jnp.asarray([[5, 1, 8, 5, 1, 9, 5, 0]], jnp.int32)
    # Pending 1 after committed ...9,5 -> suffix (5, 1), which occurred at
    # positions 0 and 3; the MOST RECENT match (j=3) wins, so the draft
    # continues with hist[5:] = [9, 5].
    drafts = ngram_propose(hist, jnp.int32(7), jnp.asarray([1], jnp.int32),
                           k=2, m=2)
    assert drafts.tolist() == [[9, 5]]
    # Continuation running past committed history falls back to repeating
    # the pending token: committed [5,1,8,5,1], pending 8 -> suffix (1, 8)
    # matches at j=1, continues [5, 1, <past history -> 8>].
    drafts = ngram_propose(hist, jnp.int32(5), jnp.asarray([8], jnp.int32),
                           k=3, m=2)
    assert drafts.tolist() == [[5, 1, 8]]
    # No match at all -> repeat the pending token.
    drafts = ngram_propose(hist, jnp.int32(7), jnp.asarray([7], jnp.int32),
                           k=3, m=2)
    assert drafts.tolist() == [[7, 7, 7]]
    # n-gram longer than the whole history buffer degrades to the fallback
    # instead of crashing on an empty window set.
    drafts = ngram_propose(hist[:, :4], jnp.int32(4),
                           jnp.asarray([3], jnp.int32), k=2, m=8)
    assert drafts.tolist() == [[3, 3]]


@pytest.mark.parametrize("arch", ["hymba-1.5b", "retnet-1.3b"])
def test_rollback_restores_exact_state_after_full_rejection(arch):
    """Force a fully-rejected verify block and check the committed cache
    continues exactly like a plain decode step: ring slots must be restored
    (rejected writes alias live history) and recurrent state rolled back to
    the boundary snapshot."""
    engine = fp_engine(arch)
    prompts = _prompt(engine, 9, seed=3)
    k = 3
    logits, cache = engine.prefill(prompts, cache_len=9 + 8 + k)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # Reference: one plain decode step.
    ref_logits, _ = engine.decode_step(tok[:, None], cache)

    # Drafts chosen to mismatch the model's own argmax -> acceptance 0.
    bad = (tok[:, None] + jnp.asarray([[1, 2, 3]])) % engine.cfg.vocab_size
    block = jnp.concatenate([tok[:, None], bad], axis=1)
    la, _, ver = lm.forward_verify_chunk(engine.params, {"tokens": block},
                                         cache, engine.cfg, engine.hsa)
    assert int(jnp.argmax(la[0, 0])) != int(bad[0, 0])  # really rejected
    committed = lm.commit_verified_cache(cache, ver, jnp.int32(1), k + 1,
                                         engine.cfg)
    assert int(committed["pos"]) == int(cache["pos"]) + 1

    # The next decode step from the rolled-back cache must match the
    # baseline continuation bit-for-bit in greedy terms.
    nxt = jnp.argmax(la[:, 0], -1).astype(jnp.int32)
    out_spec, _ = lm.forward_decode(engine.params, nxt[:, None], committed,
                                    engine.cfg, engine.hsa)
    ref2_logits, _ = engine.decode_step(
        jnp.argmax(ref_logits, -1).astype(jnp.int32)[:, None],
        engine.decode_step(tok[:, None], cache)[1])
    assert int(jnp.argmax(out_spec[0])) == int(jnp.argmax(ref2_logits[0]))


def test_scheduler_speculative_matches_engine_generate():
    """The per-slot speculative lanes reproduce dedicated engine.generate
    runs and report per-request acceptance stats."""
    engine = fp_engine("retnet-1.3b")
    spec = SpeculativeConfig(k=3)
    gen = GenerationConfig(max_new_tokens=6, speculative=spec)
    sched = RequestScheduler(engine, n_slots=2, cache_len=32, gen=gen,
                             chunk_size=8)
    prompts = {0: [2, 3, 4, 2, 3, 4, 2, 3], 1: [5, 6, 7, 8], 2: [9, 10, 11]}
    streamed = []
    sched.on_token = lambda uid, tok: streamed.append((uid, tok))
    for uid, p in prompts.items():
        sched.submit(Request(uid=uid, prompt=p))
    res = sched.run()

    plain = GenerationConfig(max_new_tokens=6)
    for uid, p in prompts.items():
        want = engine.generate(jnp.asarray([p], jnp.int32),
                               plain).tokens[0].tolist()
        assert_tokens_identical(res[uid].tokens, want, str(uid))
        assert res[uid].verify_steps >= 1
        assert [t for u, t in streamed if u == uid] == want
    assert sched.stats["verify_steps"] == sum(
        r.verify_steps for r in res.values())


def test_scheduler_speculative_budget_truncates_block():
    """A verify block that overruns the token budget is truncated at the
    budget; the slot retires cleanly."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=5,
                           speculative=SpeculativeConfig(k=4))
    sched = RequestScheduler(engine, n_slots=1, cache_len=32, gen=gen,
                             chunk_size=8)
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))
    res = sched.run()
    want = engine.generate(jnp.asarray([[2, 3, 4]], jnp.int32),
                           GenerationConfig(max_new_tokens=5))
    assert_tokens_identical(res[0].tokens, want.tokens[0])
    assert len(res[0].tokens) == 5


def test_scheduler_speculative_reserves_verify_overrun():
    """Admission must account for the k-slot verify overrun: a request that
    fits without speculation but not with it is rejected loudly."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=4,
                           speculative=SpeculativeConfig(k=4))
    sched = RequestScheduler(engine, n_slots=1, cache_len=16, gen=gen,
                             chunk_size=8)
    with pytest.raises(ValueError, match="exceeds every pool class"):
        sched.submit(Request(uid=0, prompt=list(range(2, 12))))  # 10+4+4 > 16


def test_scheduler_rejects_mtp_drafter():
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(
        max_new_tokens=4,
        speculative=SpeculativeConfig(k=2, drafter="mtp"))
    with pytest.raises(ValueError, match="ngram"):
        RequestScheduler(engine, n_slots=1, cache_len=32, gen=gen)


def test_priority_admission_order():
    """submit(priority=...): higher priorities admit first, FIFO within a
    level, and priority requests overtake a deep default-priority queue."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=3)
    sched = RequestScheduler(engine, n_slots=1, cache_len=16, gen=gen,
                             chunk_size=8)
    order = []
    sched.on_token = lambda uid, tok: (order.append(uid)
                                       if uid not in order else None)
    sched.submit(Request(uid=0, prompt=[2, 3]))
    sched.submit(Request(uid=1, prompt=[2, 3]))
    sched.submit(Request(uid=2, prompt=[2, 3], priority=5))
    sched.submit(Request(uid=3, prompt=[2, 3]), priority=5)
    sched.submit(Request(uid=4, prompt=[2, 3]), priority=-1)
    assert [r.uid for r in sched._queue] == [2, 3, 0, 1, 4]
    sched.run()
    assert order == [2, 3, 0, 1, 4]


def test_submit_priority_argument_does_not_mutate_request():
    """submit(priority=...) is submission-scoped: the caller's Request keeps
    its constructed priority."""
    engine = fp_engine("retnet-1.3b")
    sched = RequestScheduler(engine, n_slots=1, cache_len=16,
                             gen=GenerationConfig(max_new_tokens=2))
    req = Request(uid=0, prompt=[2, 3])
    sched.submit(req, priority=5)
    assert req.priority == 0
    assert sched._queue[0].priority == 5 and sched._queue[0].uid == 0


def test_speculative_stats_on_repetitive_output():
    """The bench's acceptance property: on a looping greedy continuation the
    ngram drafter gets > 1 accepted token per verify step (the > 2x
    weight-read amortization the EMA argument wants)."""
    engine = InferenceEngine.from_config("starcoder2-15b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=96)
    prompt = jax.random.randint(jax.random.key(9), (1, 10), 1,
                                engine.cfg.vocab_size, dtype=jnp.int32)
    spec = engine.generate(prompt, gen, speculative=SpeculativeConfig(k=4))
    assert spec.verify_steps < 96                  # fewer reads than tokens
    assert spec.accepted_drafts > spec.verify_steps   # > 1 accepted/step
    assert spec.tokens_per_step > 2.0
