"""Checkpoint manager: atomicity, keep-N, async, resume, reshard-on-load."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager


def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3)},
            "opt": {"step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state(3.5)
    mgr.save(10, st)
    got, manifest = mgr.restore(_state(0.0))
    assert manifest["step"] == 10
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert int(got["opt"]["step"]) == 7


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.all_steps() == [3, 4]


def test_latest_and_explicit_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=5)
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    got, _ = mgr.restore(_state(), step=1)
    assert float(got["params"]["w"][0, 0]) == 1.0
    got, _ = mgr.restore(_state())
    assert float(got["params"]["w"][0, 0]) == 2.0


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state(9.0), blocking=False)
    got, _ = mgr.restore(_state())      # restore wait()s for the writer
    assert float(got["params"]["w"][0, 0]) == 9.0


def test_no_tmp_dirs_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


def test_restore_with_shardings(tmp_path):
    """Reshard-on-load: device_put into the current mesh's shardings."""
    from repro.compat import make_auto_mesh
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), _state())
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(2.0))
    got, _ = mgr.restore(_state(), shardings=sh)
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())


def test_dtype_preserved_from_reference(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    mgr.save(1, st)
    got, _ = mgr.restore({"w": jnp.zeros((2, 2), jnp.bfloat16)})
    assert got["w"].dtype == jnp.bfloat16
