"""Property tests: the three retention forms are the same function (Sec. II)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.core import retention as ret

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _qkv(seed, b, h, s, dk, dv):
    rng = np.random.default_rng(seed)
    mk = lambda *sh: jnp.asarray(rng.normal(size=sh).astype(np.float32) * 0.3)
    return mk(b, h, s, dk), mk(b, h, s, dk), mk(b, h, s, dv)


@given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 3),
       h=st.integers(1, 5), s=st.integers(1, 48),
       dk=st.sampled_from([4, 16]), dv=st.sampled_from([8, 24]))
def test_parallel_equals_recurrent(seed, b, h, s, dk, dv):
    q, k, v = _qkv(seed, b, h, s, dk, dv)
    g = ret.head_decays(h)
    y_par = ret.retention_parallel(q, k, v, g)
    y_rec, _ = ret.retention_recurrent(q, k, v, g)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([4, 8, 16, 64]))
def test_parallel_equals_chunkwise(seed, chunk):
    q, k, v = _qkv(seed, 2, 3, 64, 16, 24)
    g = ret.head_decays(3)
    y_par = ret.retention_parallel(q, k, v, g)
    y_chk, _ = ret.retention_chunkwise(q, k, v, g, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_chk),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_chunkwise_state_equals_recurrent_state(seed):
    q, k, v = _qkv(seed, 1, 2, 32, 8, 16)
    g = ret.head_decays(2)
    _, st_rec = ret.retention_recurrent(q, k, v, g)
    _, st_chk = ret.retention_chunkwise(q, k, v, g, chunk=8)
    np.testing.assert_allclose(np.asarray(st_rec), np.asarray(st_chk),
                               rtol=1e-4, atol=1e-4)


def test_warm_state_continuation():
    """Prefill chunkwise -> decode recurrent must continue seamlessly (the
    paper's LISO flow: parallel prompt, recurrent generation)."""
    q, k, v = _qkv(0, 1, 2, 40, 8, 16)
    g = ret.head_decays(2)
    y_full, _ = ret.retention_recurrent(q, k, v, g)
    _, st32 = ret.retention_chunkwise(q[:, :, :32], k[:, :, :32],
                                      v[:, :, :32], g, chunk=8)
    state = st32
    for i in range(32, 40):
        y_t, state = ret.retention_recurrent_step(
            q[:, :, i], k[:, :, i], v[:, :, i], state, g)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, :, i]),
                                   rtol=1e-4, atol=1e-4)


def test_decays_multi_scale():
    g = np.asarray(ret.head_decays(8))
    assert (g > 0).all() and (g < 1).all()
    assert (np.diff(g) > 0).all()           # increasing retention horizon
    np.testing.assert_allclose(g[0], 1 - 2 ** -5)


def test_group_norm_unit_rms():
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(2, 3, 5, 64)).astype(np.float32) * 7)
    n = ret.group_norm_heads(y)
    rms = np.sqrt(np.mean(np.asarray(n) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
