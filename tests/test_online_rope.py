"""Online RoPE (Eq. 5-6): identity-update vs direct tables (contribution C4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.core import online_rope as orp

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_update_matches_table_exactly_at_small_m():
    th = orp.rope_thetas(64)
    st_ = orp.init_state(64)
    for m in range(1, 20):
        st_ = orp.update(st_, th)
        s_ref, c_ref = orp.rope_table(jnp.asarray(m), th)
        np.testing.assert_allclose(np.asarray(st_.sin), np.asarray(s_ref),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_.cos), np.asarray(c_ref),
                                   atol=1e-5)


def test_drift_bounded_between_resyncs():
    """fp32 identity-updates drift; `advance` resyncs every 64 tokens and the
    drift between resyncs stays < 2e-5 (the DESIGN.md §2.4 contract) — three
    orders of magnitude below bf16 resolution (~8e-3)."""
    th = orp.rope_thetas(128)
    st_ = orp.init_state(128)
    worst = 0.0
    for m in range(1, 300):
        st_ = orp.advance(st_, th)
        s_ref, c_ref = orp.rope_table(jnp.asarray(m), th)
        worst = max(worst,
                    float(jnp.abs(st_.sin - s_ref).max()),
                    float(jnp.abs(st_.cos - c_ref).max()))
    assert worst < 2e-5, worst


def test_resync_is_exact():
    th = orp.rope_thetas(32)
    st_ = orp.init_state(32, pos=63)
    st_ = orp.advance(st_, th)              # pos 64 -> resync fires
    s_ref, c_ref = orp.rope_table(jnp.asarray(64), th)
    np.testing.assert_array_equal(np.asarray(st_.sin), np.asarray(s_ref))
    assert int(st_.pos) == 64


@given(seed=st.integers(0, 2**31 - 1), pos=st.integers(0, 500))
def test_embed_equals_table_rotation(seed, pos):
    """"Embed" mode == rotating with the directly computed angles."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 3, 32)).astype(np.float32))
    th = orp.rope_thetas(32)
    st_ = orp.init_state(32, pos=pos)
    sin, cos = orp.rope_table(jnp.asarray(pos), th)
    np.testing.assert_allclose(np.asarray(orp.embed(st_, x)),
                               np.asarray(orp.apply_rope(x, sin, cos)),
                               rtol=1e-5, atol=1e-5)


def test_rotation_preserves_norm():
    """RoPE is a rotation: per-pair L2 norms are invariant."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    th = orp.rope_thetas(64)
    sin, cos = orp.rope_table(jnp.asarray(123), th)
    y = orp.apply_rope(x, sin, cos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_relative_position_property():
    """<RoPE_m(q), RoPE_n(k)> depends only on m - n (the RoPE invariant)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    th = orp.rope_thetas(16)

    def dot(m, n):
        sm, cm = orp.rope_table(jnp.asarray(m), th)
        sn, cn = orp.rope_table(jnp.asarray(n), th)
        return float(orp.apply_rope(q, sm, cm) @ orp.apply_rope(k, sn, cn))

    np.testing.assert_allclose(dot(5, 3), dot(105, 103), rtol=1e-4)
    np.testing.assert_allclose(dot(17, 4), dot(30, 17), rtol=1e-4)
