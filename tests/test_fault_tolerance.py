"""Fault tolerance: heartbeats, stragglers, elastic mesh planning."""

import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.runtime import fault_tolerance as ft

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def test_heartbeat_timeout_detection():
    mon = ft.HeartbeatMonitor(["a", "b"], timeout_s=5.0)
    mon.beat("a", now=100.0)
    mon.beat("b", now=100.0)
    assert mon.check(now=104.0) == []
    mon.beat("a", now=104.0)
    assert mon.check(now=107.0) == ["b"]
    assert mon.alive_hosts() == ["a"]


def test_mark_failed_out_of_band():
    mon = ft.HeartbeatMonitor(["a", "b", "c"], timeout_s=1e9)
    mon.mark_failed("b")
    assert mon.check() == ["b"]


def test_straggler_detection():
    det = ft.StragglerDetector(threshold=2.0, window=8, min_samples=4)
    for i in range(8):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 1.0 if h != "h3" else 3.5)
    assert det.stragglers() == ["h3"]


def test_straggler_needs_samples():
    det = ft.StragglerDetector(min_samples=4)
    det.record("a", 1.0)
    det.record("b", 99.0)
    assert det.stragglers() == []


@given(alive=st.integers(16, 4096), tp=st.sampled_from([4, 8, 16]),
       pods=st.sampled_from([1, 2]))
def test_elastic_plan_invariants(alive, tp, pods):
    if alive < tp * pods:
        return
    plan = ft.plan_elastic_mesh(alive, model_parallel=tp, pods=pods)
    used = 1
    for s in plan.shape:
        used *= s
    assert used + plan.dropped_chips == alive or used <= alive
    assert plan.dropped_chips >= 0
    # TP degree preserved (param shards stay valid)
    assert plan.shape[-1] == tp
    if pods > 1:
        assert plan.axes == ("pod", "data", "model")
        assert plan.shape[0] == pods
    # DP is a power of two (ring-friendly collectives)
    dp = plan.shape[-2]
    assert dp & (dp - 1) == 0


def test_elastic_plan_fails_below_tp():
    with pytest.raises(AssertionError):
        ft.plan_elastic_mesh(8, model_parallel=16)


def test_failure_injector():
    mon = ft.HeartbeatMonitor(["a", "b"], timeout_s=1e9)
    inj = ft.FailureInjector({3: ["b"]})
    assert inj.maybe_fail(2, mon) == []
    assert inj.maybe_fail(3, mon) == ["b"]
    assert mon.check() == ["b"]
