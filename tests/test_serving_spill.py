"""Host-memory spill tier + priority preemption over the paged cache pool:
spill->fetch bit-exactness per cache architecture, preemption/resume greedy
token-identity vs an unpreempted run, oversubscription draining without
leaks, and the pool/scheduler hardening (submit-time validation, real
ValueErrors on release/write misuse)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (assert_tokens_identical, fp_engine,
                      prompt_list as _prompt_list, run_in_devices)

from repro.models import lm
from repro.serving import (CachePool, EngineSpec, GenerationConfig,
                           InferenceEngine, Request, RequestScheduler,
                           SpeculativeConfig, pytree_nbytes)


def _slot_snapshot(pool, sid):
    clen, lane = pool.locate(sid)
    return jax.tree.map(lambda x: np.asarray(x[lane]), pool.get_store(clen))


# -- spill / fetch round trip ------------------------------------------------


def test_spill_fetch_roundtrip_bit_exact(cache_arch):
    """A slot's full cache pytree (KV/rings, recurrent state, RoPE angle
    memory, position) survives the host round trip bit-exactly, and the
    lane is genuinely free while the slot is host-resident."""
    engine = fp_engine(cache_arch)
    pool = CachePool(engine.cfg, classes=[(2, 16)])
    _, cache = engine.prefill(jnp.asarray([_prompt_list(engine, 10)],
                                          jnp.int32), cache_len=16)
    sid = pool.acquire(12)
    pool.write(sid, cache)
    before = _slot_snapshot(pool, sid)

    pool.spill(sid)
    assert pool.residency(sid) == "host"
    assert pool.host_resident == 1 and pool.host_bytes > 0
    assert pool.free_slots == 2                     # the lane is reusable
    with pytest.raises(ValueError, match="not device-resident"):
        pool.write(sid, cache)                      # host slots can't scatter

    pool.fetch(sid)
    assert pool.residency(sid) == "device"
    assert pool.host_resident == 0 and pool.free_slots == 1
    after = _slot_snapshot(pool, sid)
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)

    st = pool.spill_stats
    assert st["spills"] == 1 and st["fetches"] == 1
    assert st["bytes_to_host"] == st["bytes_to_device"] > 0


def test_double_spill_and_fetch_without_lane_raise():
    engine = fp_engine("retnet-1.3b")
    pool = CachePool(engine.cfg, classes=[(1, 8)])
    sid = pool.acquire(4)
    pool.spill(sid)
    with pytest.raises(ValueError, match="already spilled"):
        pool.spill(sid)
    other = pool.acquire(4)                         # takes the only lane
    with pytest.raises(ValueError, match="no free lane"):
        pool.fetch(sid)
    pool.release(other)
    pool.fetch(sid)                                 # lane free again
    assert pool.residency(sid) == "device"


# -- preemption / resume token identity --------------------------------------


def _drain(engine, arch_gen, preempt: bool, *, classes, chunk_size=8,
           p0=None, p1=None):
    """One-lane scheduler drain; with `preempt`, uid 1 arrives mid-decode at
    high priority and bumps uid 0 into the host tier."""
    sched = RequestScheduler(engine, classes=classes, gen=arch_gen,
                             chunk_size=chunk_size, host_spill=preempt)
    sched.submit(Request(uid=0, prompt=p0))
    if preempt:
        while not sched._active:                    # uid 0 resident...
            sched.step()
        sched.step()                                # ...and emitting
        sched.submit(Request(uid=1, prompt=p1), priority=5)
    else:
        sched.submit(Request(uid=1, prompt=p1))
    res = sched.run()
    return {u: r.tokens for u, r in res.items()}, sched


def test_preemption_resume_token_identity(cache_arch):
    """Greedy output with host-spill preemption enabled is token-identical
    to the no-spill run for every cache architecture: the preempted lane's
    cache + sampling key + pending token survive the host round trip."""
    engine = fp_engine(cache_arch)
    gen = GenerationConfig(max_new_tokens=6)
    p0 = _prompt_list(engine, 8, seed=11)
    p1 = _prompt_list(engine, 8, seed=12)
    classes = [(1, 8 + 6)]
    base, base_sched = _drain(engine, gen, False, classes=classes,
                              p0=p0, p1=p1)
    pre, pre_sched = _drain(engine, gen, True, classes=classes, p0=p0, p1=p1)
    assert base_sched.stats["preempted"] == 0
    assert pre_sched.stats["preempted"] >= 1        # it really happened
    assert pre_sched.stats["resumed"] == pre_sched.stats["preempted"]
    assert pre_sched.pool.host_resident == 0        # nothing left parked
    assert pre == base, cache_arch


def test_preemption_resume_identity_speculative():
    """The speculative lane's draft history is part of the preempted state:
    a preempted ngram-drafter run stays token-identical, and its acceptance
    stats keep accumulating across the spill."""
    engine = fp_engine("retnet-1.3b")
    k = 2
    gen = GenerationConfig(max_new_tokens=6,
                           speculative=SpeculativeConfig(k=k))
    p0 = _prompt_list(engine, 8, seed=21)
    p1 = _prompt_list(engine, 8, seed=22)
    classes = [(1, 8 + 6 + k)]
    base, _ = _drain(engine, gen, False, classes=classes, p0=p0, p1=p1)
    pre, sched = _drain(engine, gen, True, classes=classes, p0=p0, p1=p1)
    assert sched.stats["preempted"] >= 1
    assert pre == base


def test_resume_priority_order():
    """Parked requests resume priority-first (tie: oldest admitted)."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=4)
    sched = RequestScheduler(engine, classes=[(1, 12)], gen=gen,
                             chunk_size=8, host_spill=True)
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))           # priority 0
    while not sched._active:
        sched.step()
    sched.submit(Request(uid=1, prompt=[3, 4, 5]), priority=2)
    while not any(st["req"].uid == 1 for st in sched._active.values()):
        sched.step()                                 # uid 1 preempted uid 0
    sched.submit(Request(uid=2, prompt=[4, 5, 6]), priority=9)
    res = sched.run()
    assert len(res) == 3 and sched.stats["preempted"] == 2
    finish_order = [f.uid for f in sched._finished]
    # uid 2 (pri 9) finishes first; uid 1 (pri 2) resumes before uid 0.
    assert finish_order == [2, 1, 0]
    assert all(len(r.tokens) == 4 for r in res.values())


def test_preemption_requires_strictly_lower_priority():
    """Equal-priority arrivals queue instead of thrashing residents."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=4)
    sched = RequestScheduler(engine, classes=[(1, 12)], gen=gen,
                             chunk_size=8, host_spill=True)
    sched.submit(Request(uid=0, prompt=[2, 3, 4], priority=1))
    while not sched._active:
        sched.step()
    sched.submit(Request(uid=1, prompt=[3, 4, 5], priority=1))
    res = sched.run()
    assert sched.stats["preempted"] == 0
    assert len(res) == 2


# -- oversubscription ---------------------------------------------------------


def test_oversubscription_drains_without_leaks():
    """More submitted requests than device lanes: a high-priority burst
    preempts the residents to host, everything completes with its full
    budget, and the pool ends with every lane free and nothing parked."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=4)
    sched = RequestScheduler(engine, classes=[(2, 12)], gen=gen,
                             chunk_size=8, host_spill=True)
    for uid in range(2):
        sched.submit(Request(uid=uid, prompt=_prompt_list(engine, 6,
                                                          seed=uid)))
    while sched.stats["admitted"] < 2:
        sched.step()
    for uid in range(2, 6):                          # burst: 4 over 2 lanes
        sched.submit(Request(uid=uid, prompt=_prompt_list(engine, 6,
                                                          seed=uid)),
                     priority=1)
    res = sched.run()
    assert sorted(res) == list(range(6))
    assert all(len(r.tokens) == 4 for r in res.values())
    assert sched.stats["preempted"] == sched.stats["resumed"] == 2
    assert sched.pool.free_slots == 2                # no lane leaked
    assert sched.pool.host_resident == 0             # no host-tier leak
    st = sched.pool.spill_stats
    assert st["spills"] == st["fetches"] == 2
    assert st["bytes_to_host"] == st["bytes_to_device"]


def test_cancel_preempted_request():
    """cancel() reaches a parked (host-resident) request: its partial output
    comes back cancelled and the host copy is dropped."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=6)
    sched = RequestScheduler(engine, classes=[(1, 12)], gen=gen,
                             chunk_size=8, host_spill=True)
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))
    while not sched._active:
        sched.step()
    sched.step()
    sched.submit(Request(uid=1, prompt=[3, 4, 5]), priority=5)
    while not sched._preempted:
        sched.step()
    assert sched.pool.host_resident == 1
    assert sched.cancel(0)
    assert sched.pool.host_resident == 0
    res = sched.run()
    assert res[0].cancelled and 0 < len(res[0].tokens) < 6
    assert not res[1].cancelled and len(res[1].tokens) == 6


# -- warm-resume engine entry + size accounting -------------------------------


def test_engine_resume_generate_warm_identity():
    """`resume_generate` re-enters the fused loop from (pending token, warm
    cache) with no prefill: same greedy stream, no new prefill shapes."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=6)
    prompts = jnp.asarray([_prompt_list(engine, 9, seed=31)], jnp.int32)
    want = engine.generate(prompts, gen).tokens
    logits, cache = engine.prefill(prompts, cache_len=9 + 6)
    shapes_before = set(engine.prefill_shape_keys)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    got = engine.resume_generate(tok0, cache, gen)
    assert_tokens_identical(got, want)
    assert got.prefill_s == 0.0
    assert engine.prefill_shape_keys == shapes_before


def test_cache_nbytes_matches_concrete_cache():
    engine = fp_engine("retnet-1.3b")
    concrete = lm.make_decode_cache(engine.cfg, 1, 16, jnp.float32)
    assert engine.cache_nbytes(16) == pytree_nbytes(concrete) > 0
    assert engine.cache_nbytes(16, batch=2) > engine.cache_nbytes(16)


# -- hardening: release / write / submit --------------------------------------


def test_release_rejects_double_release_and_unknown_ids():
    engine = fp_engine("retnet-1.3b")
    pool = CachePool(engine.cfg, classes=[(2, 8)])
    sid = pool.acquire(4)
    pool.release(sid)
    with pytest.raises(ValueError, match="double-released"):
        pool.release(sid)
    with pytest.raises(ValueError, match="unknown slot"):
        pool.release(12345)
    assert pool.free_slots == 2


def test_release_of_host_resident_slot_drops_host_copy():
    engine = fp_engine("retnet-1.3b")
    pool = CachePool(engine.cfg, classes=[(1, 8)])
    sid = pool.acquire(4)
    pool.spill(sid)
    pool.release(sid)
    assert pool.host_resident == 0 and pool.free_slots == 1
    with pytest.raises(ValueError, match="double-released"):
        pool.release(sid)


def test_write_validates_cache_class_shape():
    """A cache built for another class (or a malformed pytree) must raise
    instead of silently corrupting the stacked store.  Linear-KV arch: its
    cache leaves actually carry cache_len (RetNet's O(1) state would not)."""
    engine = fp_engine("qwen3-8b")
    pool = CachePool(engine.cfg, classes=[(1, 8), (1, 32)])
    sid = pool.acquire(32)                           # the 32-class slot
    assert pool.slot_len(sid) == 32
    small = lm.make_decode_cache(engine.cfg, 1, 8, jnp.float32)
    with pytest.raises(ValueError, match="shape"):
        pool.write(sid, small)
    with pytest.raises(ValueError, match="structure"):
        pool.write(sid, {"pos": jnp.int32(0)})
    ok = lm.make_decode_cache(engine.cfg, 1, 32, jnp.float32)
    pool.write(sid, ok)                              # matching class: fine


def test_submit_rejects_zero_max_new_tokens():
    """Regression for `budget = req.max_new_tokens or default`: an explicit
    0 used to silently fall back to the scheduler default; it is now
    rejected at the submission boundary."""
    engine = fp_engine("retnet-1.3b")
    sched = RequestScheduler(engine, n_slots=1, cache_len=16,
                             gen=GenerationConfig(max_new_tokens=12))
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        sched.submit(Request(uid=0, prompt=[2, 3], max_new_tokens=0))
    # An explicit small budget is honored (not `or`-clobbered).
    sched.submit(Request(uid=1, prompt=[2, 3], max_new_tokens=1))
    res = sched.run()
    assert len(res[1].tokens) == 1


def test_submit_rejects_never_fitting_request_and_run_never_throws():
    """Capacity is validated at submit(): a never-fitting request raises at
    the submission boundary, so run() can't die mid-drain and abandon
    queued + resident work."""
    engine = fp_engine("retnet-1.3b")
    gen = GenerationConfig(max_new_tokens=4)
    sched = RequestScheduler(engine, n_slots=2, cache_len=16, gen=gen,
                             chunk_size=8)
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))
    free_before = sched.pool.free_slots
    with pytest.raises(ValueError, match="exceeds every pool class"):
        sched.submit(Request(uid=1, prompt=list(range(2, 40))))  # 38+4 > 16
    assert sched.pool.free_slots == free_before      # nothing acquired
    sched.submit(Request(uid=2, prompt=[5, 6, 7]))
    res = sched.run()                                # drains untouched
    assert sorted(res) == [0, 2]
    assert all(len(r.tokens) == 4 for r in res.values())


# -- sharded spill round trip (multi-device subprocess) -----------------------


def test_sharded_spill_roundtrip_restores_shardings():
    """On a 2x2 mesh, `spill` gathers a *sharded* slot pytree to host and
    `fetch` re-places it bit-exactly under the original cache shardings, for
    every cache architecture; per-device byte accounting stays below the
    global footprint.  Subprocess: the virtual-device flag must precede any
    jax init (the main pytest process keeps 1 device)."""
    out = run_in_devices("""
import jax, numpy as np, jax.numpy as jnp
import conftest
from repro.launch.mesh import make_serving_mesh
from repro.runtime import sharding as shd
from repro.serving import CachePool

mesh = make_serving_mesh("2,2")
for arch in conftest.SERVING_ARCHS:
    engine = conftest.fp_engine(arch, mesh=mesh)
    pool = CachePool(engine.cfg, classes=[(2, 16)], mesh=mesh,
                     policy=engine.policy)
    _, cache = engine.prefill(conftest.prompt_ids(engine, 10), cache_len=16)
    sid = pool.acquire(12)
    pool.write(sid, cache)
    clen, lane = pool.locate(sid)
    before = jax.tree.map(lambda x: np.asarray(x[lane]), pool.get_store(clen))

    pool.spill(sid)
    assert pool.residency(sid) == "host" and pool.host_bytes > 0, arch
    pool.fetch(sid)
    clen, lane = pool.locate(sid)
    after = jax.tree.map(lambda x: np.asarray(x[lane]), pool.get_store(clen))
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b, err_msg=arch)   # bit-exact

    bad = shd.sharding_mismatches(pool.get_store(clen),
                                  pool._store_shardings[clen])
    assert not bad, (arch, bad)                    # shardings restored
    assert 0 < pool.device_bytes_per_device < pool.device_bytes, arch
    st = pool.spill_stats
    assert st["bytes_to_host"] == st["bytes_to_device"] > 0, arch
    print("ARCH_OK", arch)
print("SHARDED_SPILL_OK")
""")
    assert "SHARDED_SPILL_OK" in out
    assert out.count("ARCH_OK") == 5
