"""Synthetic data pipeline: determinism, packing, host sharding."""

import numpy as np

from repro.data.pipeline import DataConfig, EOS, SyntheticPipeline


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=64, global_batch=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = SyntheticPipeline(_cfg()).batch(13)
    b = SyntheticPipeline(_cfg()).batch(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    p = SyntheticPipeline(_cfg())
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_labels_are_next_tokens():
    b = SyntheticPipeline(_cfg()).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_tokens_in_vocab_and_eos_present():
    b = SyntheticPipeline(_cfg(mean_doc_len=8)).batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
    assert (b["tokens"] == EOS).any()   # packing separators


def test_host_sharding_disjoint():
    h0 = SyntheticPipeline(_cfg(n_hosts=2, host_id=0)).batch(5)
    h1 = SyntheticPipeline(_cfg(n_hosts=2, host_id=1)).batch(5)
    assert h0["tokens"].shape[0] == 2   # local batch
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_resume_replays_stream():
    p = SyntheticPipeline(_cfg())
    ref = [p.batch(i)["tokens"] for i in range(4)]
    st = p.state(2)
    p2 = SyntheticPipeline(_cfg(seed=st["seed"]))
    np.testing.assert_array_equal(p2.batch(st["step"])["tokens"], ref[2])


def test_markov_structure_learnable():
    """Bigram structure: successor entropy is far below uniform."""
    p = SyntheticPipeline(_cfg(global_batch=8, seq_len=256))
    toks = np.concatenate([p.batch(i)["tokens"].ravel() for i in range(4)])
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        if a != EOS and b != EOS:
            pairs.setdefault(int(a), set()).add(int(b))
    avg_succ = np.mean([len(v) for v in pairs.values()])
    assert avg_succ <= 8  # branching 4 (+ doc boundaries), << vocab 128
