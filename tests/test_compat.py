"""repro.compat: the version-shim surface the compat-api lint rule funnels
every version-sensitive jax spelling through.

These tests pin the public surface (`__all__`) and that each shim produces a
working object on the jax in this image — so removing or breaking a shim is
an API break caught here, not a silent hole that reopens direct use of the
version-sensitive spellings elsewhere in src/repro.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import compat

SHIMS = ["shard_map", "jit_sharded", "tpu_compiler_params", "make_auto_mesh"]


def test_public_surface_pinned():
    assert compat.__all__ == SHIMS
    for name in SHIMS:
        assert callable(getattr(compat, name))


def test_jit_sharded_compiles_and_runs():
    f = compat.jit_sharded(lambda x: x * 2, in_shardings=None,
                           out_shardings=None)
    assert f(jnp.arange(4.0))[2] == 4.0


def test_jit_sharded_forwards_donation():
    f = compat.jit_sharded(lambda x: x + 1, in_shardings=None,
                           out_shardings=None, donate_argnums=(0,))
    x = jnp.arange(4.0)
    y = f(x)
    assert y[0] == 1.0


def test_make_auto_mesh():
    mesh = compat.make_auto_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": 1, "model": 1}


def test_shard_map_runs():
    mesh = compat.make_auto_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    f = compat.shard_map(lambda x: x * 2, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"))
    assert f(jnp.arange(4.0))[1] == 2.0


def test_tpu_compiler_params():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel",))
    assert params is not None
