"""repro.analysis: AST lint rules (Layer 1) + program-audit smoke (Layer 2).

Layer-1 cases run `lint_source` on inline snippets — per rule one violating,
one clean, and one suppressed case — so the rules are pinned independently of
what the live tree happens to contain.  Layer-2 reuses the shared reduced
engine to smoke the donation/transfer/recompile audits and the HLO-text
parsers they stand on.
"""

import json

import pytest

from conftest import fp_engine
from repro.analysis import lint, lint_source, lint_tree, program_audit


def rules_of(src: str, path: str = "serving/x.py") -> list[str]:
    return [f.rule for f in lint_source(src, path)]


# -- R1: compat-api ----------------------------------------------------------

class TestCompatApiRule:
    def test_violating(self):
        src = ("import jax\n"
               "def make(g):\n"
               "    return jax.jit(g, in_shardings=(None,))\n")
        assert rules_of(src) == ["compat-api"]

    def test_violating_renamed_import(self):
        # an import alias must not hide the origin
        src = ("from jax.experimental.shard_map import shard_map as smap\n"
               "y = smap(f, mesh=m, in_specs=(), out_specs=())\n")
        assert "compat-api" in rules_of(src)

    def test_clean_via_compat(self):
        src = ("from repro.compat import jit_sharded\n"
               "f = jit_sharded(g, in_shardings=(None,))\n")
        assert rules_of(src) == []

    def test_clean_plain_jit(self):
        src = ("import jax\n"
               "def make(g):\n"
               "    return jax.jit(g, donate_argnums=(0,))\n")
        assert rules_of(src) == []

    def test_compat_module_exempt(self):
        src = "import jax\nm = jax.make_mesh((1,), ('data',))\n"
        assert rules_of(src, "compat.py") == []
        assert rules_of(src, "launch/train.py") == ["compat-api"]

    def test_suppressed(self):
        src = ("import jax\n"
               "def make(g):\n"
               "    return jax.jit(g, in_shardings=(None,))"
               "  # repro: allow(compat-api)\n")
        assert rules_of(src) == []


# -- R2: bare-assert ---------------------------------------------------------

class TestBareAssertRule:
    def test_violating(self):
        src = "def f(x):\n    assert x > 0\n    return x\n"
        assert rules_of(src, "core/x.py") == ["bare-assert"]

    def test_clean(self):
        src = ("def f(x):\n"
               "    if x <= 0:\n"
               "        raise ValueError(x)\n"
               "    return x\n")
        assert rules_of(src, "core/x.py") == []

    def test_suppressed_prev_line(self):
        src = ("def f(x):\n"
               "    # repro: allow(bare-assert)\n"
               "    assert x > 0\n")
        assert rules_of(src, "core/x.py") == []


# -- R3: host-sync -----------------------------------------------------------

class TestHostSyncRule:
    def test_violating_item(self):
        src = "def step(self, x):\n    return x.item()\n"
        assert rules_of(src, "serving/x.py") == ["host-sync"]

    def test_violating_device_get(self):
        src = ("import jax\n"
               "def step(x):\n    return jax.device_get(x)\n")
        assert rules_of(src, "serving/x.py") == ["host-sync"]

    def test_violating_int_of_indexed(self):
        src = "def f(t, i):\n    return int(t[i, 0])\n"
        assert rules_of(src, "serving/x.py") == ["host-sync"]

    def test_clean_int_of_python_math(self):
        # host-side python arithmetic is not a device sync
        src = "def f(n, k):\n    return int(n * k / 2)\n"
        assert rules_of(src, "serving/x.py") == []

    def test_scoped_to_hot_packages(self):
        src = "def f(x):\n    return x.item()\n"
        assert rules_of(src, "runtime/x.py") == []

    def test_allowlisted_drain_site(self):
        # the scheduler's batched post-step drain is the sanctioned sync point
        src = ("class RequestScheduler:\n"
               "    def step(self):\n"
               "        return int(self._tokens[0][0, 0, 0])\n")
        assert rules_of(src, "serving/scheduler.py") == []

    def test_suppressed(self):
        src = ("def step(x):\n"
               "    return x.item()  # repro: allow(host-sync)\n")
        assert rules_of(src, "serving/x.py") == []

    def test_tracer_flush_is_the_only_obs_sync_site(self):
        # obs/ is in the rule's scope; only Tracer.flush may gather.
        src = ("import jax\n"
               "class Tracer:\n"
               "    def flush(self):\n"
               "        return jax.device_get({})\n"
               "    def begin(self):\n"
               "        return jax.device_get({})\n")
        assert rules_of(src, "obs/trace.py") == ["host-sync"]
        assert rules_of(src, "obs/other.py") == ["host-sync", "host-sync"]


# -- R4: module-scope-compute ------------------------------------------------

class TestModuleScopeComputeRule:
    def test_violating(self):
        src = "import jax.numpy as jnp\nTABLE = jnp.arange(1024)\n"
        assert rules_of(src, "models/x.py") == ["module-scope-compute"]

    def test_clean_inside_function(self):
        src = ("import jax.numpy as jnp\n"
               "def table():\n    return jnp.arange(1024)\n")
        assert rules_of(src, "models/x.py") == []

    def test_clean_numpy_constant(self):
        src = "import numpy as np\nTABLE = np.arange(1024)\n"
        assert rules_of(src, "models/x.py") == []

    def test_suppressed(self):
        src = ("import jax.numpy as jnp\n"
               "T = jnp.arange(4)  # repro: allow(module-scope-compute)\n")
        assert rules_of(src, "models/x.py") == []


# -- driver: tree walk, baseline, live tree ----------------------------------

class TestLintDriver:
    def test_live_tree_is_clean(self):
        # THE invariant this PR establishes: empty baseline, zero findings.
        report = lint_tree(lint.default_root(),
                           lint.load_baseline(lint.default_baseline_path()))
        assert report.new == [], report.render(verbose=True)

    def test_baseline_grandfathers_and_goes_stale(self, tmp_path):
        root = tmp_path / "pkg"
        (root / "core").mkdir(parents=True)
        bad = root / "core" / "x.py"
        bad.write_text("def f(x):\n    assert x\n")
        report = lint_tree(str(root), [])
        assert [f.rule for f in report.new] == ["bare-assert"]

        bl = tmp_path / "baseline.json"
        lint.save_baseline(str(bl), report.new)
        report2 = lint_tree(str(root), lint.load_baseline(str(bl)))
        assert report2.new == [] and len(report2.grandfathered) == 1

        bad.write_text("def f(x):\n    return x\n")   # fixed -> entry stale
        report3 = lint_tree(str(root), lint.load_baseline(str(bl)))
        assert report3.new == [] and len(report3.stale_baseline) == 1

    def test_baseline_round_trip(self, tmp_path):
        bl = tmp_path / "b.json"
        findings = lint_source("def f(x):\n    assert x\n", "core/x.py")
        lint.save_baseline(str(bl), findings)
        entries = lint.load_baseline(str(bl))
        assert json.load(open(bl)) and entries[0][0] == "bare-assert"


# -- Layer 2: HLO parsers + program-audit smoke ------------------------------

class TestProgramAudit:
    def test_parse_io_aliases(self):
        text = ('HloModule m, input_output_alias={ {1}: (13, {}, may-alias),'
                ' {2}: (14, {}, may-alias) }, entry_computation_layout='
                '{(f32[2,8]{1,0}, s32[])->(f32[2,8]{1,0})}')
        assert program_audit.parse_io_aliases(text) == [((1,), 13),
                                                        ((2,), 14)]

    def test_entry_param_bytes(self):
        text = ('entry_computation_layout={(f32[2,8]{1,0}, s32[], '
                'bf16[4]{0})->(f32[2,8]{1,0})}')
        assert program_audit.entry_param_bytes(text) == [64, 4, 8]

    def test_hlo_opcode_scan(self):
        text = ('  %r = f32[8]{0} copy-start(f32[8]{0} %x)\n'
                '  %c = f32[] custom_call(), custom_call_target='
                '"xla_ffi_python_cpu_callback"\n')
        ops, calls = program_audit._scan_transfers(text)
        assert "copy-start" in ops and calls

    def test_donation_audit_smoke(self):
        res = program_audit.audit_donation(
            engine=fp_engine("retnet-1.3b"), chunk=8, cache_len=32)
        assert res.ok, res.detail
        assert res.metrics["fraction"] >= 0.9

    def test_transfer_audit_smoke(self):
        res = program_audit.audit_transfers(
            engine=fp_engine("retnet-1.3b"), max_new_tokens=4, spec_k=2)
        assert res.ok, res.detail

    def test_recompile_audit_smoke(self):
        res = program_audit.audit_recompiles(max_len=9, chunk_size=4)
        assert res.ok, res.detail
        assert res.metrics["prefill_signatures"] <= res.metrics["bucket_bound"]

    def test_observability_audit_smoke(self):
        res = program_audit.audit_observability(max_new_tokens=4, spec_k=2)
        assert res.ok, res.detail
        assert res.metrics["diffs"] == []

    def test_report_render_and_dict(self):
        r = program_audit.AuditResult("x", True, "fine", {})
        rep = program_audit.AuditReport([r])
        assert rep.ok and "PASS" in rep.render()
        assert rep.to_dict()["results"][0]["name"] == "x"
