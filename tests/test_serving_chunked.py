"""Chunked + bucketed prefill over the paged cache pool (the MMM admission
path): token identity vs. monolithic prefill per cache architecture, the
compile ladder, sequencer overlap, paged classes, streaming and cancel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (CHUNKED_ARCHS as ARCHS, assert_tokens_identical,
                      fp_engine, greedy_continue, prompt_ids as _prompt)

from repro.serving import (CachePool, EngineSpec, GenerationConfig,
                           InferenceEngine, Request, RequestScheduler,
                           bucket_length, chunk_schedule)


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_token_identity(arch):
    """Chunk-N must continue chunk-N-1's cache and positions exactly: greedy
    decode after a chunked prefill (uneven ladder: 11 = 4+4+2+1) equals the
    monolithic path, for every cache kind."""
    engine = fp_engine(arch)
    n, s = 6, 11
    prompts = _prompt(engine, s)
    lg_m, cache_m = engine.prefill(prompts, cache_len=s + n)
    lg_c, cache_c = engine.prefill_chunked(prompts, cache_len=s + n,
                                           chunk_size=4)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_c),
                               rtol=2e-4, atol=2e-4)
    assert_tokens_identical(greedy_continue(engine, lg_c, cache_c, n),
                            greedy_continue(engine, lg_m, cache_m, n), arch)


@pytest.mark.parametrize("arch", ARCHS)
def test_bucketed_prefill_token_identity(arch):
    """Pad-and-mask bucketing: logits come from the real last token, and the
    recurrent/conv/ring cache seeds ignore the padded tail (RetNet state is
    decay-corrected, Mamba dt is zeroed, rings gather real positions only)."""
    engine = fp_engine(arch)
    n, s = 6, 11
    prompts = _prompt(engine, s, seed=2)
    lg_m, cache_m = engine.prefill(prompts, cache_len=s + n)
    lg_b, cache_b = engine.prefill(prompts, cache_len=s + n, bucket=True)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_b),
                               rtol=2e-4, atol=2e-4)
    assert_tokens_identical(greedy_continue(engine, lg_b, cache_b, n),
                            greedy_continue(engine, lg_m, cache_m, n), arch)


def test_hybrid_full_attention_exact_to_window_boundary():
    """Hybrid full-attention layers are ring-bounded during chunked
    admission (the same degradation decode applies; reduced hymba marks
    every layer full-attn).  Pin the contract edge: identity holds up to
    prompt == window exactly."""
    engine = fp_engine("hymba-1.5b")
    w = engine.cfg.sliding_window
    n = 4
    prompts = _prompt(engine, w, seed=3)
    lg_m, cache_m = engine.prefill(prompts, cache_len=w + n)
    lg_c, cache_c = engine.prefill_chunked(prompts, cache_len=w + n,
                                           chunk_size=8)
    assert_tokens_identical(greedy_continue(engine, lg_c, cache_c, n),
                            greedy_continue(engine, lg_m, cache_m, n))


def test_windowed_ring_chunked_beyond_window():
    """Sliding-window ring for prompts LONGER than the window: chunk outputs
    must match one monolithic windowed pass (the chunk's earliest queries
    still window back over keys its own writes evict — regression test for
    attend-before-evict), and the final ring must equal the monolithic seed.

    Layer-level because reduced hymba marks every layer full-attention
    (first/middle/last of 2), which legitimately degrades to the ring for
    prompts > window — this pins the *windowed* path exactly.
    """
    from repro import configs
    from repro.core import online_rope as orp
    from repro.core.hsa import HSAConfig, HSAEngine
    from repro.models import layers as L
    from repro.models.lm import _seed_attn_cache
    from repro.models.modules import ParamBuilder
    from repro.serving import chunk_schedule

    cfg = configs.get_config("hymba-1.5b").reduced()
    w = cfg.sliding_window
    s, chunk_size = 48, 8
    assert s > w
    b = ParamBuilder(key=jax.random.key(0), dtype=jnp.float32)
    L.gqa_init(b.child("attn"), cfg)
    p = b.params["attn"]
    eng = HSAEngine(HSAConfig(prefill_format="fp", decode_format="fp"))
    th = orp.rope_thetas(cfg.head_dim_, cfg.rope_base)
    sin, cos = orp.rope_table(jnp.arange(s), th)
    x = jax.random.normal(jax.random.key(1), (1, s, cfg.d_model)) * 0.2

    mono, (k, v) = L.gqa_apply(p, x, None, eng, "prefill", cfg, causal=True,
                               window=w, rope_sin=sin, rope_cos=cos)
    cache = jax.tree.map(jnp.zeros_like, L.gqa_make_cache(cfg, 1, s,
                                                          jnp.float32))
    outs, pos = [], 0
    for c in chunk_schedule(s, chunk_size):
        o, cache = L.gqa_chunk(p, x[:, pos:pos + c], None, eng, cfg, cache,
                               jnp.int32(pos), window=w,
                               rope_sin=sin[pos:pos + c],
                               rope_cos=cos[pos:pos + c])
        outs.append(o)
        pos += c
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, axis=1)),
                               np.asarray(mono), rtol=1e-5, atol=1e-5)
    ring = _seed_attn_cache(cfg, k, v, s)
    np.testing.assert_array_equal(np.asarray(ring["k"]),
                                  np.asarray(cache["k"]))


def test_chunked_prefill_matches_generate_quantized():
    """End-to-end on the paper's deployed formats (W8A8 prefill): the
    scheduler's whole chunked admission path reproduces engine.generate."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=6)
    prompts = _prompt(engine, 11)
    want = engine.generate(prompts, gen).tokens[0].tolist()
    lg, cache = engine.prefill_chunked(prompts, cache_len=11 + 6,
                                       chunk_size=4)
    assert_tokens_identical(greedy_continue(engine, lg, cache, 6), want)


def test_bucket_and_chunk_ladders():
    assert [bucket_length(s) for s in (1, 8, 9, 33, 750)] == [8, 8, 16, 64,
                                                              1024]
    assert chunk_schedule(750, 64) == [64] * 11 + [32, 8, 4, 2]
    assert chunk_schedule(5, 32) == [4, 1]
    assert chunk_schedule(32, 32) == [32]
    assert sum(chunk_schedule(1023, 64)) == 1023


def test_admitting_k_lengths_compiles_log_not_k():
    """K distinct prompt lengths through the scheduler must hit the chunk
    ladder (<= log2-ish shapes), not one prefill compile per length."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=2)
    sched = RequestScheduler(engine, n_slots=2, cache_len=64, gen=gen,
                             chunk_size=16)
    lengths = [7, 11, 19, 26, 33, 41, 50, 57]          # K = 8 distinct
    for uid, s in enumerate(lengths):
        sched.submit(Request(uid=uid, prompt=list(range(2, 2 + s))))
    sched.run()
    chunk_keys = {k for k in engine.prefill_shape_keys if k[0] == "chunk"}
    # ladder: chunks are 16 or the binary decomposition of remainders
    assert {k[1] for k in chunk_keys} <= {16, 8, 4, 2, 1}
    assert len(chunk_keys) <= 5 < len(lengths)


def test_long_admission_overlaps_resident_decode():
    """The LISO property: while a long prompt is chunk-admitted, resident
    decode lanes keep emitting every cycle (no more than one chunk of MMM
    work per step())."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=8)
    sched = RequestScheduler(engine, n_slots=2, cache_len=48, gen=gen,
                             chunk_size=4)
    # lane 0 gets a larger budget so it stays resident through the admission
    sched.submit(Request(uid=0, prompt=[3, 4, 5, 6], max_new_tokens=16))
    while not sched._active:                       # admit the short request
        sched.step()

    long_prompt = list(range(2, 26))               # 24 tokens -> 6 chunks
    sched.submit(Request(uid=1, prompt=long_prompt))
    emitted_during = 0
    admit_steps = 0
    while sched.stats["admitted"] < 2:
        before = len(sched._active[next(iter(sched._active))]["emitted"])
        sched.step()
        admit_steps += 1
        after_active = [s for s in sched._active.values()
                        if s["req"].uid == 0]
        if after_active:
            emitted_during += len(after_active[0]["emitted"]) - before
    assert admit_steps >= 6                        # one chunk per cycle
    assert emitted_during >= admit_steps - 1       # lane 0 never starved

    res = sched.run()
    want = engine.generate(jnp.asarray([long_prompt], jnp.int32), gen)
    assert_tokens_identical(res[1].tokens, want.tokens[0])


def test_paged_pool_classes_and_admission_fit():
    """Short requests land in the small class (stop paying long-request
    memory); long ones go large; admission picks by prompt + budget."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=4)
    sched = RequestScheduler(engine, classes=[(2, 12), (1, 48)], gen=gen,
                             chunk_size=8)
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))           # 3+4 -> class 12
    sched.submit(Request(uid=1, prompt=list(range(2, 32))))  # 30+4 -> class 48
    res = sched.run()
    assert res[0].cache_len == 12
    assert res[1].cache_len == 48
    # the small class's KV leaves really are smaller
    pool = sched.pool
    k_small = jax.tree_util.tree_leaves(pool.get_store(12))[0]
    k_large = jax.tree_util.tree_leaves(pool.get_store(48))[0]
    assert k_small.shape[0] == 2 and k_large.shape[0] == 1


def test_admission_validation_before_acquire_no_slot_leak():
    """A request that can never fit raises at submit() — before any
    pool.acquire, leaking nothing; the drain loop itself never throws and
    later requests still run."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=4)
    sched = RequestScheduler(engine, n_slots=2, cache_len=16, gen=gen,
                             chunk_size=8)
    free_before = sched.pool.free_slots
    with pytest.raises(ValueError, match="exceeds every pool class"):
        sched.submit(Request(uid=0, prompt=list(range(2, 40))))  # 38+4 > 16
    assert sched.pool.free_slots == free_before              # no leak
    sched.submit(Request(uid=1, prompt=[2, 3, 4]))
    res = sched.run()
    assert len(res[1].tokens) == 4


def test_streaming_callback_and_cancel():
    """on_token streams every emitted token in order; cancel() drops queued
    requests, aborts in-flight admissions, and retires active slots."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=6)
    streamed = []
    sched = RequestScheduler(engine, n_slots=2, cache_len=16, gen=gen,
                             chunk_size=8,
                             on_token=lambda uid, tok: streamed.append((uid, tok)))
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))
    sched.submit(Request(uid=1, prompt=[5, 6, 7]))
    sched.submit(Request(uid=2, prompt=[8, 9, 10]))

    for _ in range(3):
        sched.step()
    assert sched.cancel(0)                    # active -> retired, slot freed
    assert sched.cancel(2)                    # still queued -> dropped
    assert not sched.cancel(99)               # unknown uid
    res = sched.run()

    assert res[0].cancelled and len(res[0].tokens) < 6
    assert 2 not in res                       # never ran
    assert not res[1].cancelled and len(res[1].tokens) == 6
    assert [t for u, t in streamed if u == 1] == res[1].tokens
    assert [t for u, t in streamed if u == 0] == res[0].tokens


def test_cancel_from_on_token_callback():
    """cancel() issued from inside the streaming callback (client disconnect
    / first-response-wins) must not corrupt the retire loop — whether it
    targets the emitting request or another resident one."""
    engine = InferenceEngine.from_config("retnet-1.3b",
                                         EngineSpec(reduced=True))
    gen = GenerationConfig(max_new_tokens=6)
    sched = RequestScheduler(engine, n_slots=2, cache_len=16, gen=gen,
                             chunk_size=8)
    sched.submit(Request(uid=0, prompt=[2, 3, 4]))
    sched.submit(Request(uid=1, prompt=[5, 6, 7]))
    while sched.stats["admitted"] < 2:     # both lanes resident first
        sched.step()
    counts: dict[int, int] = {}

    def cb(uid, tok):
        counts[uid] = counts.get(uid, 0) + 1
        if counts[uid] == 2:
            sched.cancel(uid)          # self-cancel mid-loop
            sched.cancel(1 - uid)      # cancel the *other* resident lane
    sched.on_token = cb
    res = sched.run()
    assert res[0].cancelled and res[1].cancelled
    assert all(len(r.tokens) < gen.max_new_tokens for r in res.values())


def test_cache_pool_paged_accounting():
    from repro import configs
    cfg = configs.get_config("retnet-1.3b").reduced()
    pool = CachePool(cfg, classes=[(2, 8), (1, 32)])
    assert pool.n_slots == 3 and pool.free_slots == 3
    assert pool.cache_len == 32                       # compat: largest class
    a = pool.acquire(6)                               # smallest fitting: 8
    assert pool.slot_len(a) == 8
    b = pool.acquire(20)                              # must take the 32 class
    assert pool.slot_len(b) == 32
    c = pool.acquire(6)
    assert pool.slot_len(c) == 8
    assert pool.acquire(6) is None and pool.free_slots == 0
    assert not pool.fits(64) and pool.fits(32)
    pool.release(b)
    d = pool.acquire(2)                # small classes full: reuses b's lane
    assert d is not None and pool.slot_len(d) == 32
    assert pool.free_slots == 0
