"""SmoothQuant invariance and outlier-migration properties (Section III)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.core import fused_rmsnorm as fr
from repro.core import mxint4 as mx
from repro.core import smoothquant as sq

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(seed=st.integers(0, 2**31 - 1),
       alpha=st.sampled_from([0.3, 0.5, 0.8]))
def test_smoothing_is_exact_rewrite(seed, alpha):
    """rmsnorm(x; gamma') @ W' == rmsnorm(x; gamma) @ W exactly in f32."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(9, 32)).astype(np.float32))
    gamma = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    g2, w2, s = sq.smooth_linear_pair(gamma, w, sq.collect_act_absmax(x),
                                      alpha=alpha)
    a = fr.rmsnorm(x, gamma) @ w
    b = fr.rmsnorm(x, g2) @ w2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_outlier_channel_quantizes_better_after_smoothing():
    """The SmoothQuant effect: activation outliers migrate into weights so
    INT8 activation quantization error drops."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 32)).astype(np.float32)
    x[:, 3] *= 50.0                     # classic outlier channel
    x = jnp.asarray(x)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    gamma = jnp.ones((32,), jnp.float32)

    def int8_err(xx):
        xq, s = mx.quantize_act_int8(xx)
        return float(jnp.mean((xx - xq.astype(jnp.float32) * s) ** 2)
                     / jnp.mean(xx ** 2))

    g2, w2, s = sq.smooth_linear_pair(gamma, w, sq.collect_act_absmax(x),
                                      alpha=0.8)   # strong migration
    x_smooth = x / s[None, :]
    assert int8_err(x_smooth) < 0.5 * int8_err(x)


@given(seed=st.integers(0, 2**31 - 1))
def test_scales_positive_unit_geomean(seed):
    rng = np.random.default_rng(seed)
    stats = sq.CalibStats(
        act_absmax=jnp.asarray(np.abs(rng.normal(size=32)) + 0.1,
                               jnp.float32),
        weight_absmax=jnp.asarray(np.abs(rng.normal(size=32)) + 0.1,
                                  jnp.float32))
    s = sq.smoothing_scales(stats)
    assert bool(jnp.all(s > 0))
    np.testing.assert_allclose(float(jnp.exp(jnp.mean(jnp.log(s)))), 1.0,
                               rtol=1e-4)


def test_running_max_merge():
    a = jnp.asarray([1.0, 5.0, 2.0])
    b = jnp.asarray([3.0, 1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(sq.merge_absmax(a, b)),
                                  [3.0, 5.0, 2.0])
