"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED family-preserving config and runs one
forward/train step on CPU, asserting output shapes and finiteness.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.hsa import HSAConfig, HSAEngine
from repro.models import frontends, lm

ENGINE = HSAEngine(HSAConfig())


def _batch(cfg, B=2, S=32, seed=0):
    toks = jax.random.randint(jax.random.key(seed), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = frontends.synth_patch_embeds(cfg, B)
    if cfg.is_encdec:
        batch["src_embeds"] = frontends.synth_frame_embeds(cfg, B, 16)
    return batch


@pytest.mark.parametrize("arch", configs.ASSIGNED + ("retnet-1.3b",))
def test_reduced_forward_and_train_step(arch):
    cfg = configs.get_config(arch).reduced()
    params, axes, paths = lm.init(cfg, jax.random.key(0))
    batch = _batch(cfg)

    loss, metrics = lm.forward_train(params, batch, cfg, ENGINE)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    logits, cache = lm.forward_prefill(params, batch, cfg, ENGINE,
                                       cache_len=36)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = lm.forward_decode(params, tok, cache, cfg, ENGINE)
    assert logits2.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch
    assert int(cache["pos"]) == 33


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_one_sgd_step_reduces_loss_direction(arch):
    """Gradient sanity: a small step along -grad reduces the loss."""
    cfg = configs.get_config(arch).reduced()
    params, _, _ = lm.init(cfg, jax.random.key(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return lm.forward_train(p, batch, cfg, ENGINE)[0]

    l0, g = jax.value_and_grad(loss_fn)(params)
    p2 = jax.tree.map(lambda p, gg: p - 1e-2 * gg, params, g)
    l1 = loss_fn(p2)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


def test_cell_support_matrix():
    """long_500k runs only for sub-quadratic archs; the skip list is exactly
    the full-attention ones (DESIGN.md §4)."""
    from repro.models.config import LONG_500K
    runnable = {a for a in configs.ASSIGNED
                if configs.cell_supported(configs.get_config(a), LONG_500K)[0]}
    assert runnable == {"hymba-1.5b", "falcon-mamba-7b"}


def test_input_specs_shapes():
    from repro.models.config import TRAIN_4K, DECODE_32K
    cfg = configs.get_config("qwen3-8b")
    sp = configs.input_specs(cfg, TRAIN_4K)
    assert sp["tokens"].shape == (256, 4096)
    assert sp["labels"].shape == (256, 4096)
    sp = configs.input_specs(cfg, DECODE_32K)
    assert sp["tokens"].shape == (128, 1)

    vlm = configs.get_config("llava-next-34b")
    sp = configs.input_specs(vlm, TRAIN_4K)
    assert sp["patch_embeds"].shape == (256, 2880, 7168)

    ed = configs.get_config("seamless-m4t-medium")
    sp = configs.input_specs(ed, TRAIN_4K)
    assert sp["src_embeds"].shape == (256, 4096, 1024)
