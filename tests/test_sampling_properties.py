"""Property tests for `serving.sampling.sample`: greedy == argmax, top-k
stays inside the top k, top-p keeps at least p cumulative mass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.serving.sampling import SamplingParams, sample

SETTINGS = dict(max_examples=40, deadline=None)


def _logits(draw_vals):
    return jnp.asarray(draw_vals, jnp.float32)[None, :]   # [1, V]


logits_strategy = st.lists(
    st.floats(min_value=-10.0, max_value=10.0,
              allow_nan=False, allow_infinity=False, width=32),
    min_size=2, max_size=32)


@settings(**SETTINGS)
@given(vals=logits_strategy)
def test_greedy_is_argmax(vals):
    logits = _logits(vals)
    got = sample(logits, SamplingParams(), jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.argmax(np.asarray(logits), axis=-1))


@settings(**SETTINGS)
@given(vals=logits_strategy, k=st.integers(min_value=1, max_value=8),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_top_k_never_samples_outside_top_k(vals, k, seed):
    logits = _logits(vals)
    v = logits.shape[-1]
    k = min(k, v)
    tok = int(sample(logits, SamplingParams(temperature=1.0, top_k=k),
                     jax.random.key(seed))[0])
    kth = np.sort(np.asarray(logits)[0])[-k]
    # The sampled logit must be >= the k-th largest (ties may widen the set).
    assert np.asarray(logits)[0, tok] >= kth


@settings(**SETTINGS)
@given(vals=logits_strategy,
       p=st.floats(min_value=0.05, max_value=0.999, width=32),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_top_p_keeps_cumulative_mass_at_least_p(vals, p, seed):
    """The nucleus (every token top-p can sample) must carry >= p mass, and
    the sampled token must be inside it."""
    logits = _logits(vals)
    params = SamplingParams(temperature=1.0, top_p=p)
    tok = int(sample(logits, params, jax.random.key(seed))[0])

    probs = np.asarray(jax.nn.softmax(logits, axis=-1))[0]
    order = np.argsort(-probs, kind="stable")
    cum = np.cumsum(probs[order])
    # Smallest prefix of the sorted distribution reaching p (crossing token
    # included) — the filter keeps every logit >= the prefix's smallest.
    n_keep = int(np.searchsorted(cum, p * (1 - 1e-6)) + 1)
    thresh = np.asarray(logits)[0, order[n_keep - 1]]
    kept = np.asarray(logits)[0] >= thresh
    assert float(probs[kept].sum()) >= min(p, float(cum[-1])) - 1e-5
    assert kept[tok]
