"""Property tests for the MXINT4 quantization core (paper Section III, Eq. 1)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip without it
from hypothesis import given, settings, strategies as st

from repro.core import mxint4 as mx

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand_w(seed, k, n, scale=0.1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * scale)


@given(seed=st.integers(0, 2**31 - 1),
       k=st.integers(1, 24),
       ng=st.integers(1, 6),
       scale=st.sampled_from([1e-4, 1e-2, 0.1, 1.0, 10.0]))
def test_error_bound(seed, k, ng, scale):
    """|w - dq(q(w))| <= 2^(S_g - 2) — one mantissa scale unit (Eq. 1)."""
    n = ng * 2 * mx.GROUP_SIZE
    w = _rand_w(seed, k, n, scale)
    q = mx.quantize_mxint4(w)
    err = jnp.abs(w - mx.dequantize_mxint4(q, jnp.float32))
    bound = mx.mxint4_error_bound(q.exps)
    assert bool(jnp.all(err <= bound + 1e-7))


@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-9, 1e-4, 1.0, 30.0]))
def test_exponent_clamp_range(seed, scale):
    w = _rand_w(seed, 4, 64, scale)
    q = mx.quantize_mxint4(w)
    assert int(q.exps.min()) >= mx.SHIFT_MIN
    assert int(q.exps.max()) <= mx.SHIFT_MAX


@given(seed=st.integers(0, 2**31 - 1))
def test_pack_roundtrip_int4(seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.integers(-8, 8, size=(6, 32)), jnp.int8)
    assert (mx.unpack_int4(mx.pack_int4(m)) == m).all()


@given(seed=st.integers(0, 2**31 - 1))
def test_pack_roundtrip_uint4(seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.integers(0, 15, size=(3, 8)), jnp.uint8)
    assert (mx.unpack_uint4(mx.pack_uint4(c)) == c).all()


def test_streamed_bits_exactly_4_25():
    """The paper's EMA headline: 4 + 4/16 = 4.25 bits/weight on the wire."""
    w = _rand_w(0, 64, 128)
    q = mx.quantize_mxint4(w)
    assert q.nbytes_streamed() * 8 / w.size == 4.25


def test_dequant_exact_in_bf16():
    """m * 2^(S-2) is exactly representable in bf16 for the full code range."""
    mants = jnp.arange(-8, 8, dtype=jnp.int8)
    for s in range(mx.SHIFT_MIN, mx.SHIFT_MAX + 1):
        vals32 = mants.astype(jnp.float32) * 2.0 ** (s - mx.MANT_SHIFT)
        vals16 = vals32.astype(jnp.bfloat16).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(vals32), np.asarray(vals16))


def test_zero_weights_quantize_to_zero():
    w = jnp.zeros((4, 32), jnp.float32)
    q = mx.quantize_mxint4(w)
    assert float(jnp.abs(mx.dequantize_mxint4(q, jnp.float32)).max()) == 0.0


@given(seed=st.integers(0, 2**31 - 1))
def test_int8_tensor_roundtrip(seed):
    w = _rand_w(seed, 8, 32)
    q8 = mx.quantize_int8_tensor(w)
    err = jnp.abs(w - mx.dequantize_int8(q8, jnp.float32))
    assert float(err.max()) <= float(q8.scale) / 2 + 1e-7


def test_quality_ordering_mxint4_vs_naive_int4():
    """Table III's story: group-wise MXINT4 beats per-tensor INT4 by a wide
    margin on realistic (outlier-bearing) weights."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(128, 256)).astype(np.float32) * 0.02
    w[7, 33] = 2.0  # outlier channel, the LLM failure mode
    w = jnp.asarray(w)
    q4 = mx.quantize_mxint4(w)
    mse_mx = float(jnp.mean((w - mx.dequantize_mxint4(q4, jnp.float32)) ** 2))
    mant, scale = mx.quantize_int4_naive(w)
    mse_naive = float(jnp.mean((w - mx.dequantize_int4_naive(mant, scale)) ** 2))
    assert mse_mx * 20 < mse_naive


def test_mxint4_close_to_fp16_scale_quality():
    """4-bit shift scaling should be within ~2x MSE of FP16 group scaling
    (the paper: 'preserving minimal performance drop' vs 10-16x HW cost)."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32) * 0.05)
    q4 = mx.quantize_mxint4(w)
    mse_mx = float(jnp.mean((w - mx.dequantize_mxint4(q4, jnp.float32)) ** 2))
    mant, scale = mx.quantize_int4_fp16_scale(w)
    mse_fp16 = float(jnp.mean((w - mx.dequantize_int4_fp16_scale(mant, scale)) ** 2))
    assert mse_mx < 2.0 * mse_fp16


def test_act_quant_dynamic():
    x = _rand_w(3, 4, 32, scale=3.0)
    xq, s = mx.quantize_act_int8(x)
    err = jnp.abs(x - xq.astype(jnp.float32) * s)
    assert float(err.max()) <= float(s) / 2 + 1e-6
