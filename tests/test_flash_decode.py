"""Flash-decode kernel + quantized KV residency: the decode-step tentpole.

Two layers of coverage (the online-softmax property tests live in
`test_flash_decode_properties.py` — hypothesis is optional):

  * kernel level — the Pallas split-KV kernel (interpret mode) against the
    `layers.attend_one_step` oracle for every cache encoding (fp32,
    legacy int8, int8_tok, mxint4_blk), the MLA two-stream layout, and
    non-dividing ``block_c``;
  * engine level — greedy token-identity of ``kernel_impl='pallas'``
    (interpret on CPU) vs the ref path across the serving cache archs and
    the admission paths (plain, chunked, speculative, spill/resume), with
    and without a quantized cache; plus the `CacheCapacityError` admission
    guard and the byte-aware spill victim policy.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (assert_tokens_identical, fp_engine,
                      greedy_continue, prompt_ids, prompt_list)

from repro.core import kvq
from repro.kernels import ops as kops
from repro.kernels.flash_decode import flash_decode_pallas
from repro.models import layers as L
from repro.serving import (CacheCapacityError, EngineSpec, GenerationConfig,
                           InferenceEngine, Request, RequestScheduler,
                           SpeculativeConfig)

_PALLAS: dict = {}


def pallas_engine(arch: str) -> InferenceEngine:
    """Reduced fp engine forced onto the Pallas kernel path (interpret mode
    on CPU), sharing the ref engine's weights so only `impl` differs."""
    if arch not in _PALLAS:
        _PALLAS[arch] = InferenceEngine.from_config(
            arch, EngineSpec(reduced=True, quantize=False,
                             kernel_impl="pallas"),
            params=fp_engine(arch).params)
    return _PALLAS[arch]


def _gqa_case(seed=0, b=2, kv=2, g=3, d=32, c=24, kv_len=17):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, kv, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, c, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, c, kv, d), jnp.float32)
    return q, k, v, jnp.int32(kv_len)


def _encode(x, fmt):
    if fmt == "fp":
        return x
    if fmt == "legacy_int8":
        return jnp.clip(jnp.round(x * kvq.KV8_SCALE), -127, 127
                        ).astype(jnp.int8)
    return kvq.encode(x, fmt)


# -- kernel vs the attend_one_step oracle ------------------------------------


@pytest.mark.parametrize("fmt", ["fp", "legacy_int8", "int8_tok",
                                 "mxint4_blk"])
def test_kernel_matches_attend_one_step(fmt):
    """Interpret-mode kernel == the engine's decode-attention oracle on the
    *same* (possibly lossily encoded) cache bytes, for every format."""
    q, k, v, kv_len = _gqa_case()
    ke, ve = _encode(k, fmt), _encode(v, fmt)
    got = flash_decode_pallas(q, ke, ve, kv_len, interpret=True)
    valid = jnp.arange(k.shape[1])[None, :] < kv_len
    want = L.attend_one_step(q, ke, ve, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(got.argmax(-1)),
                                  np.asarray(want.argmax(-1)))


@pytest.mark.parametrize("block_c", [5, 16, 24, 64])
def test_kernel_block_c_invariance(block_c):
    """Any block size — including non-dividing and larger-than-C — gives the
    same output; the split only changes the combine order."""
    q, k, v, kv_len = _gqa_case(seed=3)
    want = kops.flash_decode(q, k, v, kv_len, impl="ref")
    got = flash_decode_pallas(q, k, v, kv_len, block_c=block_c,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("fmt", ["fp", "int8_tok"])
def test_kernel_mla_two_stream(fmt):
    """MLA layout: latent-space attention with the shared rope key as a
    second score stream, through the ops wrapper's singleton-kv plumbing."""
    b, h, r, dr, c = 2, 4, 32, 16, 20
    ks = jax.random.split(jax.random.key(5), 4)
    qa = jax.random.normal(ks[0], (b, h, r), jnp.float32)
    qr = jax.random.normal(ks[1], (b, h, dr), jnp.float32)
    ckv = jax.random.normal(ks[2], (b, c, r), jnp.float32)
    krope = jax.random.normal(ks[3], (b, c, dr), jnp.float32)
    kv_len = jnp.int32(13)
    scale = 1.0 / np.sqrt(r + dr)
    ckv_e = _encode(ckv, fmt)
    want = kops.flash_decode(qa, ckv_e, ckv_e, kv_len, q2=qr, k2=krope,
                             scale=scale, impl="ref")
    got = kops.flash_decode(qa, ckv_e, ckv_e, kv_len, q2=qr, k2=krope,
                            scale=scale, impl="pallas", block_c=7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_kernel_kv_len_zero_and_full():
    """Edge lengths: kv_len == C streams every row; the kernel must also not
    NaN when only one row is valid (the first decode step after prefill=1)."""
    q, k, v, _ = _gqa_case(seed=7)
    for n in (1, k.shape[1]):
        got = flash_decode_pallas(q, k, v, jnp.int32(n), interpret=True)
        want = kops.flash_decode(q, k, v, jnp.int32(n), impl="ref")
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)


# -- engine-level greedy identity: pallas vs ref ----------------------------


def test_engine_identity_plain(cache_arch):
    """Plain generate: the Pallas decode loop (interpret) is greedy
    token-identical to the ref path for every serving cache arch."""
    ref, pal = fp_engine(cache_arch), pallas_engine(cache_arch)
    gen = GenerationConfig(max_new_tokens=8)
    p = prompt_ids(ref, 9, seed=31)
    assert_tokens_identical(pal.generate(p, gen), ref.generate(p, gen),
                            cache_arch)


@pytest.mark.parametrize("arch", ["qwen3-8b", "hymba-1.5b"])
def test_engine_identity_chunked(arch):
    """Chunk-admitted cache + Pallas decode == chunk-admitted + ref decode
    (the two attention archs; recurrent-state archs never hit the kernel)."""
    ref, pal = fp_engine(arch), pallas_engine(arch)
    p = prompt_ids(ref, 11, seed=32)
    n, clen = 6, 11 + 6
    lg_r, c_r = ref.prefill_chunked(p, cache_len=clen, chunk_size=4)
    lg_p, c_p = pal.prefill_chunked(p, cache_len=clen, chunk_size=4)
    assert_tokens_identical(greedy_continue(pal, lg_p, c_p, n),
                            greedy_continue(ref, lg_r, c_r, n), arch)


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v3-671b"])
def test_engine_identity_speculative(arch):
    """Speculative draft/verify with Pallas decode (GQA + MLA latent): the
    verify rollback and the kernel dispatch compose token-identically."""
    ref, pal = fp_engine(arch), pallas_engine(arch)
    p = jnp.asarray([[5, 9, 13] * 4], jnp.int32)      # repetitive: ngram-able
    gen = GenerationConfig(max_new_tokens=8,
                           speculative=SpeculativeConfig(k=2))
    assert_tokens_identical(pal.generate(p, gen), ref.generate(p, gen), arch)


@pytest.mark.parametrize("fmt", ["int8_tok", "mxint4_blk"])
def test_engine_identity_quantized_cache(fmt):
    """Quantized residency: the kernel's in-register dequant path produces
    the same greedy tokens as the ref path reading the same encoded dicts."""
    ref, pal = fp_engine("qwen3-8b"), pallas_engine("qwen3-8b")
    gen = GenerationConfig(max_new_tokens=8, cache_format=fmt)
    p = prompt_ids(ref, 9, seed=33)
    assert_tokens_identical(pal.generate(p, gen), ref.generate(p, gen), fmt)


def _drain(engine, gen, preempt, *, classes, p0, p1, chunk_size=8):
    sched = RequestScheduler(engine, classes=classes, gen=gen,
                             chunk_size=chunk_size, host_spill=preempt)
    sched.submit(Request(uid=0, prompt=p0))
    if preempt:
        while not sched._active:
            sched.step()
        sched.step()
        sched.submit(Request(uid=1, prompt=p1), priority=5)
    else:
        sched.submit(Request(uid=1, prompt=p1))
    res = sched.run()
    return {u: r.tokens for u, r in res.items()}, sched


def test_engine_identity_spill_resume_quantized():
    """Spill/resume with a *quantized* pool (encoded dict leaves through the
    host round trip) stays token-identical, on both impl paths, and the
    preempted run matches the unpreempted one."""
    gen = GenerationConfig(max_new_tokens=6, cache_format="int8_tok")
    outs = {}
    for name in ("ref", "pallas"):
        eng = fp_engine("qwen3-8b") if name == "ref" \
            else pallas_engine("qwen3-8b")
        p0 = prompt_list(eng, 8, seed=34)
        p1 = prompt_list(eng, 8, seed=35)
        base, _ = _drain(eng, gen, False, classes=[(1, 8 + 6)], p0=p0, p1=p1)
        pre, sched = _drain(eng, gen, True, classes=[(1, 8 + 6)],
                            p0=p0, p1=p1)
        assert sched.stats["preempted"] >= 1, name
        assert pre == base, name
        outs[name] = base
    assert outs["pallas"] == outs["ref"]


@pytest.mark.parametrize("arch", ["qwen3-8b", "hymba-1.5b"])
@pytest.mark.parametrize("fmt", ["int8_tok", "mxint4_blk"])
def test_speculative_identity_quantized_cache(arch, fmt):
    """Speculative vs plain greedy identity holds with a quantized cache:
    the verify rollback (linear scatter + hybrid ring_rollback) rolls the
    encoded dict leaves back bit-exactly."""
    eng = fp_engine(arch)
    p = jnp.asarray([[5, 9, 13] * 4], jnp.int32)
    gen = GenerationConfig(max_new_tokens=8, cache_format=fmt)
    sgen = dataclasses.replace(
        gen, speculative=SpeculativeConfig(k=2))
    assert_tokens_identical(eng.generate(p, sgen), eng.generate(p, gen),
                            f"{arch}/{fmt}")


# -- admission guard + byte-aware victim ------------------------------------


def test_chunked_prefill_overflow_raises_capacity_error():
    """Regression for the gqa_decode linear-cache clamp: admitting a prompt
    past ``cache_len`` raises the typed error instead of letting later
    appends clamp onto (and silently overwrite) the last cache row."""
    eng = fp_engine("qwen3-8b")
    p = prompt_ids(eng, 12, seed=36)
    with pytest.raises(CacheCapacityError):
        eng.prefill_chunked(p, cache_len=8, chunk_size=4)


def test_scheduler_submit_overflow_raises_capacity_error():
    """The scheduler's submit-time guard raises the same typed error (it is
    a ValueError subclass, so existing handlers keep working)."""
    eng = fp_engine("qwen3-8b")
    sched = RequestScheduler(eng, classes=[(1, 12)],
                             gen=GenerationConfig(max_new_tokens=4))
    with pytest.raises(CacheCapacityError):
        sched.submit(Request(uid=0, prompt=prompt_list(eng, 20, seed=37)))
    assert isinstance(CacheCapacityError("x"), ValueError)


def test_pick_victim_prefers_largest_cache_class():
    """Byte-aware preemption: among equal-priority residents, the victim is
    the lane freeing the most device bytes (largest cache class), not just
    the oldest admission."""
    eng = fp_engine("qwen3-8b")
    # long outputs so both lanes are still resident when we probe the policy
    gen = GenerationConfig(max_new_tokens=32)
    small, large = 40, 64
    sched = RequestScheduler(eng, classes=[(1, small), (1, large)], gen=gen,
                             chunk_size=8, host_spill=True)
    sched.submit(Request(uid=0, prompt=prompt_list(eng, 6, seed=38)))
    sched.submit(Request(uid=1, prompt=prompt_list(eng, 30, seed=39)))
    for _ in range(200):
        if len(sched._active) == 2:
            break
        sched.step()
    slots = {sched.pool.slot_len(s): s for s in sched._active}
    assert set(slots) == {small, large}
    # uid 0 (small class) was admitted first — priority-only ranking with
    # the seq tie-break would pick it; byte-aware ranking must not.
    assert sched._pick_victim(5, 6) == slots[large]
    sched.run()
