"""Version-compat shims for jax APIs that were renamed across releases.

The repo targets the jax that ships in the image (0.4.x today) but is written
against the modern spellings; every renamed symbol is funneled through here so
a jax upgrade is a one-file change:

  * ``pltpu.CompilerParams``      — 0.4.x calls it ``TPUCompilerParams``.
  * ``jax.sharding.AxisType``     — explicit-sharding axis types (and the
    ``axis_types=`` kwarg of ``jax.make_mesh``) only exist on newer jax;
    0.4.x meshes are implicitly Auto already.
  * ``jax.shard_map``             — 0.4.x only has the experimental spelling.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# The public shim surface.  The analysis linter (rule `compat-api`) forbids
# the underlying version-sensitive spellings everywhere else in src/repro;
# tests/test_compat.py pins this list so a removal is an API break, not a
# silent hole in the lint.
__all__ = ["shard_map", "jit_sharded", "tpu_compiler_params",
           "make_auto_mesh"]


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = True):
    """``jax.shard_map`` across versions.

    0.4.x spells it ``jax.experimental.shard_map.shard_map`` with
    ``check_rep`` instead of ``check_vma`` and an ``auto`` set (the complement
    of the modern ``axis_names`` manual set).
    """
    if hasattr(jax, "shard_map"):
        import inspect
        params = inspect.signature(jax.shard_map).parameters
        kw = {} if axis_names is None else {"axis_names": axis_names}
        # mid-window releases promoted shard_map before the check_vma rename
        kw["check_vma" if "check_vma" in params else "check_rep"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, **kw)


def jit_sharded(fn, *, in_shardings=None, out_shardings=None, **kwargs):
    """``jax.jit`` with explicit shardings across versions.

    Modern jax spells the placement kwargs ``in_shardings``/``out_shardings``;
    the pre-0.4.x pjit-era spelling was ``in_axis_resources`` /
    ``out_axis_resources``.  The sharded serving engine funnels every
    placement-carrying jit through here so a jax upgrade (or downgrade onto
    an edge image) stays a one-file change.
    """
    import inspect
    params = inspect.signature(jax.jit).parameters
    if "in_shardings" in params or any(
            p.kind == inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return jax.jit(fn, in_shardings=in_shardings,
                       out_shardings=out_shardings, **kwargs)
    return jax.jit(fn, in_axis_resources=in_shardings,
                   out_axis_resources=out_shardings, **kwargs)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` / ``pltpu.TPUCompilerParams`` across versions."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_auto_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with every axis in Auto sharding mode.

    Newer jax requires the mode to be spelled out (``AxisType.Auto``); on
    0.4.x the kwarg does not exist and Auto is the only behavior.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(axis_type.Auto,) * len(axes))
