"""Layer-1 driver: run the invariant rules over a source tree.

The unit of reporting is a `Finding` (rules.py); the driver adds:

  * **file discovery** — every ``*.py`` under the package root (default:
    the installed ``repro`` package itself, so ``python -m repro.analysis``
    lints whatever checkout/venv it runs from);
  * **baseline** — a checked-in JSON list of grandfathered findings
    (``analysis/baseline.json``).  Baselined findings are reported as
    ``grandfathered`` and do not fail the run; anything *new* does.  The
    baseline keys findings by (rule, path, source line text) so unrelated
    edits that shift line numbers don't churn it.  The repo policy is an
    EMPTY baseline: fix or explicitly ``# repro: allow(...)`` everything
    (docs/analysis.md).
  * **diff-friendly output** — one finding per line,
    ``path:line:col: [rule] message``, sorted, no timestamps.
"""

from __future__ import annotations

import dataclasses
import json
import os
from collections import Counter

from repro.analysis import rules as rules_mod
from repro.analysis.rules import Finding, lint_source

__all__ = ["LintReport", "lint_tree", "default_root", "default_baseline_path",
           "load_baseline", "save_baseline"]

_PKG_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_root() -> str:
    """The ``repro`` package directory of this very installation."""
    return _PKG_ROOT


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.json")


@dataclasses.dataclass
class LintReport:
    new: list[Finding]              # findings not covered by the baseline
    grandfathered: list[Finding]    # baselined findings still present
    stale_baseline: list[tuple]     # baseline entries no longer found
    files: int

    @property
    def ok(self) -> bool:
        return not self.new

    def render(self, *, verbose: bool = False) -> str:
        lines = [f.render() for f in self.new]
        if verbose:
            lines += [f"{f.render()}  (grandfathered)"
                      for f in self.grandfathered]
        lines.append(f"{len(self.new)} finding(s) "
                     f"({len(self.grandfathered)} grandfathered, "
                     f"{len(self.stale_baseline)} stale baseline entr(ies)) "
                     f"across {self.files} file(s)")
        return "\n".join(lines)


def iter_py_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for name in sorted(filenames):
            if name.endswith(".py"):
                out.append(os.path.join(dirpath, name))
    return out


def load_baseline(path: str) -> list[tuple[str, str, str]]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"baseline {path} must be a JSON list")
    return [(e["rule"], e["path"], e["code"]) for e in data]


def save_baseline(path: str, findings: list[Finding]) -> None:
    data = [{"rule": f.rule, "path": f.path, "code": f.code}
            for f in findings]
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def lint_tree(root: str | None = None,
              baseline: list[tuple[str, str, str]] | None = None
              ) -> LintReport:
    """Lint every python file under ``root`` against all rules."""
    root = root or default_root()
    findings: list[Finding] = []
    files = iter_py_files(root)
    for fp in files:
        rel = os.path.relpath(fp, root).replace(os.sep, "/")
        with open(fp, encoding="utf-8") as f:
            src = f.read()
        findings.extend(lint_source(src, rel))

    budget = Counter(baseline or [])
    new, grandfathered = [], []
    for f in findings:
        key = f.baseline_key()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            grandfathered.append(f)
        else:
            new.append(f)
    stale = [k for k, n in budget.items() for _ in range(n) if n > 0]
    return LintReport(new=new, grandfathered=grandfathered,
                      stale_baseline=stale, files=len(files))


def run(root: str | None = None, baseline_path: str | None = None, *,
        update_baseline: bool = False, verbose: bool = False) -> int:
    """CLI body: returns the process exit code (0 = no new findings)."""
    baseline_path = baseline_path or default_baseline_path()
    report = lint_tree(root, load_baseline(baseline_path))
    if update_baseline:
        save_baseline(baseline_path, report.new + report.grandfathered)
        print(f"baseline updated: {baseline_path} "
              f"({len(report.new) + len(report.grandfathered)} entr(ies))")
        return 0
    print(report.render(verbose=verbose))
    return 0 if report.ok else 1


def list_rules() -> str:
    lines = []
    for r in rules_mod.ALL_RULES:
        scope = ", ".join(s or "src/repro" for s in r.scope)
        lines.append(f"{r.id:22s} {r.summary}  [scope: {scope}]")
    return "\n".join(lines)
