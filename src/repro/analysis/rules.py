"""AST lint rules for the repo's serving invariants.

Each rule encodes one convention the serving stack's correctness/perf
arguments depend on but that, before this module, only review discipline
enforced:

    compat-api             (R1) version-sensitive jax APIs (shard_map,
                           CompilerParams/TPUCompilerParams, AxisType,
                           make_mesh, jit-with-shardings) are touched only in
                           ``repro/compat.py`` — a jax upgrade stays a
                           one-file change.
    bare-assert            (R2) library code raises typed exceptions, never
                           bare ``assert`` (stripped under ``python -O``, and
                           an AssertionError mid-drain abandons queued work —
                           the PR-4 pool contract, repo-wide).
    host-sync              (R3) no host round-trip primitives (``.item()``,
                           ``jax.device_get``, ``np.asarray``,
                           ``int()/float()`` on indexed arrays) in the jitted
                           serving core (``serving/``, ``models/``) outside
                           the allowlisted batched post-step drain.
    module-scope-compute   (R4) no module-scope jnp/jax computation in
                           ``models/``/``serving/`` — hidden trace-time
                           constants allocate at import and dodge sharding /
                           donation decisions.

A finding on line L is suppressed by ``# repro: allow(<rule>)`` on line L or
L-1.  Rules identify jax symbols by *resolving import aliases* (``import
jax.numpy as jnp`` and ``from jax.experimental.shard_map import shard_map``
both resolve to their dotted origins), so renamed imports cannot hide a
violation — and routing through ``repro.compat`` never trips one.
"""

from __future__ import annotations

import ast
import dataclasses
import re

__all__ = ["Finding", "Rule", "ALL_RULES", "RULE_IDS", "lint_source",
           "HOST_SYNC_ALLOW"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, keyed for diff-friendly output and baselining."""

    rule: str
    path: str              # repo-relative, forward slashes
    line: int
    col: int
    message: str
    code: str              # the stripped source line (baseline identity)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"

    def baseline_key(self) -> tuple[str, str, str]:
        """Line-number-free identity: findings survive unrelated edits."""
        return (self.rule, self.path, self.code)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    scope: tuple[str, ...]     # path prefixes the rule applies to ("" = all)
    exclude: tuple[str, ...] = ()


# -- rule catalog -----------------------------------------------------------

R1 = Rule(
    id="compat-api",
    summary="version-sensitive jax APIs only in compat.py "
            "(use repro.compat shims)",
    scope=("",),
    exclude=("compat.py",),
)
R2 = Rule(
    id="bare-assert",
    summary="no bare assert in library code (raise ValueError/TypeError)",
    scope=("",),
)
R3 = Rule(
    id="host-sync",
    summary="no host round-trip primitives in the jitted serving core",
    scope=("serving/", "models/", "obs/"),
)
R4 = Rule(
    id="module-scope-compute",
    summary="no module-scope jnp/jax computation (hidden trace-time "
            "constants)",
    scope=("serving/", "models/", "obs/"),
)

ALL_RULES = (R1, R2, R3, R4)
RULE_IDS = tuple(r.id for r in ALL_RULES)

# Functions allowed to synchronize with the host: the scheduler's batched
# post-step drain (token blocks leave the device exactly once per sequencer
# cycle, in one gather), the host-spill tiers themselves — the pool's slot
# spill and the prefix cache's cold-page migration, whose entire point is a
# device->host transfer — and the tracer's explicit flush — the ONE place
# the observability layer may gather its deferred device-array span args
# (record sites store arrays as-is; `Tracer.flush` resolves them at export
# time).  Key: "<path>::<Qualified.name>".
HOST_SYNC_ALLOW = frozenset({
    "serving/scheduler.py::RequestScheduler.step",
    "serving/scheduler.py::RequestScheduler._preempt",
    "serving/scheduler.py::CachePool.spill",
    "serving/paging.py::PrefixCache._spill",
    "obs/trace.py::Tracer.flush",
})

# Dotted names (post import-resolution) that only compat.py may touch.
_VERSION_SENSITIVE = {
    "jax.shard_map": "use repro.compat.shard_map",
    "jax.experimental.shard_map": "use repro.compat.shard_map",
    "jax.experimental.shard_map.shard_map": "use repro.compat.shard_map",
    "jax.experimental.pjit": "use repro.compat.jit_sharded",
    "jax.experimental.pjit.pjit": "use repro.compat.jit_sharded",
    "jax.sharding.AxisType": "use repro.compat.make_auto_mesh",
    "jax.make_mesh": "use repro.compat.make_auto_mesh",
    "jax.experimental.pallas.tpu.CompilerParams":
        "use repro.compat.tpu_compiler_params",
    "jax.experimental.pallas.tpu.TPUCompilerParams":
        "use repro.compat.tpu_compiler_params",
}

# jax.jit kwargs that make the call a jit-sharding entry point (renamed
# across the pjit window) — those calls go through compat.jit_sharded.
_SHARDING_KWARGS = {"in_shardings", "out_shardings",
                    "in_axis_resources", "out_axis_resources"}

# Host-sync callables by resolved dotted name.
_HOST_SYNC_CALLS = {
    "jax.device_get": "device->host transfer",
    "numpy.asarray": "forces a host copy of a device array",
    "numpy.array": "forces a host copy of a device array",
}

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([\w\-*,\s]+)\)")


def _suppressions(src: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = {t.strip() for t in m.group(1).split(",") if t.strip()}
    return out


def _in_scope(rule: Rule, path: str) -> bool:
    if any(path == e or path.endswith("/" + e) for e in rule.exclude):
        return False
    return any(path.startswith(s) for s in rule.scope)


class _ImportTable(ast.NodeVisitor):
    """Local name -> dotted origin module/symbol, across the whole file."""

    def __init__(self):
        self.names: dict[str, str] = {}
        self.sensitive_imports: list[tuple[int, int, str]] = []

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            local = a.asname or a.name.split(".")[0]
            self.names[local] = a.name if a.asname else a.name.split(".")[0]

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.level or not node.module:       # relative: best-effort skip
            return
        for a in node.names:
            full = f"{node.module}.{a.name}"
            self.names[a.asname or a.name] = full
            hit = _VERSION_SENSITIVE.get(full) \
                or _VERSION_SENSITIVE.get(node.module)
            if hit:
                self.sensitive_imports.append(
                    (node.lineno, node.col_offset, f"import of {full}: {hit}"))


def _dotted(node: ast.AST) -> list[str] | None:
    """['jnp', 'zeros'] for ``jnp.zeros`` — None if not a pure name chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return parts[::-1]


def _resolve(node: ast.AST, table: _ImportTable) -> str | None:
    parts = _dotted(node)
    if parts is None:
        return None
    root = table.names.get(parts[0])
    if root is None:
        return None
    return ".".join([root] + parts[1:])


def _contains(node: ast.AST, kind) -> bool:
    return any(isinstance(n, kind) for n in ast.walk(node))


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, src: str, lines: list[str]):
        self.path = path
        self.lines = lines
        self.table = _ImportTable()
        self.qual: list[str] = []         # class/function name stack
        self.depth = 0                    # function nesting depth
        self.findings: list[Finding] = []

    # -- helpers ------------------------------------------------------------

    def _emit(self, rule: Rule, node: ast.AST, message: str):
        if not _in_scope(rule, self.path):
            return
        line = getattr(node, "lineno", 1)
        code = self.lines[line - 1].strip() if line <= len(self.lines) else ""
        self.findings.append(Finding(rule.id, self.path, line,
                                     getattr(node, "col_offset", 0) + 1,
                                     message, code))

    def _allowlisted(self) -> bool:
        key = f"{self.path}::{'.'.join(self.qual)}"
        return key in HOST_SYNC_ALLOW

    # -- structure tracking --------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef):
        self.qual.append(node.name)
        self.generic_visit(node)
        self.qual.pop()

    def _visit_fn(self, node):
        self.qual.append(node.name)
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1
        self.qual.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # -- R2: bare assert -----------------------------------------------------

    def visit_Assert(self, node: ast.Assert):
        self._emit(R2, node,
                   "bare assert in library code — raise a typed exception "
                   "(stripped under python -O; kills the serving drain loop)")
        self.generic_visit(node)

    # -- R1 / R3 / R4 hang off name and call sites ---------------------------

    def visit_Attribute(self, node: ast.Attribute):
        full = _resolve(node, self.table)
        hit = _VERSION_SENSITIVE.get(full) if full else None
        if hit:
            self._emit(R1, node, f"{full}: {hit}")
            return                        # don't re-flag the inner chain
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            full = self.table.names.get(node.id)
            hit = _VERSION_SENSITIVE.get(full) if full else None
            if hit:
                self._emit(R1, node, f"{full}: {hit}")

    def visit_Call(self, node: ast.Call):
        full = _resolve(node.func, self.table)

        # R1: jit-sharding entry points must route through compat.jit_sharded
        if full in ("jax.jit", "jax.experimental.pjit.pjit"):
            kw = {k.arg for k in node.keywords if k.arg}
            if kw & _SHARDING_KWARGS:
                self._emit(R1, node,
                           "jax.jit with explicit shardings: use "
                           "repro.compat.jit_sharded (kwarg spelling is "
                           "version-sensitive)")

        # R3: host-sync primitives inside serving/model functions
        if self.depth > 0 and not self._allowlisted():
            sync = _HOST_SYNC_CALLS.get(full) if full else None
            if sync:
                self._emit(R3, node, f"{full}: {sync} inside the jitted "
                                     "serving core")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "item" and not node.args
                  and not node.keywords):
                self._emit(R3, node,
                           ".item(): per-element host sync inside the "
                           "jitted serving core")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in ("int", "float") and node.args
                  and _contains(node.args[0], ast.Subscript)):
                self._emit(R3, node,
                           f"{node.func.id}() on an indexed array: host "
                           "sync inside the jitted serving core")

        # R4: module-scope jnp/jax computation
        if self.depth == 0 and full and (full == "jax"
                                         or full.startswith("jax.")):
            self._emit(R4, node,
                       f"module-scope call to {full}: hidden trace-time "
                       "constant (build it inside the function or cache "
                       "explicitly)")

        self.generic_visit(node)


def lint_source(src: str, path: str) -> list[Finding]:
    """Run every rule over one file's source.

    ``path`` is the path relative to the package root being linted (e.g.
    ``serving/engine.py``), used for rule scoping, the allowlist, and
    baseline identity.
    """
    path = path.replace("\\", "/")
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("parse-error", path, e.lineno or 1, 1,
                        f"could not parse: {e.msg}", "")]
    linter = _Linter(path, src, src.splitlines())
    linter.table.visit(tree)
    if _in_scope(R1, path):
        for line, col, msg in linter.table.sensitive_imports:
            code = (linter.lines[line - 1].strip()
                    if line <= len(linter.lines) else "")
            linter.findings.append(Finding(R1.id, path, line, col + 1,
                                           msg, code))
    linter.visit(tree)

    allowed = _suppressions(src)
    out = []
    for f in linter.findings:
        for ln in (f.line, f.line - 1):
            rules = allowed.get(ln)
            if rules and (f.rule in rules or "*" in rules):
                break
        else:
            out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.col, f.rule))
