"""`repro.analysis` — machine-checked serving invariants.

Two layers (docs/analysis.md):

  * **AST lint** (`lint`, `rules`): repo conventions the serving stack's
    perf/correctness arguments rely on — compat-funneled jax APIs, typed
    exceptions, no host syncs in the jitted core, no module-scope compute.
  * **Program audit** (`program_audit`): instantiate a tiny
    `InferenceEngine` and inspect its *lowered/compiled* programs — bounded
    compile count from the bucket ladder, honored cache donation, a
    host-callback-free decode while_loop, and ServeCell sharding plans
    actually realized on the mesh.

CLI: ``python -m repro.analysis lint`` / ``python -m repro.analysis audit``
(`make lint-invariants` / `make audit-program`).
"""

from repro.analysis.rules import ALL_RULES, Finding, lint_source  # noqa: F401
from repro.analysis.lint import LintReport, lint_tree             # noqa: F401

__all__ = ["ALL_RULES", "Finding", "lint_source", "LintReport", "lint_tree"]
