"""Layer-2 program audit: inspect the serving hot path's *lowered programs*.

The AST lint (Layer 1) checks what the source says; this module checks what
XLA actually compiled.  Each audit instantiates a tiny-config
`InferenceEngine` and proves one of the software analogues of the paper's
accounting guarantees (utilization / external-memory-access minimality):

    recompiles   (A1) the power-of-two bucket ladder holds: driving every
                 prompt length 1..K produces O(log K) compiled prefill
                 signatures — not O(K) — on both the bucketed and the
                 chunked admission paths.
    donation     (A2) the chunked-prefill step's resident cache is donated
                 *in the compiled executable* (input_output_alias covers
                 every cache leaf): appending a chunk is in-place, not a
                 full cache copy per chunk.
    transfers    (A3) the fused decode / speculative-verify ``while_loop``
                 HLO contains no host callbacks and no async host/device
                 transfer ops — the MVM phase never round-trips off device.
    sharding     (A4) on a mesh, the ServeCell plan is *realized*: params
                 and caches lie where the rules engine said, every `_sjit`
                 entry's shardings come from the plan's mesh, and no entry
                 reshards its resident cache between input and output.
    decode-kernel (A5) the fused decode while_loop actually dispatches the
                 flash-decode attention kernel (`kernels/flash_decode.py`)
                 when ``kernel_impl='pallas'`` — traced-program (jaxpr)
                 inspection, for both fp and quantized caches — and that
                 'auto' resolution never smuggles a Pallas call onto a
                 non-TPU backend.
    donation-quant (A6) the donation guarantee (A2) survives the quantized
                 cache layout: with encoded dict leaves ({"q","s"} /
                 {"m","e"}) the chunk step still aliases (nearly) every
                 resident cache byte.
    observability (A7) the `repro.obs` layer is zero-overhead where it
                 counts: the compiled decode / speculative-verify programs
                 are byte-identical with the full observability stack
                 (tracer + profiler annotations + metrics) on vs off.
    prefix-reuse (A8) shared-prefix adoption (serving/paging.py) is
                 invisible to the compiled programs: the adopted-prefix
                 decode HLO is byte-identical to the cold path, the
                 suffix-only chunked prefill reuses the cold chunk ladder
                 (zero new signatures) while keeping >= 90% cache-byte
                 donation, and a warm scheduler drain actually hits.

Run via ``python -m repro.analysis audit`` (`make audit-program`).  The
sharding audit needs >= 4 devices; the Makefile target forces 4 virtual
host devices so it exercises a real 2x2 (data, model) mesh everywhere.
"""

from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["AuditResult", "AuditReport", "audit_recompiles",
           "audit_donation", "audit_transfers", "audit_sharding",
           "audit_decode_kernel", "audit_observability",
           "audit_prefix_reuse", "run_audits",
           "parse_io_aliases", "hlo_opcodes", "custom_call_targets"]

DEFAULT_ARCH = "retnet-1.3b"

# Host-communication HLO opcodes: any of these inside the decode loop means
# the MVM phase blocks on the host/network per step.
_TRANSFER_OPS = frozenset({"infeed", "outfeed", "send", "recv",
                           "send-done", "recv-done",
                           "copy-start", "copy-done"})
# custom-call targets that reach back into the host Python process.
_HOST_CALLBACK_RE = re.compile(r"callback|host|py_func|python", re.I)


@dataclasses.dataclass
class AuditResult:
    name: str
    ok: bool
    detail: str
    metrics: dict

    def render(self) -> str:
        return f"[{'ok' if self.ok else 'FAIL'}] {self.name}: {self.detail}"


@dataclasses.dataclass
class AuditReport:
    results: list[AuditResult]

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    def render(self) -> str:
        lines = [r.render() for r in self.results]
        lines.append("audit: " + ("PASS" if self.ok else "FAIL")
                     + f" ({sum(r.ok for r in self.results)}"
                       f"/{len(self.results)})")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"ok": self.ok,
                "results": [dataclasses.asdict(r) for r in self.results]}


# -- HLO text inspection -----------------------------------------------------

_ALIAS_RE = re.compile(r"\{\s*(\d+(?:\s*,\s*\d+)*)?\s*\}:\s*\((\d+),\s*\{\}")


def parse_io_aliases(hlo_text: str) -> list[tuple[tuple[int, ...], int]]:
    """(output index path, parameter number) pairs of the module's
    ``input_output_alias`` annotation — the compiled spelling of buffer
    donation."""
    m = re.search(r"input_output_alias=\{(.*?)\}\s*,\s*entry_computation",
                  hlo_text, re.S)
    section = m.group(1) if m else hlo_text
    out = []
    for idx, param in _ALIAS_RE.findall(section):
        path = tuple(int(p) for p in re.split(r"\s*,\s*", idx)) if idx else ()
        out.append((path, int(param)))
    return out


def hlo_opcodes(hlo_text: str) -> set[str]:
    """Opcode set of an HLO module text (covers all computations, so the
    bodies of while/fusion computations are included)."""
    return set(re.findall(r"=\s*[\w\[\],{}() ]*?\s([a-z][a-z0-9-]*)\(",
                          hlo_text))


def custom_call_targets(hlo_text: str) -> set[str]:
    return set(re.findall(r'custom_call_target="([^"]+)"', hlo_text))


def _compiled_text(lowered) -> str:
    return lowered.compile().as_text()


# -- engine construction -----------------------------------------------------

def tiny_engine(arch: str = DEFAULT_ARCH, *, mesh=None):
    """Reduced fp engine — small enough that compiling its programs is a CI
    step, faithful enough that the audited programs are the real hot path."""
    from repro.serving import EngineSpec, InferenceEngine
    return InferenceEngine.from_config(
        arch, EngineSpec(reduced=True, quantize=False), mesh=mesh)


# -- A1: recompile audit -----------------------------------------------------

def audit_recompiles(arch: str = DEFAULT_ARCH, *, max_len: int = 24,
                     chunk_size: int = 8) -> AuditResult:
    """Drive EVERY prompt length 1..max_len through the bucketed and the
    chunked admission paths and bound the compiled-signature counts."""
    import jax
    import jax.numpy as jnp
    from repro.serving.engine import bucket_length

    engine = tiny_engine(arch)
    cache_len = bucket_length(max_len)
    for s in range(1, max_len + 1):
        tokens = jax.random.randint(jax.random.key(s), (1, s), 1,
                                    engine.cfg.vocab_size, dtype=jnp.int32)
        engine.prefill(tokens, bucket=True)
        engine.prefill_chunked(tokens, cache_len=cache_len,
                               chunk_size=chunk_size)

    counts = engine.compile_counts()
    n_prefill = counts["prefill"]
    if n_prefill < 0:                       # no _cache_size on this jax
        n_prefill = len({k for k in engine.prefill_shape_keys
                         if k[0] == "bucket"})
    n_chunk = counts["prefill_chunk"]
    if n_chunk < 0:
        n_chunk = len({k for k in engine.prefill_shape_keys
                       if k[0] == "chunk"})
    bucket_bound = int(math.log2(cache_len)) + 1
    chunk_bound = int(math.log2(chunk_size)) + 1
    ok = 0 < n_prefill <= bucket_bound and 0 < n_chunk <= chunk_bound
    return AuditResult(
        "recompiles", ok,
        f"{max_len} prompt lengths -> {n_prefill} bucketed prefill "
        f"signature(s) (bound {bucket_bound}) and {n_chunk} chunk "
        f"signature(s) (bound {chunk_bound})",
        {"max_len": max_len, "prefill_signatures": n_prefill,
         "bucket_bound": bucket_bound, "chunk_signatures": n_chunk,
         "chunk_bound": chunk_bound, "compile_counts": counts})


# -- A2: donation audit ------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")


def entry_param_bytes(hlo_text: str) -> list[int]:
    """Byte size of each entry-computation parameter, in parameter order,
    parsed from the ``entry_computation_layout`` signature."""
    m = re.search(r"entry_computation_layout=\{\((.*?)\)\s*->", hlo_text, re.S)
    if not m:
        return []
    out = []
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        n = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n)
    return out


def audit_donation(arch: str = DEFAULT_ARCH, *, chunk: int = 8,
                   cache_len: int = 32, cache_dtype=None,
                   engine=None) -> AuditResult:
    """Compile the chunked-prefill step and verify the executable aliases
    the donated resident cache instead of silently copying it.

    `jax.jit` prunes unused args (`keep_unused=False`), so cache leaves the
    chunk step never reads (stat scalars it recomputes) do not survive to
    the entry computation — counting aliased *leaves* would under-count.
    The invariant that matters for external-memory traffic is byte
    coverage: the aliased parameter bytes must cover (nearly) the whole
    resident cache, i.e. the KV megabuffer is updated in place and never
    copied once per chunk.

    ``cache_dtype`` accepts the same values as `lm.make_decode_cache`: a jnp
    dtype, or a `core.kvq` format string ('int8_tok' | 'mxint4_blk') — the
    latter audits the *quantized* resident layout (A6, 'donation-quant'):
    the encoded dict leaves ({"q","s"} / {"m","e"}) must alias just like the
    fp megabuffer does."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm

    engine = engine or tiny_engine(arch)
    quant = isinstance(cache_dtype, str)
    cache_dtype = jnp.float32 if cache_dtype is None else cache_dtype
    lowered = engine.lower_prefill_chunk(chunk=chunk, cache_len=cache_len,
                                         cache_dtype=cache_dtype)
    text = _compiled_text(lowered)
    aliases = parse_io_aliases(text)
    sizes = entry_param_bytes(text)

    cache_abs = jax.eval_shape(
        lambda: lm.make_decode_cache(engine.cfg, 1, cache_len, cache_dtype,
                                     start_pos=0))
    cache_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(cache_abs))
    aliased = sum(sizes[p] for _, p in aliases if p < len(sizes))
    frac = aliased / cache_bytes if cache_bytes else 0.0
    ok = bool(aliases) and frac >= 0.9
    return AuditResult(
        f"donation-quant[{cache_dtype}]" if quant else "donation", ok,
        f"{len(aliases)} alias(es) keep {aliased}/{cache_bytes} cache bytes "
        f"({frac:.1%}) in place" if ok else
        f"aliases cover only {aliased}/{cache_bytes} cache bytes "
        f"({frac:.1%}): the donated cache is being copied",
        {"aliases": len(aliases), "aliased_bytes": aliased,
         "cache_bytes": cache_bytes, "fraction": round(frac, 4)})


# -- A3: transfer audit ------------------------------------------------------

def _scan_transfers(text: str) -> tuple[set[str], set[str]]:
    bad_ops = hlo_opcodes(text) & _TRANSFER_OPS
    bad_calls = {t for t in custom_call_targets(text)
                 if _HOST_CALLBACK_RE.search(t)}
    return bad_ops, bad_calls


def audit_transfers(arch: str = DEFAULT_ARCH, *, max_new_tokens: int = 8,
                    spec_k: int = 2, engine=None) -> AuditResult:
    """Scan the fused decode and speculative-verify while_loop HLO for host
    callbacks and transfer ops — there must be none: one dispatch runs the
    whole MVM phase on device."""
    from repro.serving import GenerationConfig, SpeculativeConfig

    engine = engine or tiny_engine(arch)
    gen = GenerationConfig(max_new_tokens=max_new_tokens)
    text = _compiled_text(engine.lower_decode_loop(gen))
    bad_ops, bad_calls = _scan_transfers(text)

    spec_bad_ops: set[str] = set()
    spec_bad_calls: set[str] = set()
    spec_gen = GenerationConfig(max_new_tokens=max_new_tokens,
                                speculative=SpeculativeConfig(k=spec_k))
    spec_text = _compiled_text(engine.lower_spec_loop(spec_gen))
    s_ops, s_calls = _scan_transfers(spec_text)
    spec_bad_ops |= s_ops
    spec_bad_calls |= s_calls

    bad = sorted(bad_ops | bad_calls | spec_bad_ops | spec_bad_calls)
    ok = not bad
    return AuditResult(
        "transfers", ok,
        "decode + verify while_loops are host-callback- and transfer-free"
        if ok else f"host/transfer ops in the fused loops: {bad}",
        {"decode_bad": sorted(bad_ops | bad_calls),
         "verify_bad": sorted(spec_bad_ops | spec_bad_calls)})


# -- A4: sharding audit ------------------------------------------------------

# Known cache argument positions per `_sjit` root name:
# (cache index in in_shardings, cache index in out_shardings).
_CACHE_ARGS = {"prefill_chunk": (2, 1), "decode": (2, 1),
               "loop": (2, 2), "resume_loop": (2, 2), "spec_loop": (5, 2)}


def audit_sharding(arch: str = DEFAULT_ARCH, *, mesh_spec: str = "2,2",
                   max_new_tokens: int = 4) -> AuditResult:
    """Drive the sharded engine's serving paths on a (data, model) mesh and
    prove the ServeCell plan is realized — `runtime.sharding
    .sharding_mismatches` over live arrays plus a replay of every `_sjit`
    entry's recorded shardings."""
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_serving_mesh
    from repro.runtime import sharding as shd
    from repro.serving import GenerationConfig, Request, RequestScheduler

    need = 1
    for p in re.split(r"[x,]", mesh_spec):
        need *= int(p)
    if jax.device_count() < need:
        return AuditResult(
            "sharding", True,
            f"skipped: {jax.device_count()} device(s) < {need} "
            f"(run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need}, as `make audit-program` does)",
            {"skipped": True, "devices": jax.device_count()})

    mesh = make_serving_mesh(mesh_spec)
    engine = tiny_engine(arch, mesh=mesh)
    gen = GenerationConfig(max_new_tokens=max_new_tokens)
    s_in, cache_len = 8, 8 + max_new_tokens
    prompts = jax.random.randint(jax.random.key(0), (1, s_in), 1,
                                 engine.cfg.vocab_size, dtype=jnp.int32)

    mismatches: list[str] = []

    # Params: placed exactly as the ServeCell plan says.
    for m in shd.sharding_mismatches(engine.params, engine.param_shardings):
        mismatches.append(f"params/{m}")

    # Prefill -> decode_step -> fused loop: every returned cache lies under
    # the rules engine's placement.
    logits, cache = engine.prefill(prompts, cache_len=cache_len)
    for m in shd.sharding_mismatches(cache, engine.cache_shardings(cache)):
        mismatches.append(f"prefill_cache/{m}")
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    _, cache2 = engine.decode_step(tok, cache)
    for m in shd.sharding_mismatches(cache2, engine.cache_shardings(cache2)):
        mismatches.append(f"decode_cache/{m}")
    engine.generate(prompts, gen)

    # Chunked admission + pool: the stacked stores stay on-plan after a
    # scheduler drain.
    sched = RequestScheduler(engine, n_slots=2, cache_len=cache_len, gen=gen,
                             chunk_size=4)
    for uid in range(2):
        sched.submit(Request(uid=uid, prompt=prompts[0].tolist()))
    sched.run()
    for m in sched.pool.placement_mismatches():
        mismatches.append(f"pool/{m}")

    # Every _sjit entry: shardings come from the plan's mesh, the params arg
    # carries the plan's exact placement, and no entry reshards its resident
    # cache between input and output.
    entries = engine.jit_entries()
    pkey = shd.shardings_key(engine.param_shardings)
    for entry in entries:
        name = entry["name"]
        root = name[0] if isinstance(name[0], str) else str(name[0])
        ins, outs = entry["in_shardings"], entry["out_shardings"]
        for s in shd.sharding_leaves((ins, outs)):
            if getattr(s, "mesh", None) is not None and s.mesh != mesh:
                mismatches.append(f"sjit[{root}]: sharding {s} targets a "
                                  f"foreign mesh")
        if shd.shardings_key(ins[0]) != pkey:
            mismatches.append(f"sjit[{root}]: params in_sharding departs "
                              f"from the ServeCell plan")
        pos = _CACHE_ARGS.get(root)
        if pos is not None:
            cin, cout = ins[pos[0]], outs[pos[1]]
            if shd.shardings_key(cin) != shd.shardings_key(cout):
                mismatches.append(f"sjit[{root}]: cache resharded between "
                                  f"input and output")

    ok = not mismatches and bool(entries)
    detail = (f"{len(entries)} jit entr(ies) + live params/caches/pool all "
              f"on the {mesh_spec} ServeCell plan" if ok else
              ("; ".join(mismatches[:8]) or "no _sjit entries recorded"))
    return AuditResult("sharding", ok, detail,
                       {"mesh": mesh_spec, "jit_entries": len(entries),
                        "mismatches": mismatches})


# -- A5: decode-kernel audit -------------------------------------------------

# The decode-kernel audit needs an arch whose decode path actually attends
# over a KV cache; DEFAULT_ARCH (retnet) is attention-free.
KERNEL_ARCH = "qwen3-8b"


def _count_pallas(engine, logits, cache, gen) -> int:
    """pallas_call occurrences in the traced fused-decode-loop jaxpr.

    Traced (jaxpr), not compiled: off-TPU, XLA:CPU cannot *compile* a real
    Pallas TPU kernel, but tracing still records exactly which primitive the
    `kernels.ops.flash_decode` wrapper resolved to — which is the invariant
    under audit."""
    import functools
    import jax

    key = jax.eval_shape(lambda: jax.random.key(0))
    jaxpr = jax.make_jaxpr(functools.partial(engine._loop_impl, gen=gen))(
        engine.params, logits, cache, key)
    return str(jaxpr).count("pallas_call")


def audit_decode_kernel(arch: str = KERNEL_ARCH, *, s_in: int = 8,
                        cache_len: int = 12) -> AuditResult:
    """Trace the fused decode loop and prove kernel dispatch honesty:

      * ``kernel_impl='pallas'`` puts the flash-decode `pallas_call` inside
        the while_loop body — for the fp cache AND for a quantized
        ('int8_tok') cache, i.e. dequantization is fused into the kernel's
        KV loads rather than materializing an fp cache first;
      * ``kernel_impl='auto'`` on a non-TPU backend resolves to the jnp
        reference path — zero pallas_calls smuggled onto a backend that
        cannot run them (on TPU, 'auto' must instead match 'pallas').
    """
    import jax
    from repro.models import lm
    from repro.serving import EngineSpec, GenerationConfig, InferenceEngine

    gen = GenerationConfig(max_new_tokens=4)
    on_tpu = jax.default_backend() == "tpu"

    def counts(impl: str) -> tuple[int, int]:
        eng = InferenceEngine.from_config(
            arch, EngineSpec(reduced=True, quantize=False, kernel_impl=impl))
        logits, cache = eng._abstract_prefill(s_in, cache_len)
        qcache = jax.eval_shape(
            lambda c: lm.quantize_cache(c, eng.cfg, "int8_tok"), cache)
        return (_count_pallas(eng, logits, cache, gen),
                _count_pallas(eng, logits, qcache, gen))

    n_pallas_fp, n_pallas_q = counts("pallas")
    n_auto_fp, n_auto_q = counts("auto")

    want_auto = (n_auto_fp > 0 and n_auto_q > 0) if on_tpu \
        else (n_auto_fp == 0 and n_auto_q == 0)
    ok = n_pallas_fp > 0 and n_pallas_q > 0 and want_auto
    backend = jax.default_backend()
    return AuditResult(
        "decode-kernel", ok,
        f"pallas: {n_pallas_fp} fp / {n_pallas_q} quantized pallas_call(s) "
        f"in the fused loop; auto on {backend}: {n_auto_fp} fp / "
        f"{n_auto_q} quantized"
        + ("" if ok else " — dispatch does not match the impl policy"),
        {"arch": arch, "backend": backend,
         "pallas_fp": n_pallas_fp, "pallas_quant": n_pallas_q,
         "auto_fp": n_auto_fp, "auto_quant": n_auto_q})


# -- A7: observability audit -------------------------------------------------

def audit_observability(arch: str = DEFAULT_ARCH, *, max_new_tokens: int = 8,
                        spec_k: int = 2) -> AuditResult:
    """Prove the observability layer is zero-overhead where it counts: the
    compiled decode and speculative-verify programs are **byte-identical**
    with the full `repro.obs` stack enabled (live tracer + profiler
    annotations + metrics) vs absent.

    The layer's contract is host-side-only recording at step/drain
    boundaries — nothing it does may reach the traced computation.  A
    metric read that forced a reshape, an annotation that entered the
    jaxpr, or a tracer arg that materialized inside the loop would all
    change the compiled text; comparing the bytes catches every such leak
    at once."""
    from repro.obs import Observability, Tracer
    from repro.serving import GenerationConfig, SpeculativeConfig

    plain = tiny_engine(arch)
    from repro.serving import EngineSpec, InferenceEngine
    obs = Observability(tracer=Tracer(), profile=True)
    traced = InferenceEngine.from_config(
        arch, EngineSpec(reduced=True, quantize=False), obs=obs)

    gen = GenerationConfig(max_new_tokens=max_new_tokens)
    spec_gen = GenerationConfig(max_new_tokens=max_new_tokens,
                                speculative=SpeculativeConfig(k=spec_k))
    diffs = []
    for name, lower in (("decode", lambda e: e.lower_decode_loop(gen)),
                        ("verify", lambda e: e.lower_spec_loop(spec_gen))):
        base = _compiled_text(lower(plain))
        instr = _compiled_text(lower(traced))
        if base != instr:
            diffs.append(f"{name} ({len(base)} vs {len(instr)} bytes)")
    ok = not diffs
    return AuditResult(
        "observability", ok,
        "decode + verify programs byte-identical with obs on vs off"
        if ok else f"observability changed compiled program(s): "
                   f"{', '.join(diffs)}",
        {"programs": ["decode", "verify"], "diffs": diffs})


# -- A8: prefix-reuse audit ---------------------------------------------------

def audit_prefix_reuse(arch: str = KERNEL_ARCH, *, cache_len: int = 24,
                       chunk_size: int = 8) -> AuditResult:
    """Prove shared-prefix adoption (serving/paging.py) is invisible to the
    compiled programs:

      * the cache `PrefixCache` assembles for an adopted prefix has avals
        identical to a cold decode cache, so the decode step compiles to
        **byte-identical** HLO warm vs cold — adoption can never push the
        MVM phase onto a different (slower) program;
      * the suffix-only chunked prefill stays inside the cold admission's
        compiled chunk ladder (zero new prefill signatures) and every
        suffix chunk length still donates >= 90% of the resident cache
        bytes (the A2 guarantee survives a nonzero start offset);
      * a warm scheduler drain actually hits the index — the audit fails
        loudly if adoption silently degrades to cold admissions.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    from repro.serving import (GenerationConfig, PrefixCache, Request,
                               RequestScheduler)

    engine = tiny_engine(arch)
    donor = jax.random.randint(jax.random.key(1), (1, 16), 1,
                               engine.cfg.vocab_size, dtype=jnp.int32)
    suffix = jax.random.randint(jax.random.key(2), (1, 5), 1,
                                engine.cfg.vocab_size, dtype=jnp.int32)
    query = jnp.concatenate([donor, suffix], axis=1)
    problems: list[str] = []

    # Register the donor's prefix, then adopt it for the query.
    _, donor_cache = engine.prefill_chunked(donor, cache_len=cache_len,
                                            chunk_size=chunk_size)
    pc = PrefixCache(engine.cfg, jnp.float32, enabled=True, page_size=4)
    pc.register(donor[0].tolist(), donor_cache, cache_len)
    p, warm = pc.lookup(query[0].tolist(), cache_len, slot=0,
                        chunk_size=chunk_size)
    if p != donor.shape[1]:
        problems.append(f"adopted {p}/{donor.shape[1]} donor tokens")

    # (1) Assembled-cache avals == cold-cache avals => the decode step
    # lowers and compiles to byte-identical HLO on either.
    cold = lm.make_decode_cache(engine.cfg, 1, cache_len, jnp.float32,
                                start_pos=p)
    shape_of = jax.eval_shape
    if shape_of(lambda: warm) != shape_of(lambda: cold):
        problems.append("assembled prefix cache avals differ from cold")
    tok = jnp.zeros((1, 1), jnp.int32)
    text_warm = _compiled_text(
        jax.jit(engine._decode_impl).lower(engine.params, tok, warm))
    text_cold = _compiled_text(
        jax.jit(engine._decode_impl).lower(engine.params, tok, cold))
    hlo_identical = text_warm == text_cold
    if not hlo_identical:
        problems.append(f"decode HLO differs warm vs cold "
                        f"({len(text_warm)} vs {len(text_cold)} bytes)")

    # (2) Suffix-only prefill: same compiled ladder as a cold admission of
    # the same prompt, and per-chunk donation still >= 90%.
    engine.prefill_chunked(query, cache_len=cache_len,
                           chunk_size=chunk_size)       # the cold ladder
    before = set(engine.prefill_shape_keys)
    cp = engine.begin_chunked_prefill(query, cache_len=cache_len,
                                      chunk_size=chunk_size,
                                      initial_cache=warm, start_offset=p)
    while not cp.done:
        cp.advance()
    new_keys = sorted(set(engine.prefill_shape_keys) - before)
    if new_keys:
        problems.append(f"adopted admission compiled new prefill "
                        f"signature(s): {new_keys}")
    fractions = {}
    for c in sorted(set(cp.schedule)):
        r = audit_donation(arch, chunk=c, cache_len=cache_len, engine=engine)
        fractions[c] = r.metrics["fraction"]
        if not r.ok:
            problems.append(f"suffix chunk {c}: only "
                            f"{r.metrics['fraction']:.1%} cache bytes donated")

    # (3) A warm scheduler drain hits the index end to end.
    sched = RequestScheduler(engine, n_slots=2, cache_len=2 * cache_len,
                             gen=GenerationConfig(max_new_tokens=4),
                             chunk_size=chunk_size, prefix_cache=True)
    sched.submit(Request(uid=0, prompt=donor[0].tolist()))
    sched.submit(Request(uid=1, prompt=query[0].tolist()))
    sched.run()
    st = sched.pool.prefix.stats
    if st["prefix_hits"] < 1:
        problems.append("warm scheduler drain never hit the prefix index")

    ok = not problems
    return AuditResult(
        "prefix-reuse", ok,
        f"adopted {p} tokens: decode HLO byte-identical, suffix chunks "
        f"{sorted(set(cp.schedule))} reuse the cold ladder with "
        f"{min(fractions.values()):.1%}+ cache bytes donated, "
        f"{st['prefix_hit_tokens']} tokens skipped in a scheduler drain"
        if ok else "; ".join(problems),
        {"arch": arch, "adopted_tokens": int(p),
         "hlo_identical": hlo_identical, "new_prefill_keys": new_keys,
         "suffix_donation": fractions,
         "sched_hits": st["prefix_hits"],
         "sched_hit_tokens": st["prefix_hit_tokens"]})


# -- driver ------------------------------------------------------------------

def run_audits(arch: str = DEFAULT_ARCH, *, mesh_spec: str = "2,2",
               max_len: int = 24) -> AuditReport:
    engine = tiny_engine(arch)
    results = [
        audit_recompiles(arch, max_len=max_len),
        audit_donation(arch, engine=engine),
        # quantized-layout donation needs an arch that *has* an attention
        # KV cache to encode; DEFAULT_ARCH (retnet) is attention-free.
        audit_donation(KERNEL_ARCH, cache_dtype="int8_tok"),
        audit_transfers(arch, engine=engine),
        audit_sharding(arch, mesh_spec=mesh_spec),
        audit_decode_kernel(),
        audit_observability(arch),
        # Prefix adoption needs a *pageable* (dense-attention) cache;
        # DEFAULT_ARCH (retnet) takes the snapshot path instead.
        audit_prefix_reuse(KERNEL_ARCH),
    ]
    return AuditReport(results)
