"""CLI for the repro invariant checker.

    python -m repro.analysis              # lint (Layer 1; no jax import)
    python -m repro.analysis lint -v      # per-file findings
    python -m repro.analysis lint --update-baseline
    python -m repro.analysis audit        # program audit (Layer 2; runs jax)
    python -m repro.analysis audit --mesh 2,2 --arch retnet-1.3b

Exit status is 0 iff every check passes — both are CI gates
(`make lint-invariants`, `make audit-program`).
"""

from __future__ import annotations

import argparse
import json
import sys


def _cmd_lint(ns) -> int:
    from repro.analysis import lint

    if ns.list_rules:
        print(lint.list_rules())
        return 0
    return lint.run(root=ns.root, baseline_path=ns.baseline,
                    update_baseline=ns.update_baseline, verbose=ns.verbose)


def _cmd_audit(ns) -> int:
    from repro.analysis import program_audit

    report = program_audit.run_audits(ns.arch, mesh_spec=ns.mesh,
                                      max_len=ns.max_len)
    if ns.json:
        print(json.dumps(report.to_dict(), indent=2))
    else:
        print(report.render())
    return 0 if report.ok else 1


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant checker: AST lint + program audit")
    sub = parser.add_subparsers(dest="cmd")

    p_lint = sub.add_parser("lint", help="Layer-1 AST lint of src/repro")
    p_lint.add_argument("--root", default=None,
                        help="tree to lint (default: the repro package)")
    p_lint.add_argument("--baseline", default=None,
                        help="baseline file (default: analysis/baseline.json)")
    p_lint.add_argument("--update-baseline", action="store_true",
                        help="grandfather all current findings and exit 0")
    p_lint.add_argument("--list-rules", action="store_true")
    p_lint.add_argument("-v", "--verbose", action="store_true")

    p_audit = sub.add_parser("audit",
                             help="Layer-2 jaxpr/HLO audit of the hot path")
    p_audit.add_argument("--arch", default="retnet-1.3b")
    p_audit.add_argument("--mesh", default="2,2",
                         help="data,model mesh for the sharding audit")
    p_audit.add_argument("--max-len", type=int, default=24,
                         help="prompt-length sweep bound for the recompile "
                              "audit")
    p_audit.add_argument("--json", action="store_true")

    ns = parser.parse_args(argv)
    if ns.cmd == "audit":
        return _cmd_audit(ns)
    if ns.cmd is None:                    # bare `python -m repro.analysis`
        ns = p_lint.parse_args([])
    return _cmd_lint(ns)


if __name__ == "__main__":
    sys.exit(main())
