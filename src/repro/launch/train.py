"""End-to-end training driver: data pipeline -> pjit step -> checkpointing ->
fault tolerance (heartbeats, straggler watch, failure injection, elastic
re-mesh restore).

Runs at any scale: CPU smoke (``--arch internlm2-1.8b --reduced --steps 20``)
up to the production mesh.  The control loop is the production shape:

    for step in range(start, total):
        batch   <- pipeline.batch(step)                (deterministic resume)
        state   <- jit_step(state, batch)              (donated)
        monitor <- heartbeats + straggler check        (simulated hosts)
        failure -> save + plan_elastic_mesh + restore  (elastic path)
        every K -> async checkpoint

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch retnet-1.3b --reduced \
        --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.compat import jit_sharded, make_auto_mesh
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch.mesh import make_mesh_by_name
from repro.optim import adamw
from repro.runtime import checkpoint as ckpt_lib
from repro.runtime import fault_tolerance as ft
from repro.runtime import sharding as shd
from repro.runtime import train_step as ts


def build(cfg, mesh, opt_cfg, opts):
    built = ts.build_train_step(cfg, mesh, opt_cfg=opt_cfg, opts=opts)
    jit_step = jit_sharded(built["step"],
                           in_shardings=(built["state_shardings"], None),
                           out_shardings=(built["state_shardings"], None),
                           donate_argnums=(0,))
    return built, jit_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default=None, help="single|multi|tiny|tiny_multi")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a simulated host failure at this step")
    ap.add_argument("--n-hosts", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=5,
                                total_steps=args.steps)
    opts = ts.TrainOptions(microbatches=args.microbatches,
                           compress_grads=args.compress_grads)

    mesh = make_mesh_by_name(args.mesh) if args.mesh else None
    if mesh is None:
        mesh = make_auto_mesh((1, 1), ("data", "model"))
    built, jit_step = build(cfg, mesh, opt_cfg, opts)

    data = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    mgr = ckpt_lib.CheckpointManager(args.ckpt_dir, keep_n=3)

    state = built["init_state"](jax.random.key(0))
    start = 0
    if args.resume and mgr.latest_step() is not None:
        state, manifest = mgr.restore(state, shardings=built["state_shardings"])
        start = manifest["step"] + 1
        print(f"[train] resumed from step {manifest['step']}")

    hosts = [f"host{i}" for i in range(args.n_hosts)]
    monitor = ft.HeartbeatMonitor(hosts, timeout_s=10.0)
    stragglers = ft.StragglerDetector()
    injector = ft.FailureInjector(
        {args.fail_at: [hosts[-1]]} if args.fail_at >= 0 else {})

    losses = []
    ctx = shd.sharding_ctx(mesh, built["policy"])
    ctx.__enter__()
    try:
        for step in range(start, args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            state, metrics = jit_step(state, batch)
            dt = time.time() - t0
            for h in monitor.alive_hosts():
                monitor.beat(h)
                stragglers.record(h, dt * (1.0 + 0.01 * hash(h) % 3 / 100))

            failed = injector.maybe_fail(step, monitor)
            dead = monitor.check()
            if failed or dead:
                print(f"[train] step {step}: hosts failed: {dead}; "
                      "checkpoint + elastic re-mesh")
                mgr.save(step, state, extra={"reason": "failure"},
                         blocking=True)
                alive_chips = mesh.size * len(monitor.alive_hosts()) // len(hosts)
                plan = ft.plan_elastic_mesh(
                    max(alive_chips, mesh.shape["model"]),
                    model_parallel=mesh.shape["model"])
                print(f"[train] elastic plan: {plan}")
                mesh = make_auto_mesh(plan.shape, plan.axes)
                built, jit_step = build(cfg, mesh, opt_cfg, opts)
                state, _ = mgr.restore(built["init_state"](jax.random.key(0)),
                                       shardings=built["state_shardings"])
                hosts = monitor.alive_hosts()
                monitor = ft.HeartbeatMonitor(hosts, timeout_s=10.0)
                ctx.__exit__(None, None, None)       # re-enter on the new mesh
                ctx = shd.sharding_ctx(mesh, built["policy"])
                ctx.__enter__()

            loss = float(metrics["loss"])
            losses.append(loss)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"[train] step {step:4d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            slow = stragglers.stragglers()
            if slow:
                print(f"[train] stragglers detected: {slow}")
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                mgr.save(step, state, blocking=False)
    finally:
        ctx.__exit__(None, None, None)

    mgr.save(args.steps - 1, state, blocking=True)
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"[train] done. loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
