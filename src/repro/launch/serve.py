"""End-to-end serving CLI — thin shim over `repro.serving.InferenceEngine`.

Implements the paper's edge serving flow at any scale: quantize the model
(SmoothQuant + MXINT4, Section III), prefill the prompt in the W8A8 MMM
dataflow, then autoregressively decode in the W4A8 MVM dataflow — the decode
loop fused into one jitted `lax.while_loop` by the engine.  Batched requests;
LISO/SILO scenario presets matching the paper's evaluation.

All wiring lives in `repro.serving`; this module only parses flags and keeps
the historical `generate(...)` entry point for existing callers.

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch retnet-1.3b --reduced \
        --scenario SILO --scale 0.1 --batch 2

Continuous-batching mode (`--requests N`) drives the `RequestScheduler`
instead: N staggered requests with mixed prompt lengths are chunk-admitted
(`--chunk-size`) into a paged cache pool while resident lanes decode — the
paper's sequencer behavior, with per-step stats printed at the end.
`--host-spill` (optionally with `--oversubscribe R`) turns on the pool's
host-memory tier: a late high-priority burst preempts resident lanes to CPU
DRAM, and they resume bit-exactly once device lanes free up.
`--prefix-cache` turns on shared-prefix reuse and reshapes the stream into
the repeated-system-prompt workload it targets: every request opens with one
shared prefix, later admissions adopt it from the page index and prefill
only their unique tail (hit stats printed at the end).

`--frontend` switches to the open-loop asyncio front end: seeded Poisson or
bursty arrivals (`--rate`, `--arrival`) submitted through SLO-aware
admission (`--ttft-slo`), goodput and shed rate reported at the end.
`--virtual-clock` runs it on deterministic virtual time — wall-clock-free —
and asserts the CI smoke contract (`make smoke-frontend`): nonzero goodput,
zero unexplained sheds.

`--trace FILE` records the full request lifecycle (submit → admit → prefill
chunks → first token → decode → preempt/resume → finish) through `repro.obs`
and writes Chrome-trace-event JSON loadable in Perfetto; `--metrics FILE`
dumps the run's counter/gauge/percentile-histogram snapshot.  `make
trace-demo` produces both from an oversubscribed scheduler run.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import edge_model
from repro.core.hsa import HSAEngine
from repro.models.config import ModelConfig
from repro.obs import Observability, Tracer
from repro.serving import (EngineSpec, GenerationConfig, InferenceEngine,
                           Request, RequestScheduler, SamplingParams,
                           SpeculativeConfig)


def generate(cfg: ModelConfig, params, engine: HSAEngine, prompts: jax.Array,
             n_out: int, greedy: bool = True, key=None):
    """Legacy entry point: prefill + fused decode loop.

    prompts [B, S_in] -> (tokens [B, n_out], t_prefill_s, t_decode_s).
    Deprecated shim — construct an `InferenceEngine` directly instead.
    """
    eng = InferenceEngine(cfg, params, EngineSpec(), hsa=engine)
    sampling = SamplingParams() if greedy else SamplingParams(temperature=1.0)
    res = eng.generate(prompts,
                       GenerationConfig(max_new_tokens=n_out,
                                        sampling=sampling),
                       key=key)
    return res.tokens, res.prefill_s, res.decode_s


def _run_scheduler_demo(engine: InferenceEngine, args,
                        n_in: int, n_out: int) -> None:
    """Sequencer demo: mixed-length prompts chunk-admitted into a paged pool
    (a small + a large cache class) while resident lanes decode."""
    import time

    cfg = engine.cfg
    spec = (SpeculativeConfig(k=args.draft_k) if args.speculative else None)
    gen = GenerationConfig(
        max_new_tokens=n_out,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p),
        speculative=spec)
    rng = np.random.default_rng(0)
    if args.prefix_cache:
        # The repeated-system-prompt workload prefix reuse targets: uniform
        # full-length prompts, each opening with the same shared prefix long
        # enough to clear the one-page adoption floor.
        lengths = [n_in] * args.requests
        shared_len = min(n_in - 1, max(16, int(n_in * 0.75)))
        shared = jax.random.randint(jax.random.key(5), (shared_len,), 1,
                                    cfg.vocab_size, dtype=jnp.int32).tolist()
    else:
        lengths = [max(2, int(n_in * f)) for f in
                   rng.choice([0.25, 0.5, 1.0], size=args.requests)]
    extra = spec.k if spec else 0        # verify blocks overrun by k slots
    small = max(2, int(n_in * 0.5)) + n_out + extra
    large = n_in + n_out + extra
    # A 1-slot pool cannot split into two classes (the max(1, ...) guards
    # would silently double it, under-delivering --oversubscribe's ratio).
    classes = ([(args.slots, large)] if small >= large or args.slots < 2 else
               [(args.slots // 2, small),
                (args.slots - args.slots // 2, large)])
    sched = RequestScheduler(engine, classes=classes, gen=gen,
                             chunk_size=args.chunk_size,
                             host_spill=args.host_spill,
                             prefix_cache=args.prefix_cache,
                             key=jax.random.key(2), obs=engine.obs)

    def make_request(uid: int, s: int) -> Request:
        prompt = jax.random.randint(jax.random.fold_in(jax.random.key(1), uid),
                                    (s,), 1, cfg.vocab_size, dtype=jnp.int32)
        tokens = prompt.tolist()
        if args.prefix_cache:
            tokens = shared + tokens[len(shared):]
        return Request(uid=uid, prompt=tokens)

    print(f"[serve] scheduler: {args.requests} requests, prompt lengths "
          f"{sorted(set(lengths))}, classes {classes}, "
          f"chunk={args.chunk_size}"
          + (", host-spill preemption on" if args.host_spill else "")
          + (f", prefix-cache on ({len(shared)}-token shared prefix)"
             if args.prefix_cache else ""))
    t0 = time.perf_counter()
    if args.host_spill and args.requests > 1:
        # Oversubscription demo: fill the pool with default-priority
        # residents first, then a late high-priority burst that preempts
        # them into the host tier (they resume once lanes free up).
        n_burst = max(1, args.requests // 3)
        for uid, s in list(enumerate(lengths))[:-n_burst]:
            sched.submit(make_request(uid, s))
        while sched.stats["admitted"] < min(args.requests - n_burst,
                                            sched.pool.n_slots):
            sched.step()
        for uid, s in list(enumerate(lengths))[-n_burst:]:
            sched.submit(make_request(uid, s), priority=1)
    else:
        for uid, s in enumerate(lengths):
            sched.submit(make_request(uid, s))
    results = sched.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results.values()) + sum(lengths)
    print(f"[serve] {sched.stats['steps']} cycles, "
          f"{sched.stats['prefill_chunks']} prefill chunks, "
          f"{engine.prefill_compiles} prefill compiles, "
          f"{sched.stats['decode_stall_steps']} decode-stall steps")
    if args.host_spill:
        ss = sched.pool.spill_stats
        print(f"[serve] host tier: {sched.stats['preempted']} preempted / "
              f"{sched.stats['resumed']} resumed, {ss['spills']} spills "
              f"({ss['bytes_to_host']} B to host), {ss['fetches']} fetches "
              f"({ss['bytes_to_device']} B back)")
    if args.prefix_cache:
        px = sched.pool.prefix
        ps = px.stats
        print(f"[serve] prefix cache [{px.mode}]: {ps['prefix_hits']}/"
              f"{ps['prefix_lookups']} admissions adopted a cached prefix, "
              f"{ps['prefix_hit_tokens']} prefill tokens skipped, "
              f"{px.n_pages} pages resident "
              f"({ps['cow_copies']} COW copies)")
    if spec:
        for uid in sorted(results):
            r = results[uid]
            print(f"[serve]   req {uid}: {len(r.tokens)} tokens in "
                  f"{r.verify_steps} verify steps "
                  f"({r.tokens_per_step:.2f} tokens/step, "
                  f"{r.accepted_drafts} drafts accepted)")
        vs = max(1, sched.stats["verify_steps"])
        print(f"[serve] speculative: {sched.stats['accepted_drafts']} drafts "
              f"accepted over {vs} verify steps "
              f"({1 + sched.stats['accepted_drafts'] / vs:.2f} tokens/step)")
    print(f"[serve] tokens/s (paper convention, prompt+output): "
          f"{total / dt:.2f}")


def _run_frontend_demo(engine: InferenceEngine, args,
                       n_in: int, n_out: int) -> None:
    """Open-loop front-end demo: seeded arrivals (`--rate`, `--arrival`)
    submitted through the asyncio `ServingFrontend` with SLO-aware admission
    (`--ttft-slo`), reporting goodput / shed rate.  With `--virtual-clock`
    the run is wall-clock-free and deterministic, and doubles as the CI
    smoke contract: nonzero goodput, zero unexplained sheds."""
    from repro.serving import (BurstyArrivals, FrontendConfig, LengthMix,
                               MonotonicClock, PoissonArrivals,
                               ServingFrontend, VirtualClock, Workload,
                               run_open_loop)

    cfg = engine.cfg
    n_req = args.requests if args.requests > 0 else 8
    clock = VirtualClock() if args.virtual_clock else MonotonicClock()
    gen = GenerationConfig(
        max_new_tokens=n_out,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p))
    mix = LengthMix(prompt_min=max(2, n_in // 4), prompt_max=n_in,
                    new_min=max(2, n_out // 2), new_max=n_out)
    sched = RequestScheduler(engine, classes=[(args.slots, n_in + n_out)],
                             gen=gen, chunk_size=args.chunk_size,
                             prefix_cache=args.prefix_cache,
                             key=jax.random.key(2), obs=engine.obs,
                             clock=clock.now)
    frontend = ServingFrontend(
        sched, config=FrontendConfig(ttft_slo_s=args.ttft_slo, journal=True),
        clock=clock)
    arrivals = (BurstyArrivals(args.rate) if args.arrival == "bursty"
                else PoissonArrivals(args.rate))
    workload = Workload(arrivals=arrivals, lengths=mix, n_requests=n_req,
                        vocab_size=cfg.vocab_size, seed=4)

    async def drive():
        async with frontend:
            return await run_open_loop(frontend, workload)

    print(f"[serve] frontend: {n_req} open-loop requests, {args.arrival} "
          f"arrivals at {args.rate:.1f} req/s, TTFT SLO {args.ttft_slo:.2f}s, "
          f"{'virtual' if args.virtual_clock else 'monotonic'} clock")
    report = clock.run(drive())
    print(f"[serve] elapsed {report.elapsed_s:.3f}s"
          f"{' (virtual)' if args.virtual_clock else ''}: "
          f"{report.completed}/{report.n_requests} completed, "
          f"{report.met_slo} met SLO -> goodput {report.goodput_rps:.2f} "
          f"req/s, shed rate {report.shed_rate:.2f}")
    ttft = report.to_dict().get("ttft")
    if ttft:
        print(f"[serve] TTFT p50/p95/p99: {ttft['p50']:.4f}/"
              f"{ttft['p95']:.4f}/{ttft['p99']:.4f} s")
    if args.virtual_clock:
        # The smoke contract `make smoke-frontend` relies on.
        if report.goodput_rps <= 0:
            raise SystemExit("[serve] frontend smoke FAILED: zero goodput")
        if report.sheds_unexplained:
            raise SystemExit(f"[serve] frontend smoke FAILED: "
                             f"{report.sheds_unexplained} unexplained sheds")
        print(f"[serve] frontend smoke OK: goodput {report.goodput_rps:.2f} "
              f"req/s, 0 unexplained sheds, {len(frontend.journal)} journal "
              f"events")


def _export_obs(obs: Observability, args) -> None:
    """Write the run's trace / metrics artifacts, when asked for."""
    if args.trace:
        obs.tracer.export(args.trace)
        print(f"[serve] trace: {len(obs.tracer.events)} events -> "
              f"{args.trace} (open in Perfetto / chrome://tracing)")
    if args.metrics:
        import json
        with open(args.metrics, "w") as f:
            json.dump(obs.metrics.snapshot(), f, indent=2)
            f.write("\n")
        print(f"[serve] metrics snapshot -> {args.metrics}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scenario", choices=["LISO", "SILO"], default="SILO")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale LISO/SILO token counts (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--no-quant", action="store_true",
                    help="serve fp master weights (ablation)")
    ap.add_argument("--unfused-norm", action="store_true",
                    help="disable the Eq.(4) fused RMSNorm (ablation)")
    ap.add_argument("--requests", type=int, default=0,
                    help="> 0: continuous-batching scheduler demo with this "
                         "many mixed-length requests")
    ap.add_argument("--slots", type=int, default=4,
                    help="scheduler mode: decode lanes in the cache pool")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="scheduler mode: prefill chunk size (tokens/cycle)")
    ap.add_argument("--speculative", action="store_true",
                    help="scheduler mode: multi-token speculative decode "
                         "(ngram drafter) — prints per-request acceptance")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative mode: drafted tokens per verify step")
    ap.add_argument("--host-spill", action="store_true",
                    help="scheduler mode: enable the host-memory spill tier "
                         "— a late high-priority burst preempts resident "
                         "lanes to CPU DRAM instead of queueing behind them")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="scheduler mode: shared-prefix reuse — every "
                         "request opens with one shared system prompt; "
                         "later admissions adopt its cached pages and "
                         "prefill only their unique tail (hit stats "
                         "printed at the end)")
    ap.add_argument("--frontend", action="store_true",
                    help="open-loop asyncio front-end demo: seeded arrivals "
                         "(--rate/--arrival) through SLO-aware admission "
                         "(--ttft-slo), goodput + shed rate printed at the "
                         "end; --requests sets the request count (default 8)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="frontend mode: offered load, requests/second")
    ap.add_argument("--arrival", choices=["poisson", "bursty"],
                    default="poisson",
                    help="frontend mode: arrival process (bursty = 2-state "
                         "Markov-modulated Poisson at the same mean rate)")
    ap.add_argument("--ttft-slo", type=float, default=2.0,
                    help="frontend mode: TTFT SLO target in seconds — the "
                         "admission policy sheds while the windowed p99 "
                         "breaches it")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="frontend mode: run on deterministic virtual time "
                         "(wall-clock-free; the CI smoke contract)")
    ap.add_argument("--oversubscribe", type=float, default=0.0,
                    help="scheduler mode: request-to-lane ratio — shrinks "
                         "the pool to ~requests/R device lanes so demand "
                         "exceeds device capacity (pair with --host-spill)")
    ap.add_argument("--mesh", default=None,
                    help="serve sharded on a device mesh: 'dp,tp' (e.g. 2,2 "
                         "— axes data,model) or a named mesh from "
                         "launch.mesh; needs dp*tp devices (CPU smoke: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="record the request-lifecycle trace and write it to "
                         "FILE as Chrome-trace-event JSON (load in Perfetto "
                         "or chrome://tracing; `make trace-demo` shows one)")
    ap.add_argument("--metrics", metavar="FILE", default=None,
                    help="write the run's metrics-registry snapshot "
                         "(counters, gauges, p50/p95/p99 histograms) to "
                         "FILE as JSON")
    args = ap.parse_args()
    if args.oversubscribe:
        if args.oversubscribe <= 1.0:
            ap.error("--oversubscribe is a request-to-lane ratio and must "
                     "be > 1.0 (omit it to disable)")
        if args.requests > 0:
            args.slots = max(1, round(args.requests / args.oversubscribe))

    scen = edge_model.LISO if args.scenario == "LISO" else edge_model.SILO
    n_in = max(2, int(scen.tokens_in * args.scale))
    n_out = max(2, int(scen.tokens_out * args.scale))

    spec = EngineSpec(quantize=not args.no_quant, reduced=args.reduced,
                      fuse_rmsnorm=not args.unfused_norm)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.mesh)
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        print(f"[serve] mesh: {axes} over {mesh.size} "
              f"{mesh.devices.flat[0].platform} devices "
              f"(params + cache sharded per ServeCell)")
    # One bundle across the engine + scheduler + pool: the trace interleaves
    # engine phases with per-request lifecycle tracks, and the metrics
    # snapshot carries every component's counters under one registry.
    obs = Observability()
    if args.trace:
        obs.tracer = Tracer()
    engine = InferenceEngine.from_config(args.arch, spec, mesh=mesh, obs=obs)
    cfg = engine.cfg
    if args.frontend:
        _run_frontend_demo(engine, args, n_in, n_out)
        return _export_obs(obs, args)
    if args.requests > 0:
        _run_scheduler_demo(engine, args, n_in, n_out)
        return _export_obs(obs, args)
    print(f"[serve] {cfg.name} scenario={scen.name} in/out={n_in}/{n_out} "
          f"batch={args.batch}")
    if not args.no_quant:
        print("[serve] deployed: W8A8 prefill / MXINT4 (4.25b) decode weights")

    prompts = jax.random.randint(jax.random.key(1), (args.batch, n_in), 1,
                                 cfg.vocab_size, dtype=jnp.int32)
    gen = GenerationConfig(
        max_new_tokens=n_out,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p),
        speculative=(SpeculativeConfig(k=args.draft_k)
                     if args.speculative else None))
    res = engine.generate(prompts, gen, key=jax.random.key(2))
    if args.speculative:
        print(f"[serve] speculative: {res.verify_steps} verify steps, "
              f"{res.accepted_drafts}/{res.drafted} drafts accepted "
              f"({res.tokens_per_step:.2f} tokens/step)")
    total = n_in + n_out
    t_p, t_d = res.prefill_s, res.decode_s
    print(f"[serve] prefill {t_p*1e3:.0f} ms, decode {t_d*1e3:.0f} ms "
          f"({t_d/n_out*1e3:.1f} ms/token)")
    print(f"[serve] {scen.name} tokens/s (paper convention, prompt+output): "
          f"{args.batch * total / (t_p + t_d):.2f}")
    print(f"[serve] sample output tokens: {np.asarray(res.tokens[0, :16])}")
    _export_obs(obs, args)


if __name__ == "__main__":
    main()
