"""End-to-end serving driver: PTQ deploy -> prefill (MMM) -> decode loop (MVM).

Implements the paper's edge serving flow at any scale: quantize the model
(SmoothQuant + MXINT4, Section III), prefill the prompt in the W8A8 MMM
dataflow, then autoregressively decode in the W4A8 MVM dataflow with the
online RoPE unit advancing per token.  Batched requests; LISO/SILO scenario
presets matching the paper's evaluation.

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch retnet-1.3b --reduced \
        --scenario SILO --scale 0.1 --batch 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import edge_model
from repro.core.hsa import HSAConfig, HSAEngine
from repro.models import deploy, lm
from repro.models.config import ModelConfig


def generate(cfg: ModelConfig, params, engine: HSAEngine, prompts: jax.Array,
             n_out: int, greedy: bool = True, key=None):
    """Prefill + decode loop.  prompts [B, S_in] -> tokens [B, n_out]."""
    b, s_in = prompts.shape
    cache_len = s_in + n_out

    prefill = jax.jit(lambda p, t: lm.forward_prefill(
        p, {"tokens": t}, cfg, engine, cache_len=cache_len))
    decode = jax.jit(lambda p, t, c: lm.forward_decode(p, t, c, cfg, engine))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(n_out):
        outs.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return jnp.concatenate(outs, axis=1), t_prefill, t_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scenario", choices=["LISO", "SILO"], default="SILO")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale LISO/SILO token counts (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--no-quant", action="store_true",
                    help="serve fp master weights (ablation)")
    ap.add_argument("--unfused-norm", action="store_true",
                    help="disable the Eq.(4) fused RMSNorm (ablation)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    scen = edge_model.LISO if args.scenario == "LISO" else edge_model.SILO
    n_in = max(2, int(scen.tokens_in * args.scale))
    n_out = max(2, int(scen.tokens_out * args.scale))

    print(f"[serve] {cfg.name} scenario={scen.name} in/out={n_in}/{n_out} "
          f"batch={args.batch}")
    params, axes, paths = lm.init(cfg, jax.random.key(0))
    if not args.no_quant:
        params = deploy.deploy_quantize(params, paths)
        print("[serve] deployed: W8A8 prefill / MXINT4 (4.25b) decode weights")
    engine = HSAEngine(HSAConfig(
        prefill_format="fp" if args.no_quant else "w8a8",
        decode_format="fp" if args.no_quant else "mxint4",
        fuse_rmsnorm=not args.unfused_norm))

    prompts = jax.random.randint(jax.random.key(1), (args.batch, n_in), 1,
                                 cfg.vocab_size, dtype=jnp.int32)
    toks, t_p, t_d = generate(cfg, params, engine, prompts, n_out)
    total = n_in + n_out
    print(f"[serve] prefill {t_p*1e3:.0f} ms, decode {t_d*1e3:.0f} ms "
          f"({t_d/n_out*1e3:.1f} ms/token)")
    print(f"[serve] {scen.name} tokens/s (paper convention, prompt+output): "
          f"{args.batch * total / (t_p + t_d):.2f}")
    print(f"[serve] sample output tokens: {np.asarray(toks[0,:16])}")


if __name__ == "__main__":
    main()
