"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before *any* jax initialization.
"""

from __future__ import annotations

from repro.compat import make_auto_mesh


def _mesh(shape, axes):
    return make_auto_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    single-pod: 16 x 16 = 256 chips  (axes: data, model)
    multi-pod : 2 x 16 x 16 = 512 chips (axes: pod, data, model);
                the 'pod' axis crosses the DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """4/8-device mesh for CI-scale subprocess tests of the same code path."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_by_name(name: str):
    return {
        "single": lambda: make_production_mesh(multi_pod=False),
        "multi": lambda: make_production_mesh(multi_pod=True),
        # 8-pod scale-out (2048 chips) — the ds-v3 feasibility point (§Perf A)
        "pod8": lambda: _mesh((8, 16, 16), ("pod", "data", "model")),
        "tiny": lambda: make_tiny_mesh(multi_pod=False),
        "tiny_multi": lambda: make_tiny_mesh(multi_pod=True),
    }[name]()
