"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before *any* jax initialization.
"""

from __future__ import annotations

from repro.compat import make_auto_mesh


def _mesh(shape, axes):
    return make_auto_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    single-pod: 16 x 16 = 256 chips  (axes: data, model)
    multi-pod : 2 x 16 x 16 = 512 chips (axes: pod, data, model);
                the 'pod' axis crosses the DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """4/8-device mesh for CI-scale subprocess tests of the same code path."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_by_name(name: str):
    return {
        "single": lambda: make_production_mesh(multi_pod=False),
        "multi": lambda: make_production_mesh(multi_pod=True),
        # 8-pod scale-out (2048 chips) — the ds-v3 feasibility point (§Perf A)
        "pod8": lambda: _mesh((8, 16, 16), ("pod", "data", "model")),
        "tiny": lambda: make_tiny_mesh(multi_pod=False),
        "tiny_multi": lambda: make_tiny_mesh(multi_pod=True),
    }[name]()


def make_serving_mesh(spec: str):
    """Mesh for `serve.py --mesh`: a named mesh, or an explicit ``dp,tp``
    (also ``dpXtp``) shape over the (data, model) axes — ``data`` replicates
    the weight stream across request groups, ``model`` tensor-shards it
    (heads/mlp/vocab) plus the cache length axis where divisible.

    A bare integer means pure tensor parallelism (``1,tp``): the common
    multi-chip edge deployment where one request's weight stream is split
    across chips rather than batched.
    """
    import re

    try:
        return make_mesh_by_name(spec.strip())
    except KeyError:
        pass
    try:
        parts = [int(p) for p in re.split(r"[x,]", spec.strip().lower()) if p]
    except ValueError:
        parts = []
    if len(parts) == 1:
        parts = [1, parts[0]]
    if len(parts) != 2 or any(p < 1 for p in parts):
        raise ValueError(f"--mesh wants 'dp,tp' (e.g. 2,2) or a named mesh, "
                         f"got {spec!r}")
    dp, tp = parts
    return _mesh((dp, tp), ("data", "model"))
