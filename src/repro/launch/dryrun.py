import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the scale proof without hardware: 512 virtual host devices stand in
for 2 TPU pods, `jax.jit(step).lower(...).compile()` must succeed for every
cell, `memory_analysis()` proves the per-device footprint fits, and
`cost_analysis()` + the partitioned HLO text feed the §Roofline terms
(FLOPs, bytes, per-collective wire traffic).

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>[__policy].json``
— benchmarks/roofline.py consumes them.  Already-present artifacts are
skipped unless --force (the grid is resumable).

Usage:
    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh single,multi
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.compat import jit_sharded
from repro.launch.mesh import make_mesh_by_name
from repro.models import lm
from repro.models.config import InputShape, ModelConfig
from repro.optim import adamw
from repro.runtime import serve_step, sharding as shd, train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
             "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
             "s8": 1, "u8": 1, "pred": 1}
for _k in ("f8e4m3fn", "f8e5m2", "f8e4m3", "f8e3m4", "f8e8m0fnu"):
    _DT_BYTES[_k] = 1


def _result_bytes(line: str) -> int:
    """Size of the op's result tuple/array from the lhs of the HLO line."""
    lhs = line.split(" = ", 1)[0] if " = " in line else line
    # result types actually appear after '=': `%x = (f32[..], ..) all-reduce(...`
    rhs = line.split(" = ", 1)[1] if " = " in line else line
    head = rhs.split("(", 2)  # result type may itself be a tuple
    # take everything up to the op name occurrence
    m = _COLL_RE.search(rhs)
    type_str = rhs[: m.start()] if m else rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES.get(dt, 4)
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str]:
    """Map computation name -> its lines; returns (comps, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = ""
    cur: list[str] | None = None
    name = ""
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if m:
            name = m.group(2).lstrip("%")
            cur = []
            comps[name] = cur
            if m.group(1):
                entry = name
        elif line.strip() == "}":
            cur = None
        elif cur is not None:
            cur.append(line)
    return comps, entry


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-op-type wire bytes per chip (ring model) from partitioned HLO,
    **trip-count corrected**: XLA's cost analysis counts a while (lax.scan)
    body once, so we walk the call graph — each while's condition computation
    carries the trip count as its comparison constant — and multiply the
    body's collectives by the product of enclosing trip counts.  (Verified:
    EXPERIMENTS.md §Dry-run methodology.)
    """
    comps, entry = _split_computations(hlo_text)
    if not entry:
        comps, entry = {"": hlo_text.splitlines()}, ""

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for line in comps.get(cond_name, ())
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    stats: dict[str, dict] = {}

    def visit(comp: str, mult: float) -> None:
        # HLO call graphs are DAGs (no recursion); multiple call sites of one
        # body are legitimately counted once per site.
        for line in comps.get(comp, ()):
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                visit(body, mult * trip_count(cond))
                continue
            m = _COLL_RE.search(line)
            if not m:
                continue
            op = m.group(1)
            size = _result_bytes(line)
            g = _group_size(line, n_devices)
            if g <= 1:
                continue
            ring = (g - 1) / g
            if op == "all-reduce":
                wire = 2 * size * ring
            elif op == "collective-permute":
                wire = size
            else:                  # all-gather / reduce-scatter / all-to-all
                wire = size * ring
            s = stats.setdefault(op, {"count": 0.0, "bytes": 0.0,
                                      "wire_bytes": 0.0})
            s["count"] += mult
            s["bytes"] += size * mult
            s["wire_bytes"] += wire * mult
    visit(entry, 1.0)
    return stats


def _memory_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        out["total_size_in_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    except Exception as e:          # noqa: BLE001
        out["error"] = repr(e)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:          # noqa: BLE001
        return {"error": repr(e)}


# §Perf hillclimb variants: named deltas on top of the baseline.
#   bf16act       — engine out_dtype bfloat16 (activation collectives halve)
#   serve_repl    — serving weights replicated over the DP axes (no per-step
#                   FSDP all-gather of quantized weights during decode)
#   kv8           — int8 KV cache (serve_repl +) halves cache HBM reads
#   micro8        — 8 grad-accumulation microbatches (train)
VARIANTS = ("baseline", "bf16act", "serve_repl", "kv8", "micro8", "micro2")


def lower_cell(cfg: ModelConfig, shape: InputShape, mesh,
               policy: shd.ShardingPolicy, variant: str = "baseline"):
    """Build + lower one cell.  Returns (lowered, extra_info)."""
    import jax.numpy as _jnp

    from repro.core.hsa import HSAConfig, HSAEngine

    specs = configs.input_specs(cfg, shape)
    engine = None
    if variant == "bf16act":
        engine = HSAEngine(HSAConfig(out_dtype="bfloat16"))
    if variant in ("serve_repl", "kv8") and shape.kind != "train":
        policy = policy.with_rule("embed", ())     # no FSDP on serve params
    cache_dtype = _jnp.int8 if variant == "kv8" else _jnp.bfloat16

    if shape.kind == "train":
        # Per-scale training overrides: 100B+ models use bf16 moments and
        # gradient accumulation (activation working set / microbatches).
        big = cfg.d_model >= 7000
        opt_cfg = adamw.AdamWConfig(
            moment_dtype="bfloat16" if big else "float32")
        micro = {"micro8": 8, "micro2": 2}.get(variant, 4 if big else 1)
        opts = train_step.TrainOptions(microbatches=micro)
        built = train_step.build_train_step(cfg, mesh, policy=policy,
                                            opt_cfg=opt_cfg, opts=opts,
                                            engine=engine)
        batch_sh = built["batch_shardings"](specs)
        jit_step = jit_sharded(built["step"],
                               in_shardings=(built["state_shardings"],
                                             batch_sh),
                               out_shardings=(built["state_shardings"], None),
                               donate_argnums=(0,))
        with shd.sharding_ctx(mesh, policy):
            lowered = jit_step.lower(built["state_shapes"], specs)
        return lowered

    cell = serve_step.build_serve(cfg, mesh, shape, policy=policy,
                                  cache_dtype=cache_dtype)
    if shape.kind == "prefill":
        batch_sh = shd.shardings_from_specs(
            shd.batch_specs(specs, mesh, policy), mesh)
        jit_fn = jit_sharded(cell.prefill,
                             in_shardings=(cell.param_shardings, batch_sh),
                             out_shardings=(None, cell.cache_shardings))
        with shd.sharding_ctx(mesh, policy):
            return jit_fn.lower(cell.param_shapes, specs)

    # decode
    tok_sh = shd.shardings_from_specs(
        shd.batch_specs(specs, mesh, policy), mesh)["tokens"]
    jit_fn = jit_sharded(cell.decode,
                         in_shardings=(cell.param_shardings, tok_sh,
                                       cell.cache_shardings),
                         out_shardings=(None, cell.cache_shardings),
                         donate_argnums=(2,))
    with shd.sharding_ctx(mesh, policy):
        return jit_fn.lower(cell.param_shapes, specs["tokens"],
                            cell.cache_shapes)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             policy: shd.ShardingPolicy | None = None,
             variant: str = "baseline", out_dir: str = ARTIFACT_DIR,
             force: bool = False, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__{variant}" if variant != "baseline" else "")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "policy": variant}

    ok, reason = configs.cell_supported(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        if verbose:
            print(f"[dryrun] SKIP {tag}: {reason}")
        return record

    mesh = make_mesh_by_name(mesh_name)
    policy = policy or shd.ShardingPolicy()
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, policy, variant=variant)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = _memory_dict(compiled)
        cost = _cost_dict(compiled)
        print(f"[dryrun] {tag}: memory_analysis: {mem}")
        print(f"[dryrun] {tag}: cost_analysis: "
              f"flops={cost.get('flops')} bytes={cost.get('bytes accessed')}")
        try:
            hlo = compiled.as_text()
        except Exception:            # noqa: BLE001
            hlo = lowered.as_text()
        colls = parse_collectives(hlo, mesh.size)
        record.update(
            status="ok", n_devices=int(mesh.size),
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=mem, cost=cost, collectives=colls,
            hlo_bytes=len(hlo),
        )
    except Exception as e:           # noqa: BLE001
        record.update(status="error", error=repr(e),
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] ERROR {tag}: {e}")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose and record["status"] == "ok":
        per_dev = record["memory"].get("total_size_in_bytes", 0) / 1e9
        print(f"[dryrun] OK {tag}: {per_dev:.2f} GB/device, "
              f"lower {record['lower_s']}s compile {record['compile_s']}s")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=VARIANTS)
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    meshes = args.mesh.split(",")
    if args.all:
        cells = [(a, s.name) for a in configs.ASSIGNED
                 for s in configs.ALL_SHAPES]
    else:
        if not (args.arch and args.shape):
            raise ValueError("--arch/--shape or --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for mesh_name in meshes:
        for arch, shape_name in cells:
            rec = run_cell(arch, shape_name, mesh_name, out_dir=args.out,
                           variant=args.variant, force=args.force)
            if rec["status"] == "error":
                failures.append((arch, shape_name, mesh_name))
    if failures:
        print("FAILED cells:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
