"""AdamW with warmup+cosine schedule and global-norm clipping.

Moments inherit the param sharding (FSDP: optimizer state is sharded over the
'data' axis with the params — ZeRO-style).  `moment_dtype='bfloat16'` halves
optimizer HBM for the 671B config (DESIGN.md §2 scale note); master params
stay in the model's param_dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def init(params: Params, cfg: AdamWConfig) -> Params:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(grads: Params, state: Params, params: Params,
           cfg: AdamWConfig) -> tuple[Params, Params, dict]:
    step = state["step"]
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decoupled WD on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return new_p, m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t3: t3[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
