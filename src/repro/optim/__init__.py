"""Optimizers and distributed-optimization tricks (no optax dependency)."""
