"""INT8 error-feedback gradient compression (beyond-paper distributed trick).

At 1000+-node scale the cross-pod (DCN) gradient all-reduce is the slowest
hop.  Compressing gradients to int8 with per-tensor scales cuts those bytes
4x (vs f32) while error feedback keeps the *accumulated* quantization error
bounded: the residual of each round is added back before the next round, so
the compressed sequence tracks the true gradient sum (standard EF-SGD result;
`tests/test_compression.py` checks the accumulated-error property).

Usage in the train step (runtime/train_step.py, `compress_grads=True`): the
compression is applied to the gradient tree between backprop and the
optimizer, carrying the residual in the optimizer-adjacent state.  The wire
format (int8 + f32 scale) is exactly what a DCN all-reduce would move; the
roofline accounting in §Perf uses `wire_bytes` for the pod-axis collective
term.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def init_residuals(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, residual: jax.Array
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One tensor: error-feedback int8 quantization.

    Returns (q int8, scale f32, new_residual f32) with
    ``dequant(q, scale) + new_residual == g + residual`` (exactly, in f32).
    """
    target = g.astype(jnp.float32) + residual
    absmax = jnp.max(jnp.abs(target))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_residual = target - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_grads(grads: Params, residuals: Params
                     ) -> tuple[Params, Params, jax.Array]:
    """Tree version.  Returns (dequantized grads, new residuals, wire_bytes).

    The dequantized grads are what the optimizer consumes (= what every
    worker reconstructs after the int8 all-reduce); wire_bytes counts the
    int8+scale payload that crosses the DCN.
    """
    qs = jax.tree.map(compress, grads, residuals)
    deq = jax.tree.map(lambda t: decompress(t[0], t[1]), qs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[2], qs,
                           is_leaf=lambda x: isinstance(x, tuple))
    wire = sum(x.size for x in jax.tree.leaves(grads)) + 4 * len(
        jax.tree.leaves(grads))
    return deq, new_res, wire


def psum_compressed(grads: Params, residuals: Params, axis_name: str
                    ) -> tuple[Params, Params]:
    """shard_map-side int8 all-reduce: quantize locally, psum the int8
    payload (XLA moves int8 on the wire), dequantize, keep residuals local.

    Scales are max-reduced first so every worker uses one shared scale —
    required for the int8 sum to be meaningful.
    """

    def one(g, r):
        target = g.astype(jnp.float32) + r
        absmax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_name)
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        new_r = target - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale, new_r

    out = jax.tree.map(one, grads, residuals)
    summed = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return summed, new_res
