"""Serving metrics registry: counters, gauges, and percentile recorders.

The registry is the one sink every serving-layer statistic flows through —
the scheduler's sequencer counters, the cache pool's spill accounting, the
per-request latency recorders (TTFT, inter-token), queue depth and cache
occupancy — so `bench_serving`, `serve.py`, and the tests all read the same
numbers instead of each layer keeping an ad-hoc dict.

Design constraints, in order:

  * **Hot-path free.**  Recording is plain-Python arithmetic on host scalars
    the serving loop already holds (wall-clock floats, queue lengths, byte
    counts from abstract shapes).  Nothing here touches a device array, so
    instrumentation cannot introduce a host sync — the A7 program audit
    (`repro.analysis`) proves the compiled decode/verify programs are
    byte-identical with observability on and off.
  * **Live dict views.**  The scheduler's historical ``stats`` /
    ``spill_stats`` dict attributes survive as `CounterView`s over the
    registry: same keys, same ``stats["steps"] += 1`` spelling, but the
    values *are* the registry counters — one source of truth.
  * **numpy-faithful percentiles.**  `Histogram.percentile` matches
    ``numpy.percentile(..., method="linear")`` exactly (test-enforced), so
    p50/p95/p99 in ``BENCH_serving.json`` mean what a reader armed with
    numpy expects.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Iterable, Iterator, MutableMapping, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "CounterView", "MetricsRegistry",
           "percentile"]


def percentile(samples: Sequence[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation — the same
    estimator as ``numpy.percentile(samples, q)`` on an unsorted 1-D input.
    """
    if not samples:
        raise ValueError("percentile of an empty sample set")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = sorted(float(x) for x in samples)
    if len(xs) == 1:
        return xs[0]
    rank = (q / 100.0) * (len(xs) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return xs[lo]
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclasses.dataclass
class Counter:
    """Monotone-in-spirit integer counter (`CounterView` may also assign)."""

    name: str
    value: int = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


@dataclasses.dataclass
class Gauge:
    """Last-value metric with min/max watermarks (e.g. device-tier bytes)."""

    name: str
    value: float | None = None
    min: float | None = None
    max: float | None = None

    def set(self, v: float) -> None:
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)


class Histogram:
    """Exact-sample percentile recorder.

    Keeps every observation (serving runs here are seconds to minutes; the
    sample vectors are small) up to ``max_samples``, after which the vector
    is *decimated*: every other retained sample is dropped and the keep-rate
    halves, so long runs degrade to a uniform subsample instead of
    unbounded memory.  count/sum/min/max stay exact regardless.

    Every retained sample carries a timestamp (caller-supplied via
    ``record(v, t=...)``, else ``time.monotonic()``), so consumers that need
    *recent* tail behavior — the front end's SLO admission policy reads the
    p99 of the last N seconds of TTFT, not the lifetime p99 — can ask for
    ``percentile(q, window_s=..., now=...)`` over the windowed slice.
    """

    def __init__(self, name: str, max_samples: int = 65536):
        if max_samples < 2:
            raise ValueError(f"max_samples must be >= 2, got {max_samples}")
        self.name = name
        self.max_samples = max_samples
        self.count = 0
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._samples: list[float] = []
        self._times: list[float] = []   # kept in lockstep with _samples
        self._stride = 1          # record every _stride-th observation
        self._skip = 0

    def record(self, v: float, t: float | None = None) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if self._skip:
            self._skip -= 1
            return
        self._skip = self._stride - 1
        self._samples.append(v)
        self._times.append(time.monotonic() if t is None else float(t))
        if len(self._samples) >= self.max_samples:
            self._samples = self._samples[::2]
            self._times = self._times[::2]
            self._stride *= 2

    def reset(self) -> None:
        """Forget every observation (keeps name/max_samples; see
        `MetricsRegistry.reset`)."""
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self._samples = []
        self._times = []
        self._stride = 1
        self._skip = 0

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def samples(self) -> list[float]:
        """The retained sample vector (exact until decimation kicks in)."""
        return list(self._samples)

    def window_samples(self, window_s: float, now: float) -> list[float]:
        """Retained samples recorded at ``t >= now - window_s``.

        ``now`` must come from the same timebase the samples were recorded
        against (the scheduler's injected clock, or ``time.monotonic()`` for
        untimed records) — mixing timebases silently empties or floods the
        window, which is why `percentile` refuses a window without an
        explicit ``now``.
        """
        if window_s <= 0.0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        cutoff = now - window_s
        return [v for t, v in zip(self._times, self._samples) if t >= cutoff]

    def percentile(self, q: float, *, window_s: float | None = None,
                   now: float | None = None) -> float:
        """Lifetime percentile, or — with ``window_s`` — the percentile over
        samples recorded in the trailing window ending at ``now``.  Same
        numpy-linear estimator either way; raises ``ValueError`` when the
        window holds no samples (callers decide the no-evidence policy)."""
        if window_s is None:
            return percentile(self._samples, q)
        if now is None:
            raise ValueError("windowed percentile needs an explicit `now` "
                             "from the recording timebase")
        return percentile(self.window_samples(window_s, now), q)

    def summary(self) -> dict:
        """The block `bench_serving` embeds per metric: count, mean, and the
        SLO percentiles.  Zero-observation histograms summarize to counts
        only, so an idle metric cannot crash a bench append."""
        out: dict = {"count": self.count}
        if not self._samples:
            return out
        out.update({
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50.0),
            "p95": self.percentile(95.0),
            "p99": self.percentile(99.0),
        })
        return out


class CounterView(MutableMapping):
    """A dict-shaped live view over a group of registry counters.

    ``view["steps"] += 1`` reads and writes the underlying `Counter`, so
    legacy callers of the scheduler's ``stats`` / the pool's ``spill_stats``
    keep working unchanged while the registry stays the single source of
    truth.  Unknown keys raise (a typo would otherwise silently mint a new
    counter and the historical dict would have KeyError'd too); new keys may
    only be introduced through `MetricsRegistry.counter_view`.
    """

    def __init__(self, registry: "MetricsRegistry", prefix: str,
                 keys: Iterable[str]):
        self._registry = registry
        self._prefix = prefix
        self._keys = list(keys)
        for k in self._keys:
            registry.counter(prefix + k)

    def _counter(self, key: str) -> Counter:
        if key not in self._keys:
            raise KeyError(key)
        return self._registry.counter(self._prefix + key)

    def __getitem__(self, key: str) -> int:
        return self._counter(key).value

    def __setitem__(self, key: str, value: int) -> None:
        self._counter(key).value = int(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("CounterView keys are fixed at construction")

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __repr__(self) -> str:
        return repr(dict(self))

    def __eq__(self, other) -> bool:
        if isinstance(other, (dict, CounterView)):
            return dict(self) == dict(other)
        return NotImplemented


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Names are dot-scoped by convention (``sched.steps``, ``pool.spills``,
    ``req.ttft_s``, ``engine.inter_token_s``); `snapshot` renders the whole
    registry to plain JSON-ready python (serve.py's ``--metrics`` artifact).
    """

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def _fresh(self, name: str) -> None:
        if (name in self._counters or name in self._gauges
                or name in self._histograms):
            raise ValueError(f"metric {name!r} already registered with a "
                             f"different type")

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._fresh(name)
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._fresh(name)
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            self._fresh(name)
            h = self._histograms[name] = Histogram(name)
        return h

    def counter_view(self, prefix: str, keys: Iterable[str]) -> CounterView:
        return CounterView(self, prefix, keys)

    def reset(self) -> None:
        """Zero every registered metric *in place* — counters to 0, gauges to
        unset, histograms emptied — while keeping the metric objects (and
        every live `CounterView` over them) attached.  The warm-vs-measured
        seam: a bench drives a warmup pass through a scheduler to pay its
        trace/compile costs, resets, then measures a clean run on the same
        instance (`bench_serving.run_prefix_reuse`)."""
        for c in self._counters.values():
            c.value = 0
        for g in self._gauges.values():
            g.value = g.min = g.max = None
        for h in self._histograms.values():
            h.reset()

    def snapshot(self) -> dict:
        """JSON-ready dump: counters as ints, gauges as value/min/max,
        histograms as their summary blocks."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: {"value": g.value, "min": g.min, "max": g.max}
                       for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }
