"""Structured request-lifecycle tracer emitting Chrome-trace-event JSON.

Records the serving stack's lifecycle spans — submit → admit → prefill
chunks → first token → decode steps → preempt/resume → finish — as
Trace Event Format events (``B``/``E`` duration pairs, ``i`` instants,
``C`` counter series) that Perfetto / ``chrome://tracing`` load directly:
each request gets its own named track, the scheduler's sequencer cycle its
own, so a preemption reads as a gap on the request's track bracketed by
``preempt``/``resume`` markers while the high-priority request's admit span
runs on a sibling track (docs/observability.md shows a worked example).

Hot-path discipline:

  * Recording appends a dict to a python list — no device access, no
    serialization, no I/O.  `NullTracer` is the default everywhere and
    no-ops every method, so an untraced run pays one attribute lookup per
    potential span; the A7 program audit pins that the *compiled* serving
    programs are byte-identical either way.
  * Span/instant ``args`` may carry **device arrays**: they are stored
    as-is at record time and gathered in ONE `jax.device_get` at `flush`
    (export calls it) — deferred args never force a sync inside the
    sequencer cycle.  `Tracer.flush` is the single allowlisted host-sync
    point the ``host-sync`` lint rule grants this module.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Iterator

__all__ = ["Tracer", "NullTracer", "SCHED_TRACK", "ENGINE_TRACK",
           "request_track"]

SCHED_TRACK = "scheduler"
ENGINE_TRACK = "engine"


def request_track(uid: int) -> str:
    """The per-request track name (`tid`) a request's lifecycle lives on."""
    return f"req {uid}"


def _is_device_array(v: Any) -> bool:
    """Array-ish (has shape+dtype) but not already a host scalar/list."""
    return hasattr(v, "shape") and hasattr(v, "dtype") \
        and not isinstance(v, (int, float, bool))


class NullTracer:
    """The disabled tracer: every record is a no-op, `span` yields nothing.

    This is the default on every engine/scheduler — observability off means
    the serving loop executes the same statements it always did.
    """

    enabled = False

    def begin(self, name: str, track: str = SCHED_TRACK, **args) -> None:
        pass

    def end(self, name: str, track: str = SCHED_TRACK, **args) -> None:
        pass

    def instant(self, name: str, track: str = SCHED_TRACK, **args) -> None:
        pass

    def counter(self, name: str, value: float,
                track: str = SCHED_TRACK) -> None:
        pass

    @contextlib.contextmanager
    def span(self, name: str, track: str = SCHED_TRACK,
             **args) -> Iterator[None]:
        yield

    def flush(self) -> None:
        pass


class Tracer(NullTracer):
    """Chrome-trace-event recorder with per-track span nesting.

    ``ts`` is microseconds since the tracer's construction; every event
    lands on one process (``pid`` 0) with the *track* name as its thread,
    declared via ``thread_name`` metadata so Perfetto labels the lanes.
    ``B``/``E`` events must nest per track — `end` checks the name against
    the track's open-span stack and raises on a mismatch, so a mis-paired
    instrumentation site fails loudly in tests instead of producing a trace
    Perfetto silently mis-renders.
    """

    enabled = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._events: list[dict] = []
        self._open: dict[str, list[str]] = {}    # track -> span-name stack
        self._tids: dict[str, int] = {}
        self._pending_args: list[dict] = []      # device-array args to gather

    # -- recording ----------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids)
            self._events.append({"ph": "M", "pid": 0, "tid": tid,
                                 "name": "thread_name",
                                 "args": {"name": track}})
        return tid

    def _args(self, args: dict) -> dict | None:
        if not args:
            return None
        if any(_is_device_array(v) for v in args.values()):
            self._pending_args.append(args)
        return args

    def _event(self, ph: str, name: str, track: str, **fields) -> dict:
        ev = {"ph": ph, "name": name, "pid": 0, "tid": self._tid(track),
              "ts": self._now_us(), **fields}
        self._events.append(ev)
        return ev

    def begin(self, name: str, track: str = SCHED_TRACK, **args) -> None:
        """Open a span on ``track``; close it with `end` (LIFO per track)."""
        ev = self._event("B", name, track)
        a = self._args(args)
        if a is not None:
            ev["args"] = a
        self._open.setdefault(track, []).append(name)

    def end(self, name: str, track: str = SCHED_TRACK, **args) -> None:
        stack = self._open.get(track, [])
        if not stack or stack[-1] != name:
            raise ValueError(
                f"span end({name!r}) on track {track!r} does not match the "
                f"innermost open span ({stack[-1] if stack else None!r})")
        stack.pop()
        ev = self._event("E", name, track)
        a = self._args(args)
        if a is not None:
            ev["args"] = a

    def instant(self, name: str, track: str = SCHED_TRACK, **args) -> None:
        ev = self._event("i", name, track, s="t")
        a = self._args(args)
        if a is not None:
            ev["args"] = a

    def counter(self, name: str, value: float,
                track: str = SCHED_TRACK) -> None:
        """A Perfetto counter-series sample (e.g. queue depth per cycle)."""
        self._event("C", name, track, args={"value": value})

    @contextlib.contextmanager
    def span(self, name: str, track: str = SCHED_TRACK,
             **args) -> Iterator[None]:
        self.begin(name, track, **args)
        try:
            yield
        finally:
            self.end(name, track)

    # -- introspection / export ---------------------------------------------

    @property
    def events(self) -> list[dict]:
        return list(self._events)

    def open_spans(self, track: str = SCHED_TRACK) -> list[str]:
        """Names of the track's currently-open spans, outermost first."""
        return list(self._open.get(track, []))

    def flush(self) -> None:
        """Resolve deferred device-array args in one host gather.

        The ONLY point in the tracer that synchronizes with the device —
        called from `export` / end-of-run, never from the sequencer cycle
        (``host-sync`` lint allowlists exactly this qualname).
        """
        if not self._pending_args:
            return
        import jax

        pending, self._pending_args = self._pending_args, []
        for args in pending:
            arrays = {k: v for k, v in args.items() if _is_device_array(v)}
            host = jax.device_get(arrays)
            for k, v in host.items():
                args[k] = v.tolist() if hasattr(v, "tolist") else v

    def to_dict(self) -> dict:
        self.flush()
        return {"traceEvents": self._events,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        """Write the Perfetto-loadable JSON (gathers deferred args first)."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
            f.write("\n")
