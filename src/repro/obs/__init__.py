"""`repro.obs` — the serving observability layer.

Three pieces, one bundle:

  * `metrics` — a `MetricsRegistry` of counters / gauges / percentile
    histograms (p50/p95/p99 faithful to numpy): per-request TTFT and
    inter-token latency, queue depth, cache occupancy per tier, spill/fetch
    bytes, speculative acceptance, chunked-prefill pacing.  The scheduler's
    legacy ``stats`` / ``spill_stats`` dicts are live `CounterView`s over
    this registry.
  * `trace` — a structured span `Tracer` recording each request's lifecycle
    (submit → admit → prefill chunks → first token → decode steps →
    preempt/resume → finish) as Chrome-trace-event JSON loadable in
    Perfetto; `NullTracer` (the default) no-ops everything.
  * `profiler` — zero-overhead `jax.profiler` annotation hooks around the
    engine's jit dispatch sites.

`Observability` carries all three through the serving stack
(`InferenceEngine(obs=...)`, `RequestScheduler(obs=...)`); every piece is
host-side only, and the A7 program audit (`python -m repro.analysis audit`)
proves the compiled decode/verify programs are byte-identical with the
whole layer enabled vs absent.  docs/observability.md is the catalog.
"""

from __future__ import annotations

import dataclasses

from repro.obs import profiler
from repro.obs.metrics import (Counter, CounterView, Gauge, Histogram,
                               MetricsRegistry, percentile)
from repro.obs.trace import (ENGINE_TRACK, SCHED_TRACK, NullTracer, Tracer,
                             request_track)

__all__ = ["Counter", "CounterView", "Gauge", "Histogram", "MetricsRegistry",
           "NullTracer", "Observability", "Tracer", "percentile", "profiler",
           "SCHED_TRACK", "ENGINE_TRACK", "request_track"]


@dataclasses.dataclass
class Observability:
    """The bundle a serving component records through.

    ``metrics`` is always a real registry (recording a counter is cheaper
    than branching around it); ``tracer`` defaults to the no-op
    `NullTracer`; ``profile`` gates the `jax.profiler` annotations around
    jit dispatch sites.  One bundle may be shared across the engine, the
    scheduler, and the pool — their metric names are dot-prefixed
    (``engine.``, ``sched.``, ``pool.``, ``req.``) so a shared registry
    stays collision-free.
    """

    metrics: MetricsRegistry = dataclasses.field(default_factory=MetricsRegistry)
    tracer: NullTracer = dataclasses.field(default_factory=NullTracer)
    profile: bool = False

    def annotation(self, name: str):
        """Profiler annotation for one jit dispatch site (no-op unless
        ``profile`` is set)."""
        return profiler.annotation(name, self.profile)
