"""Zero-overhead `jax.profiler` hooks for the serving hot path.

The engine and scheduler wrap their jit *dispatch sites* (prefill, chunk
step, fused decode/verify loops, the pool's vmapped steps) in
`annotation(name)` contexts.  With profiling off — the default — the hook
returns one shared ``nullcontext`` instance: no object allocation, no
`jax.profiler` import, nothing in the dispatch path.  With profiling on,
each site becomes a named `jax.profiler.TraceAnnotation`, so a
``jax.profiler.trace`` capture (or a profiler server the user attaches
Perfetto/TensorBoard to) shows the serving phases labeled exactly like the
`obs.trace` span names.

The annotations wrap only host-side dispatch: they never enter the traced
program, so the compiled decode/verify HLO stays byte-identical whether
profiling is on or off (the A7 program audit pins this).

Usage::

    from repro import obs

    with obs.profiler.capture("/tmp/jax-trace"):   # or start_server(port)
        engine.generate(prompts, gen)              # obs=... with profile=True

Missing-profiler environments (stripped jax builds) degrade to no-ops
rather than import errors — the serving stack must not grow a hard
dependency on the profiler being present.
"""

from __future__ import annotations

import contextlib

__all__ = ["annotation", "capture", "start_server", "PROFILER_AVAILABLE"]

_NULL = contextlib.nullcontext()

try:  # pragma: no cover - exercised implicitly on every import
    from jax.profiler import TraceAnnotation as _TraceAnnotation
    PROFILER_AVAILABLE = True
except ImportError:  # pragma: no cover - stripped jax build
    _TraceAnnotation = None
    PROFILER_AVAILABLE = False


def annotation(name: str, enabled: bool = True):
    """A named profiler annotation context; the shared no-op when disabled
    (or when this jax has no profiler)."""
    if not enabled or _TraceAnnotation is None:
        return _NULL
    return _TraceAnnotation(name)


@contextlib.contextmanager
def capture(logdir: str):
    """A `jax.profiler.trace` capture written under ``logdir`` (view with
    TensorBoard or Perfetto); a no-op context when jax has no profiler."""
    try:
        from jax.profiler import trace as profiler_trace
    except ImportError:  # pragma: no cover - stripped jax build
        yield
        return
    with profiler_trace(logdir):
        yield


def start_server(port: int = 9999):
    """Start the profiler server (attach via TensorBoard's profile tab);
    returns the server object, or None when jax has no profiler."""
    try:
        from jax.profiler import start_server as profiler_start
    except ImportError:  # pragma: no cover - stripped jax build
        return None
    return profiler_start(port)
