"""Online RoPE — Section IV-B2 of the HSA paper (Eq. 5-6).

Naive decoders either (a) store a precomputed ``sin/cos[max_seq, d/2]`` table
and gather row ``m`` per generated token (an HBM read per step), or (b)
recompute ``sin(m * theta_i)`` with transcendental ops per step.  The paper's
RoPE unit instead keeps the *current* ``(sin m theta, cos m theta)`` vectors in
a small angle memory and advances them with the angle-addition identities:

    sin((m+1) t) = sin(mt) cos(t) + cos(mt) sin(t)        (Eq. 6)
    cos((m+1) t) = cos(mt) cos(t) - sin(mt) sin(t)

reusing the embedding multipliers ("Embed" mode applies the rotation to
q/k, "Update" mode advances the angle state).

TPU adaptation (DESIGN.md §2): the decode loop carries the angle state in the
serving cache pytree; `update` is 4 fused multiply-adds on the VPU and removes
the per-step table gather.  Unlike the ASIC's fixed-point datapath, fp32
repeated rotation drifts, so `advance` resyncs exactly every `RESYNC_PERIOD`
tokens (tests bound drift < 2e-5 between resyncs).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

RESYNC_PERIOD = 64


def rope_thetas(head_dim: int, base: float = 10000.0) -> jax.Array:
    """theta_i = base^(-2(i-1)/d), i in [1, d/2]  (Eq. 5)."""
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    return jnp.power(base, -2.0 * i / head_dim)


def rope_table(positions: jax.Array, thetas: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference table: (sin, cos) of shape ``positions.shape + [d/2]``."""
    ang = positions.astype(jnp.float32)[..., None] * thetas
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate ``x[..., d]`` with interleaved-pair convention (Eq. 5).

    ``sin/cos`` broadcast over leading axes and have trailing dim ``d/2``.
    """
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape).astype(x.dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OnlineRopeState:
    """The angle memory: (sin, cos) for the *current* position, per theta_i."""

    sin: jax.Array   # f32 [d/2]
    cos: jax.Array   # f32 [d/2]
    pos: jax.Array   # i32 scalar — current absolute position m


def init_state(head_dim: int, base: float = 10000.0,
               pos: int | jax.Array = 0) -> OnlineRopeState:
    thetas = rope_thetas(head_dim, base)
    p = jnp.asarray(pos, jnp.int32)
    sin, cos = rope_table(p, thetas)
    return OnlineRopeState(sin=sin, cos=cos, pos=p)


def update(state: OnlineRopeState, thetas: jax.Array) -> OnlineRopeState:
    """"Update" mode: advance one token via the trig identities (Eq. 6)."""
    st, ct = jnp.sin(thetas), jnp.cos(thetas)  # constants, CSE'd by XLA
    sin_next = state.sin * ct + state.cos * st
    cos_next = state.cos * ct - state.sin * st
    return OnlineRopeState(sin=sin_next, cos=cos_next, pos=state.pos + 1)


def advance(state: OnlineRopeState, thetas: jax.Array,
            resync_period: int = RESYNC_PERIOD) -> OnlineRopeState:
    """`update` with periodic exact resync (fp-drift guard; DESIGN.md §2.4)."""
    nxt = update(state, thetas)
    need = (nxt.pos % resync_period) == 0
    exact_sin, exact_cos = rope_table(nxt.pos, thetas)
    return OnlineRopeState(
        sin=jnp.where(need, exact_sin, nxt.sin),
        cos=jnp.where(need, exact_cos, nxt.cos),
        pos=nxt.pos,
    )


def embed(state: OnlineRopeState, x: jax.Array) -> jax.Array:
    """"Embed" mode: rotate the current token's q/k with the angle memory."""
    return apply_rope(x, state.sin, state.cos)
