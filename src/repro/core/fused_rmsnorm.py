"""Layer-fused RMSNorm — Section IV-B1, Eq. (4) of the HSA paper.

Instead of fully normalizing ``Y_n`` before layer ``n+1``:

    X_{n+1} = RMSNorm(Y_n) = Y_n * sigma^{-1} * gamma + beta
    Y_{n+1} = (X_{n+1} @ W_{n+1}) * S_{n+1}

the paper applies only ``* gamma`` in layer ``n`` and folds ``sigma^{-1}`` and
``beta`` into layer ``n+1``'s quantization scale and bias:

    Y_{n+1} = (Y_n^* @ W_{n+1}) * S_{n+1}^*  +  B_{n+1}
      where  Y_n^*     = Y_n * gamma                (emitted by layer n)
             S_{n+1}^* = sigma_{Y_n}^{-1} * S_{n+1} (a per-ROW output scale)
             B_{n+1}   = beta @ W_{n+1} * S_{n+1}   (precomputed offline)

On the ASIC this removes a 32 kB normalization buffer and a 5-10 % latency
bubble by pipelining the sigma^{-1} reduction with the next layer's MAC.  On
TPU the same algebra removes one full memory-bound elementwise pass over the
activation tensor (an HBM round-trip): the matmul kernel applies
``row_scale = sigma^{-1}`` in its epilogue (see kernels/mxint4_matmul.py).

Note ``sigma^{-1}`` is a *per-token scalar* so it commutes with the matmul's
contraction — the fusion is exact, which `tests/test_fused_rmsnorm.py`
verifies bit-tightly in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_sigma_inv(y: jax.Array, eps: float = 1e-6) -> jax.Array:
    """The sigma^{-1} reduction (square-accumulate + rsqrt), per token.

    Input ``[..., D]`` -> output ``[...]``.  This is the only part of RMSNorm
    the fused pipeline still computes — the paper keeps this unit ("(b)
    calculation of sigma^{-1} remains the same") and overlaps it with the MAC.
    """
    y32 = y.astype(jnp.float32)
    return jax.lax.rsqrt(jnp.mean(y32 * y32, axis=-1) + eps)


def rmsnorm(y: jax.Array, gamma: jax.Array, beta: jax.Array | None = None,
            eps: float = 1e-6) -> jax.Array:
    """Unfused reference RMSNorm (Eq. 3) — the baseline path."""
    out = y.astype(jnp.float32) * rms_sigma_inv(y, eps)[..., None] * gamma.astype(jnp.float32)
    if beta is not None:
        out = out + beta.astype(jnp.float32)
    return out.astype(y.dtype)


def fused_rmsnorm_emit(y: jax.Array, gamma: jax.Array, eps: float = 1e-6
                       ) -> tuple[jax.Array, jax.Array]:
    """Layer-n side of Eq. (4): emit ``Y* = Y * gamma`` and ``sigma^{-1}``.

    ``Y*`` flows to the next matmul unnormalized; ``sigma^{-1}`` rides along as
    a per-token row scale to be applied in that matmul's epilogue.
    """
    y_star = (y.astype(jnp.float32) * gamma.astype(jnp.float32)).astype(y.dtype)
    return y_star, rms_sigma_inv(y, eps)


def fused_bias(beta: jax.Array, w: jax.Array, out_scale: jax.Array | float = 1.0
               ) -> jax.Array:
    """Precompute ``B_{n+1} = (beta @ W_{n+1}) * S_{n+1}`` offline (Eq. 4).

    beta is usually absent in modern LLMs (the paper notes this); included for
    generality and for the LayerNorm archs (starcoder2, seamless-m4t).
    """
    return (beta.astype(jnp.float32) @ w.astype(jnp.float32)) * out_scale


# ---------------------------------------------------------------------------
# LayerNorm extension (DESIGN.md §4): starcoder2 / seamless-m4t use LayerNorm.
# LN(y) = (y - mu) * sigma_c^{-1} * gamma + beta.  The (y - mu) centering stays
# online (cheap vector subtract); the gamma/sigma^{-1} factorization then fuses
# exactly like RMSNorm.
# ---------------------------------------------------------------------------


def layernorm_stats(y: jax.Array, eps: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    """Return (mu, sigma^{-1}) for centered LayerNorm fusion."""
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, axis=-1)
    var = jnp.mean(jnp.square(y32 - mu[..., None]), axis=-1)
    return mu, jax.lax.rsqrt(var + eps)


def fused_layernorm_emit(y: jax.Array, gamma: jax.Array, eps: float = 1e-6
                         ) -> tuple[jax.Array, jax.Array]:
    """LN variant of `fused_rmsnorm_emit`: emit ``(y - mu) * gamma`` + sigma^{-1}."""
    y32 = y.astype(jnp.float32)
    mu, sig_inv = layernorm_stats(y, eps)
    y_star = ((y32 - mu[..., None]) * gamma.astype(jnp.float32)).astype(y.dtype)
    return y_star, sig_inv


def layernorm(y: jax.Array, gamma: jax.Array, beta: jax.Array | None = None,
              eps: float = 1e-6) -> jax.Array:
    """Unfused reference LayerNorm."""
    y32 = y.astype(jnp.float32)
    mu, sig_inv = layernorm_stats(y, eps)
    out = (y32 - mu[..., None]) * sig_inv[..., None] * gamma.astype(jnp.float32)
    if beta is not None:
        out = out + beta.astype(jnp.float32)
    return out.astype(y.dtype)
