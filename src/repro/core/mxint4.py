"""MXINT4 weight quantization — Section III of the HSA paper (Eq. 1).

The paper stores weights as 4-bit two's-complement mantissas plus a shared
*power-of-two* shift exponent per group of ``g = 16`` values **along the output
channel** ("we choose the weight group size to 16 (along the output channel) to
match the capacity of each PE").  The shift is

    S_g = floor(log2(max |W_g|))            (Eq. 1)

clamped to ``[-9, +5]`` so the 4-bit shift code never overflows, and the
tensor-wise quantization scale ``S_w`` is itself a power of two folded into the
group shifts.  Dequantization is a shift, not a multiply — the paper maps it
onto idle PEs (Table V: 10.3x area / 7.2x power cheaper than an INT8-scale
multiplier, 16x cheaper than FP16).

Layout conventions used throughout this framework
--------------------------------------------------
Weights are stored as ``W[K, N]`` (``in_features x out_features``) so that the
forward pass is ``y = x @ W``.  The *output channel* axis is therefore ``N``
(axis=1) and groups are 16 **consecutive output channels at fixed input
channel**, giving an exponent tensor of shape ``[K, N // 16]`` — 4 bits per 16
weights, i.e. 4.25 effective bits/weight streamed from HBM during decode.

Mantissa packing: two int4 values (adjacent output channels) per int8 byte,
packed shape ``[K, N // 2]``; low nibble = even channel, high nibble = odd.
Exponent packing: shifts live in [-9, +5], biased by +9 into unsigned nibbles
(codes 0..14), two per byte, packed shape ``[K, N // 32]`` — so the streamed
format is exactly the paper's 4 + 4/16 = 4.25 bits/weight.

Numerical contract (tested): ``m * 2^(S_g - 2)`` is exact in bf16/fp32 for the
full code range, and the quantization error obeys
``|w - dq(q(w))| <= 2^(S_g - 2)`` (one mantissa scale unit) for unclamped
groups — see `mxint4_error_bound`.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

GROUP_SIZE = 16          # paper: group of 16 along the output channel (one PE)
SHIFT_MIN = -9           # paper: shift constrained to [-9, +5]
SHIFT_MAX = 5
MANT_MIN = -8            # int4 two's complement
MANT_MAX = 7
# max|W_g| in [2^S, 2^{S+1})  =>  |w| / 2^(S-2) in [4, 8): full int4 range.
MANT_SHIFT = 2
EXP_BIAS = 9             # shift codes stored as unsigned nibble: code = S_g + 9


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MXINT4Weight:
    """A weight matrix in MXINT4 format (the decode-stage storage format).

    Attributes:
      packed:      int8 ``[K, N // 2]`` — two int4 mantissas per byte.
      exps_packed: uint8 ``[K, N // (2*GROUP_SIZE)]`` — two biased shift codes
                   (``S_g + 9``, unsigned nibbles) per byte.
      shape:       static logical ``(K, N)``.

    Streamed size is exactly ``K*N/2 + K*N/32`` bytes = 4.25 bits/weight.
    """

    packed: jax.Array
    exps_packed: jax.Array
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def kdim(self) -> int:
        return self.shape[0]

    @property
    def ndim_out(self) -> int:
        return self.shape[1]

    @property
    def exps(self) -> jax.Array:
        """Unpacked int8 shift exponents ``[K, N // GROUP_SIZE]`` in [-9, +5]."""
        return (unpack_uint4(self.exps_packed).astype(jnp.int8) - EXP_BIAS)

    def nbytes_streamed(self) -> int:
        """HBM bytes the decode dataflow actually streams (the EMA metric)."""
        return self.packed.size + self.exps_packed.size


def _floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for x > 0, exact for powers of two (uses frexp)."""
    mant, exp = jnp.frexp(x)  # x = mant * 2^exp, mant in [0.5, 1)
    return exp - 1


def group_shift_exponents(w: jax.Array, group_size: int = GROUP_SIZE) -> jax.Array:
    """Eq. (1): S_g = clip(floor(log2 max|W_g|), -9, +5), groups along axis 1."""
    k, n = w.shape
    if n % group_size != 0:
        raise ValueError(f"N={n} not divisible by group {group_size}")
    grouped = jnp.abs(w).reshape(k, n // group_size, group_size)
    gmax = jnp.max(grouped, axis=-1)
    # Zero groups: park at SHIFT_MIN (mantissas will be exactly zero).
    safe = jnp.where(gmax > 0, gmax, jnp.exp2(jnp.float32(SHIFT_MIN)))
    exps = _floor_log2(safe.astype(jnp.float32))
    return jnp.clip(exps, SHIFT_MIN, SHIFT_MAX).astype(jnp.int8)


def pack_int4(mant: jax.Array) -> jax.Array:
    """Pack int8-valued int4 mantissas ``[K, N]`` -> bytes ``[K, N//2]``."""
    k, n = mant.shape
    if n % 2 != 0:
        raise ValueError(f"mantissa width {n} must be even to pack nibble pairs")
    lo = mant[:, 0::2].astype(jnp.int8) & jnp.int8(0x0F)
    hi = (mant[:, 1::2].astype(jnp.int8) & jnp.int8(0x0F)) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Unpack bytes ``[K, N//2]`` -> sign-extended int8 mantissas ``[K, N]``."""
    # Arithmetic shifts sign-extend: (b << 4) >> 4 recovers the low nibble.
    lo = ((packed << 4) >> 4).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    k, half = packed.shape
    out = jnp.empty((k, half * 2), dtype=jnp.int8)
    out = out.at[:, 0::2].set(lo)
    out = out.at[:, 1::2].set(hi)
    return out


def pack_uint4(codes: jax.Array) -> jax.Array:
    """Pack unsigned nibble codes (0..15) ``[K, G]`` -> uint8 ``[K, G//2]``."""
    k, g = codes.shape
    if g % 2 != 0:
        raise ValueError(f"packed width {g} must be even to unpack nibble pairs")
    lo = codes[:, 0::2].astype(jnp.uint8) & jnp.uint8(0x0F)
    hi = (codes[:, 1::2].astype(jnp.uint8) & jnp.uint8(0x0F)) << 4
    return (lo | hi).astype(jnp.uint8)


def unpack_uint4(packed: jax.Array) -> jax.Array:
    """Unpack uint8 ``[K, G//2]`` -> unsigned nibble codes uint8 ``[K, G]``."""
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.uint8)
    hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.uint8)
    k, half = packed.shape
    out = jnp.empty((k, half * 2), dtype=jnp.uint8)
    out = out.at[:, 0::2].set(lo)
    out = out.at[:, 1::2].set(hi)
    return out


@partial(jax.jit, static_argnames=("group_size",))
def quantize_mxint4(w: jax.Array, group_size: int = GROUP_SIZE) -> MXINT4Weight:
    """PTQ a weight matrix ``W[K, N]`` to MXINT4 (Section III).

    The tensor-wise scale S_w is a power of two folded into the group shifts
    (the paper: "the quantization scaling factor S_w remains tensor-wise, which
    can be fused together with the group-wise shifter"), so it is absorbed by
    Eq. (1) directly — no separate storage.
    """
    w = w.astype(jnp.float32)
    exps = group_shift_exponents(w, group_size)
    scale = jnp.exp2(exps.astype(jnp.float32) - MANT_SHIFT)  # [K, N//g]
    scale_full = jnp.repeat(scale, group_size, axis=1)
    mant = jnp.clip(jnp.round(w / scale_full), MANT_MIN, MANT_MAX).astype(jnp.int8)
    codes = (exps.astype(jnp.int32) + EXP_BIAS).astype(jnp.uint8)
    return MXINT4Weight(packed=pack_int4(mant), exps_packed=pack_uint4(codes),
                        shape=tuple(w.shape))


@partial(jax.jit, static_argnames=("dtype", "group_size"))
def dequantize_mxint4(
    q: MXINT4Weight, dtype=jnp.bfloat16, group_size: int = GROUP_SIZE
) -> jax.Array:
    """Reference dequantization: ``w = m * 2^(S_g - 2)`` (exact in bf16)."""
    mant = unpack_int4(q.packed).astype(jnp.float32)
    scale = jnp.exp2(q.exps.astype(jnp.float32) - MANT_SHIFT)
    w = mant * jnp.repeat(scale, group_size, axis=1)
    return w.astype(dtype)


def mxint4_error_bound(exps: jax.Array, group_size: int = GROUP_SIZE) -> jax.Array:
    """Per-element worst-case error, ``2^(S_g - 2)`` = one mantissa scale unit.

    Round-to-nearest contributes half a unit; the positive-clip edge (values in
    ``(7.5, 8) * scale`` clip to mantissa 7) contributes up to one full unit,
    so one unit is the tight bound (tested).  Groups whose true max exceeded
    2^(SHIFT_MAX+1) are exponent-clamped and may exceed it; standard LLM
    weights never do (|w| < 32).
    """
    bound = jnp.exp2(exps.astype(jnp.float32) - MANT_SHIFT)
    return jnp.repeat(bound, group_size, axis=1)


# ---------------------------------------------------------------------------
# INT8 paths (prefill W8A8, SmoothQuant activations) — Section III.
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int8Weight:
    """Per-tensor symmetric INT8 weight (the paper's prefill format)."""

    values: jax.Array  # int8 [K, N]
    scale: jax.Array   # f32 scalar

    def nbytes_streamed(self) -> int:
        return self.values.size


@jax.jit
def quantize_int8_tensor(w: jax.Array) -> Int8Weight:
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    vals = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return Int8Weight(values=vals, scale=scale)


@partial(jax.jit, static_argnames=("dtype",))
def dequantize_int8(q: Int8Weight, dtype=jnp.bfloat16) -> jax.Array:
    return (q.values.astype(jnp.float32) * q.scale).astype(dtype)


def quantize_act_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dynamic per-tensor activation quantization (A8 after SmoothQuant)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    xq = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return xq, scale


# ---------------------------------------------------------------------------
# Ablation formats for Table V (dequant-scaling hardware overhead).
# ---------------------------------------------------------------------------


def quantize_int4_fp16_scale(w: jax.Array, group_size: int = GROUP_SIZE):
    """INT4 with *FP16* group scale (GPTQ/QServe-style) — Table V comparator."""
    w = w.astype(jnp.float32)
    k, n = w.shape
    grouped = jnp.abs(w).reshape(k, n // group_size, group_size)
    scale = jnp.max(grouped, axis=-1) / 7.0
    scale = jnp.where(scale > 0, scale, 1.0).astype(jnp.float16)
    sf = jnp.repeat(scale.astype(jnp.float32), group_size, axis=1)
    mant = jnp.clip(jnp.round(w / sf), MANT_MIN, MANT_MAX).astype(jnp.int8)
    return mant, scale


def dequantize_int4_fp16_scale(mant, scale, group_size: int = GROUP_SIZE):
    sf = jnp.repeat(scale.astype(jnp.float32), group_size, axis=1)
    return mant.astype(jnp.float32) * sf


def quantize_int4_naive(w: jax.Array):
    """Per-tensor INT4 (no grouping) — the accuracy-collapse baseline."""
    w = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(w))
    scale = jnp.where(absmax > 0, absmax / 7.0, 1.0)
    mant = jnp.clip(jnp.round(w / scale), MANT_MIN, MANT_MAX).astype(jnp.int8)
    return mant, scale


def dequantize_int4_naive(mant, scale):
    return mant.astype(jnp.float32) * scale
