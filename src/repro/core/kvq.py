"""Quantized KV-cache codecs — the *cache*-side half of the paper's EMA
(external memory access) argument.

Once decode weights stream at MXINT4 (core/mxint4.py, deploy.py), the
per-token DRAM traffic of the MVM phase is dominated by KV-cache reads:
a fp32 GQA cache costs ``4*d`` bytes per token per head, every step.  This
module provides drop-in cache leaf encodings that cut that stream 4-8x
while keeping the pool/spill/rollback machinery structure-agnostic:

``int8_tok``
    Per-token symmetric int8: each cache row (the last axis — one head's
    key or value vector, or one MLA latent) stores an int8 vector plus one
    f32 absmax/127 scale.  Bytes/row: ``d + 4`` vs ``4*d`` fp32 (~3.9x).

``mxint4_blk``
    MXINT4 with per-block shared exponents, the same element format the
    weight path uses (core/mxint4.py): groups of GROUP_SIZE=16 along the
    last axis share one power-of-two scale; mantissas are 4-bit two's
    complement packed two-per-int8.  Bytes/row: ``d/2 + d/16`` (~7.1x vs
    fp32).  Rows whose last dim is not a multiple of 16 (or odd) fall back
    to ``int8_tok`` *per leaf* — deterministically, so cache pytree
    structure is a pure function of (cfg, format).

An encoded leaf is a plain dict (``{"q","s"}`` or ``{"m","e"}``), so every
pytree-generic consumer — `CachePool` stores, host spill/fetch,
`ring_rollback`, ServeCell sharding — threads it unchanged.  Encoding is
row-local (touches only the last axis), which is what makes chunked-prefill
append bit-exact vs the monolithic path: the same row values encode to the
same bits regardless of how many rows arrive per dispatch.

`encode`/`decode` are pure jnp and run inside the engine's jitted decode
loops; the flash-decode kernel (kernels/flash_decode.py) instead dequantizes
*inside* its KV block loads, so HBM only ever sees the packed bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mxint4 as mx

# Cache format names accepted wherever a cache dtype goes
# (lm.make_decode_cache, CachePool(dtype=...), GenerationConfig.cache_format).
FORMATS = ("int8_tok", "mxint4_blk")

# Legacy whole-cache int8 (models/layers.py `to_cache_dtype`): one static
# power-of-two scale, no per-row metadata.  Kept so pre-existing int8 cache
# dtypes decode identically through this module.
KV8_SCALE = 32.0


def is_format(fmt) -> bool:
    """True when ``fmt`` is a quantized-cache format name (not a dtype)."""
    return isinstance(fmt, str) and fmt in FORMATS


def check_format(fmt) -> str:
    if not is_format(fmt):
        raise ValueError(f"unknown cache format {fmt!r}; expected one of "
                         f"{FORMATS} or a jnp dtype")
    return fmt


def effective_format(fmt: str, d: int) -> str:
    """Per-leaf format after the divisibility fallback: mxint4_blk needs the
    last dim to hold whole 16-element groups and an even mantissa count."""
    check_format(fmt)
    if fmt == "mxint4_blk" and (d % mx.GROUP_SIZE != 0 or d % 2 != 0):
        return "int8_tok"
    return fmt


def leaf_format(leaf) -> str | None:
    """Format of an encoded leaf dict, or None for a plain array."""
    if not isinstance(leaf, dict):
        return None
    keys = set(leaf.keys())
    if keys == {"q", "s"}:
        return "int8_tok"
    if keys == {"m", "e"}:
        return "mxint4_blk"
    return None


def decoded_dim(leaf) -> int:
    """Last (feature) dim of a cache leaf after decoding."""
    fmt = leaf_format(leaf)
    if fmt == "int8_tok":
        return leaf["q"].shape[-1]
    if fmt == "mxint4_blk":
        return leaf["m"].shape[-1] * 2
    return leaf.shape[-1]


def nbytes_per_row(fmt, d: int) -> float:
    """Modeled cache bytes for one d-element row — the roofline's currency.
    ``fmt`` may be a format name or anything `jnp.dtype` accepts."""
    if is_format(fmt):
        if effective_format(fmt, d) == "mxint4_blk":
            return d / 2 + d / mx.GROUP_SIZE      # packed mantissas + exps
        return d + 4.0                            # int8 + one f32 scale
    return d * jnp.dtype(fmt).itemsize


# -- int8_tok ----------------------------------------------------------------

def _encode_int8_tok(x: jax.Array) -> dict:
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _decode_int8_tok(leaf: dict) -> jax.Array:
    return leaf["q"].astype(jnp.float32) * leaf["s"]


# -- mxint4_blk --------------------------------------------------------------
# Reuses the weight codec's constants/geometry (core/mxint4.py) on N-D cache
# leaves: groups of GROUP_SIZE along the last axis share a power-of-two
# scale 2^(e - MANT_SHIFT); mantissas are 4-bit two's complement packed
# low-nibble-first two-per-int8.  Exponents stay one int8 per group
# (unpacked): one byte per 16 elements is already noise next to the
# mantissa stream and keeps odd group counts representable.

def _encode_mxint4_blk(x: jax.Array) -> dict:
    xf = x.astype(jnp.float32)
    d = xf.shape[-1]
    g = xf.reshape(xf.shape[:-1] + (d // mx.GROUP_SIZE, mx.GROUP_SIZE))
    gmax = jnp.max(jnp.abs(g), axis=-1)
    safe = jnp.where(gmax > 0, gmax, 2.0 ** mx.SHIFT_MIN)
    _, e = jnp.frexp(safe)
    exps = jnp.clip(e - 1, mx.SHIFT_MIN, mx.SHIFT_MAX).astype(jnp.int8)
    scale = jnp.exp2(exps.astype(jnp.float32) - mx.MANT_SHIFT)
    mant = jnp.round(g / scale[..., None])
    mant = jnp.clip(mant, mx.MANT_MIN, mx.MANT_MAX).astype(jnp.int8)
    flat = mant.reshape(xf.shape[:-1] + (d,))
    lo, hi = flat[..., 0::2], flat[..., 1::2]
    packed = ((lo & 0x0F) | (hi << 4)).astype(jnp.int8)
    return {"m": packed, "e": exps}


def _decode_mxint4_blk(leaf: dict) -> jax.Array:
    m, e = leaf["m"], leaf["e"]
    lo = jnp.left_shift(m, 4)
    lo = jnp.right_shift(lo, 4)                     # arithmetic: sign-extends
    hi = jnp.right_shift(m, 4)
    mant = jnp.stack([lo, hi], axis=-1).reshape(m.shape[:-1] + (2 * m.shape[-1],))
    scale = jnp.exp2(e.astype(jnp.float32) - mx.MANT_SHIFT)
    g = mant.astype(jnp.float32).reshape(
        m.shape[:-1] + (e.shape[-1], mx.GROUP_SIZE))
    return (g * scale[..., None]).reshape(mant.shape)


# -- public API --------------------------------------------------------------

def encode(x: jax.Array, fmt: str) -> dict:
    """Encode a cache leaf (last axis = feature dim) into format ``fmt``.
    Already-encoded dicts pass through (idempotent on matching structure)."""
    if isinstance(x, dict):
        return x
    fmt = effective_format(fmt, x.shape[-1])
    if fmt == "mxint4_blk":
        return _encode_mxint4_blk(x)
    return _encode_int8_tok(x)


def encode_like(x: jax.Array, leaf) -> dict:
    """Encode ``x`` into the same format as an existing encoded leaf —
    the cache-append path: new K/V rows must match the resident store."""
    fmt = leaf_format(leaf)
    if fmt is None:
        raise TypeError(f"encode_like target is not an encoded cache leaf: "
                        f"{type(leaf).__name__}")
    return encode(x, fmt)


def decode(leaf) -> jax.Array:
    """Encoded leaf dict (or plain array) -> f32 array.  Plain int8 arrays
    take the legacy static-scale path (`KV8_SCALE`); other dtypes upcast."""
    fmt = leaf_format(leaf)
    if fmt == "int8_tok":
        return _decode_int8_tok(leaf)
    if fmt == "mxint4_blk":
        return _decode_mxint4_blk(leaf)
    if hasattr(leaf, "dtype") and leaf.dtype == jnp.int8:
        return leaf.astype(jnp.float32) / KV8_SCALE
    return leaf.astype(jnp.float32) if hasattr(leaf, "astype") else leaf


def zeros(shape: tuple, fmt: str) -> dict:
    """Zero-initialized encoded leaf, bit-identical to ``encode(zeros)`` —
    required so pool stores, spill round trips and rollback merges of
    untouched slots compare equal to freshly-encoded zero rows."""
    d = shape[-1]
    fmt = effective_format(fmt, d)
    lead = tuple(shape[:-1])
    if fmt == "mxint4_blk":
        return {"m": jnp.zeros(lead + (d // 2,), jnp.int8),
                "e": jnp.full(lead + (d // mx.GROUP_SIZE,), mx.SHIFT_MIN,
                              jnp.int8)}
    return {"q": jnp.zeros(shape, jnp.int8),
            "s": jnp.ones(lead + (1,), jnp.float32)}
