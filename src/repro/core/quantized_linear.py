"""QuantizedLinear — one weight, three execution paths (the HSA's PE array
seen from software).

A `QuantizedLinear` owns a single logical weight ``W[K, N]`` stored in up to
three formats, mirroring the paper's storage scheme:

  * ``w``        — bf16/f32 master (training; absent in deploy-only mode)
  * ``w8``       — per-tensor INT8 (prefill MMM dataflow, Fig. 4b)
  * ``mx``       — MXINT4 packed + group shifts (decode MVM dataflow, Fig. 4c)

`apply` dispatches on the requested phase and implements the Eq. (4) epilogue
(`row_scale` = sigma^{-1} from the upstream fused RMSNorm, `bias` = folded
B_{n+1}).  The HSA engine (hsa.py) chooses the phase; models never pick
formats directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import mxint4 as mx
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedLinearParams:
    """Pytree of all stored formats for one linear layer."""

    w: jax.Array | None          # [K, N] master (None in deploy-only mode)
    w8: mx.Int8Weight | None     # prefill format
    mx: mx.MXINT4Weight | None   # decode format
    bias: jax.Array | None       # [N] (includes folded B_{n+1} when fused)


def quantize_params(w: jax.Array, bias: jax.Array | None = None,
                    keep_master: bool = True) -> QuantizedLinearParams:
    """PTQ one weight into all deploy formats (Section III pipeline)."""
    return QuantizedLinearParams(
        w=w if keep_master else None,
        w8=mx.quantize_int8_tensor(w),
        mx=mx.quantize_mxint4(w),
        bias=bias,
    )


def apply(
    params: QuantizedLinearParams,
    x: jax.Array,
    phase: str,                       # 'train' | 'prefill' | 'decode'
    *,
    row_scale: jax.Array | None = None,   # sigma^{-1}, per token (Eq. 4)
    out_scale: jax.Array | float | None = None,
    impl: str = "auto",
    out_dtype=jnp.float32,
    kernel_opts: dict[str, Any] | None = None,
) -> jax.Array:
    """Run ``y = (x @ W) * out_scale * row_scale + bias`` in the phase format."""
    kernel_opts = kernel_opts or {}
    if phase == "train" or (phase == "prefill" and params.w8 is None):
        if params.w is None:
            raise TypeError("master weight required for train phase")
        y = (x.astype(jnp.float32) @ params.w.astype(jnp.float32))
        if out_scale is not None:
            y = y * out_scale
        if row_scale is not None:
            y = y * row_scale[..., None]
        if params.bias is not None:
            y = y + params.bias
        return y.astype(out_dtype)

    if phase == "prefill":
        # MMM dataflow: dynamic A8, per-tensor W8, int32 accumulate on the MXU.
        xq, act_scale = mx.quantize_act_int8(x)
        combined = act_scale * params.w8.scale * (
            1.0 if out_scale is None else out_scale)
        return ops.w8a8_matmul(
            xq, params.w8.values, combined,
            row_scale=row_scale, bias=params.bias, out_dtype=out_dtype)

    if phase == "decode":
        # MVM dataflow: MXINT4 weights, dequant fused into the kernel (C2).
        os = None
        if out_scale is not None:
            os = jnp.broadcast_to(jnp.asarray(out_scale, jnp.float32),
                                  (params.mx.shape[1],))
        return ops.mxint4_matmul(
            x, params.mx, out_scale=os,
            row_scale=row_scale,
            bias=params.bias, out_dtype=out_dtype, impl=impl, **kernel_opts)

    raise ValueError(f"unknown phase: {phase!r}")


def streamed_bytes(params: QuantizedLinearParams, phase: str) -> int:
    """Weight bytes the phase's dataflow moves from HBM/DRAM (the EMA metric)."""
    if phase == "decode":
        return params.mx.nbytes_streamed()
    if phase == "prefill":
        return params.w8.nbytes_streamed()
    return int(params.w.size * params.w.dtype.itemsize)
