"""SmoothQuant activation smoothing (Section III: "We first adopt SmoothQuant
and compress the activation precision down to INT8").

SmoothQuant migrates activation outliers into the weights with a per-input-
channel scale

    s_j = max|X_j|^alpha / max|W_j|^(1 - alpha)

so that ``(X / s) @ (s * W) == X @ W`` exactly, but ``X / s`` now quantizes to
INT8 with far less clipping error.  The division by ``s`` is folded into the
*producer* of X (the previous layer's output projection or the preceding
norm's gamma), so smoothing is free at inference — exactly how the paper's
accelerator consumes it (activations arrive already-smoothed, INT8).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CalibStats:
    """Per-input-channel absolute maxima collected from calibration batches."""

    act_absmax: jax.Array  # f32 [K]
    weight_absmax: jax.Array  # f32 [K]


def collect_act_absmax(x: jax.Array) -> jax.Array:
    """Reduce a batch of activations ``[..., K]`` to per-channel abs-max."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=tuple(range(x.ndim - 1)))


def merge_absmax(a: jax.Array, b: jax.Array) -> jax.Array:
    """Running-max merge across calibration batches (the PTQ loop)."""
    return jnp.maximum(a, b)


def smoothing_scales(stats: CalibStats, alpha: float = 0.5, eps: float = 1e-6) -> jax.Array:
    """Compute s_j; clamped away from zero so the fold stays invertible."""
    a = jnp.maximum(stats.act_absmax, eps)
    w = jnp.maximum(stats.weight_absmax, eps)
    s = jnp.power(a, alpha) / jnp.power(w, 1.0 - alpha)
    # Normalize so the geometric mean is 1 — keeps both tensors in range.
    s = s / jnp.exp(jnp.mean(jnp.log(s)))
    return jnp.maximum(s, eps)


def apply_smoothing(w: jax.Array, s: jax.Array) -> jax.Array:
    """Scale weights by s along the input-channel (K) axis: ``W[K,N] * s[K,None]``."""
    return w * s[:, None].astype(w.dtype)


def fold_into_producer_gamma(gamma: jax.Array, s: jax.Array) -> jax.Array:
    """Fold ``1/s`` into the preceding RMSNorm/LayerNorm gamma (free smoothing)."""
    return gamma / s.astype(gamma.dtype)


def smooth_linear_pair(
    gamma: jax.Array, w: jax.Array, act_absmax: jax.Array, alpha: float = 0.5
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-shot PTQ transform for a (norm -> linear) pair.

    Returns (gamma', W', s) with ``rmsnorm(x; gamma') @ W' == rmsnorm(x; gamma) @ W``.
    """
    stats = CalibStats(
        act_absmax=act_absmax.astype(jnp.float32),
        weight_absmax=jnp.max(jnp.abs(w.astype(jnp.float32)), axis=1),
    )
    s = smoothing_scales(stats, alpha=alpha)
    return fold_into_producer_gamma(gamma, s), apply_smoothing(w, s), s
