"""Analytic edge latency/energy/area model — contribution C6.

Reproduces the paper's evaluation machinery: Fig. 1(b) (Jetson-class
breakdown), Fig. 3 (Llama vs RetNet footprint), Fig. 8 / Table I (conv-SA vs
vector-unit vs HSA) and Table II ("this work" row).  The paper itself evaluates
Table II analytically under a DDR5 51.2 GB/s bandwidth bound with
MAC = 0.5 pJ/Byte and DRAM = 32 pJ/Byte — this module implements that model
from first principles, with every constant explicit.

Model (per phase):
  latency  = max(compute_time, memory_time)          (overlapped engine)
  compute_time = macs / (peak_mac_rate * utilization) * ppu_overhead
  memory_time  = bytes_streamed / dram_bw
  energy   = macs * e_mac_per_op + dram_bytes * e_dram + sram_penalty

`ppu_overhead` models the post-processing bubble the paper's fused RMSNorm
removes (5-10 % of latency): 1.15 unfused -> 1.05 with C3+C4 enabled.

Calibration note (EXPERIMENTS.md §Paper-claims): with the paper's hardware
constants (256 PEs @ 500 MHz, 2 MAC/PE/cycle = 0.256 TOPS where 1 OP = 1 MAC,
51.2 GB/s) and RetNet-1.3B, this model lands within ±12 % of every Table I /
Table II entry and preserves all orderings; residuals are un-reported
micro-architectural detail (SRAM banking, drain cycles).
"""

from __future__ import annotations

import dataclasses

from repro.core.hsa import ArrayArch, CONV_SA, HSA, VECTOR_UNIT  # noqa: F401

PJ = 1e-12
GB = 1e9


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_mac_per_s: float            # MACs/s at full utilization
    dram_bw: float                   # bytes/s
    area_mm2: float
    e_mac: float = 1.0 * PJ          # J per MAC (2 int8 operand bytes x 0.5 pJ/B)
    e_dram: float = 32.0 * PJ        # J per DRAM byte  [2], [18]
    e_sram: float = 0.18 * PJ        # J per on-chip SRAM byte (refetch penalty)
    freq_hz: float = 500e6
    prefill_tile: int = 16           # tokens batched per weight pass (ASIC
    #                                  activation-SRAM limit, Sec. IV-A)


# The paper's accelerator: 256 PEs, 500 MHz, 2 MAC/PE/cycle, 0.636 mm^2, DDR5.
# e_mac = 0.5 pJ/MAC (the paper's "MAC=0.5pJ/Byte" at one int8 operand byte);
# prefill streams each weight from DRAM once per prompt (the 16-token tile is
# a PE-array batching limit, not a DRAM-reload boundary) — both calibrated
# against Table II's prefill 0.773 / decode 24.06 mJ/token (EXPERIMENTS.md
# §Paper-claims).
PAPER_ACCEL = HardwareSpec(
    name="hsa_28nm", peak_mac_per_s=256 * 500e6 * 2, dram_bw=51.2 * GB,
    area_mm2=0.636, e_mac=0.5 * PJ, prefill_tile=10**6)

# Jetson Orin Nano reference (Fig. 1): 40 TOPS peak (=20e12 MACs), LPDDR5;
# a GPU streams whole prompts through each weight pass (no 16-token tile).
JETSON_ORIN_NANO = HardwareSpec(
    name="jetson_orin_nano", peak_mac_per_s=20e12, dram_bw=68 * GB,
    area_mm2=float("nan"), e_mac=1.0 * PJ, prefill_tile=10**6)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Per-token workload of one LLM (derived from a real config)."""

    name: str
    macs_per_token: float            # forward MACs per token
    weight_bytes_int8: float         # streamed weight bytes, int8 format
    state_bytes_per_token: float     # KV-cache/retention-state R+W per decode token
    act_bytes_per_token: float = 0.0
    kv_growth_bytes_per_token: float = 0.0   # KV written per token (grows for attn)

    def weight_bytes(self, bits: float) -> float:
        return self.weight_bytes_int8 * bits / 8.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    tokens_in: int
    tokens_out: int

    @property
    def total_tokens(self) -> int:
        return self.tokens_in + self.tokens_out


LISO = Scenario("LISO", 750, 50)     # long input short output (summarize)
SILO = Scenario("SILO", 50, 750)     # short input long output (generate)


@dataclasses.dataclass(frozen=True)
class PhaseResult:
    latency_s: float
    energy_j: float
    compute_time_s: float
    memory_time_s: float

    @property
    def bound(self) -> str:
        return "compute" if self.compute_time_s >= self.memory_time_s else "memory"


def prefill(model: ModelSpec, hw: HardwareSpec, arch: ArrayArch,
            n_tokens: int, weight_bits: float = 8.0,
            ppu_overhead: float = 1.05) -> PhaseResult:
    """MMM phase: weights reloaded once per PREFILL_TILE-token tile."""
    macs = model.macs_per_token * n_tokens
    rate = hw.peak_mac_per_s * arch.mmm_utilization
    t_compute = macs / rate * ppu_overhead
    tile = hw.prefill_tile
    n_tiles = max(1, -(-n_tokens // tile))
    dram_bytes = model.weight_bytes(weight_bits) * n_tiles \
        + model.act_bytes_per_token * n_tokens
    t_mem = dram_bytes / hw.dram_bw
    energy = macs * hw.e_mac + dram_bytes * hw.e_dram
    if not arch.weight_reuse_prefill:
        # Vector unit refetches weights from SRAM per output element row:
        # each weight byte is read ~tile times instead of once.
        energy += model.weight_bytes(weight_bits) * n_tiles \
            * (min(tile, n_tokens) - 1) * hw.e_sram
    return PhaseResult(max(t_compute, t_mem), energy, t_compute, t_mem)


def decode(model: ModelSpec, hw: HardwareSpec, arch: ArrayArch,
           n_tokens: int, weight_bits: float | None = None,
           ppu_overhead: float = 1.05) -> PhaseResult:
    """MVM phase: every weight streamed from DRAM for every token."""
    bits = arch.decode_weight_bits if weight_bits is None else weight_bits
    macs = model.macs_per_token * n_tokens
    rate = hw.peak_mac_per_s * arch.mvm_utilization
    t_compute = macs / rate * ppu_overhead
    dram_per_tok = (model.weight_bytes(bits) + model.state_bytes_per_token
                    + model.act_bytes_per_token + model.kv_growth_bytes_per_token)
    t_mem = dram_per_tok * n_tokens / hw.dram_bw
    energy = macs * hw.e_mac + dram_per_tok * n_tokens * hw.e_dram
    return PhaseResult(max(t_compute, t_mem), energy, t_compute, t_mem)


@dataclasses.dataclass(frozen=True)
class EndToEnd:
    scenario: Scenario
    prefill: PhaseResult
    decode: PhaseResult

    @property
    def latency_s(self) -> float:
        return self.prefill.latency_s + self.decode.latency_s

    @property
    def energy_j(self) -> float:
        return self.prefill.energy_j + self.decode.energy_j

    @property
    def tokens_per_s(self) -> float:
        """Paper convention: 'token' = prompt + output tokens (Sec. V-A)."""
        return self.scenario.total_tokens / self.latency_s

    @property
    def tokens_per_j(self) -> float:
        return self.scenario.total_tokens / self.energy_j

    def tokens_per_s_per_mm2(self, hw: HardwareSpec) -> float:
        return self.tokens_per_s / hw.area_mm2

    @property
    def prefill_mj_per_token(self) -> float:
        return self.prefill.energy_j / max(1, self.scenario.tokens_in) * 1e3

    @property
    def decode_mj_per_token(self) -> float:
        return self.decode.energy_j / max(1, self.scenario.tokens_out) * 1e3


def run_scenario(model: ModelSpec, hw: HardwareSpec, arch: ArrayArch,
                 scenario: Scenario, prefill_bits: float = 8.0,
                 decode_bits: float | None = None,
                 ppu_overhead: float = 1.05) -> EndToEnd:
    return EndToEnd(
        scenario,
        prefill(model, hw, arch, scenario.tokens_in, prefill_bits, ppu_overhead),
        decode(model, hw, arch, scenario.tokens_out, decode_bits, ppu_overhead),
    )


def retnet_model_spec(params: float, n_layers: int, d_model: int,
                      n_heads: int, name: str = "retnet") -> ModelSpec:
    """RetNet: O(1) recurrent state (Sec. II) — dk x dv per head per layer."""
    dk = d_model // n_heads
    dv = 2 * d_model // n_heads
    state = n_layers * n_heads * dk * dv          # int8 elements
    return ModelSpec(
        name=name, macs_per_token=params,          # 1 MAC per param per token
        weight_bytes_int8=params,
        state_bytes_per_token=2 * state,           # read + write each token
        act_bytes_per_token=2 * n_layers * d_model,
        kv_growth_bytes_per_token=0.0)


def attention_model_spec(params: float, n_layers: int, d_model: int,
                         n_kv_heads: int, head_dim: int, avg_context: float,
                         name: str = "llama") -> ModelSpec:
    """Softmax-attention LLM: KV cache grows; decode reads the whole cache."""
    kv_per_tok = 2 * n_layers * n_kv_heads * head_dim   # int8 bytes appended
    return ModelSpec(
        name=name, macs_per_token=params,
        weight_bytes_int8=params,
        state_bytes_per_token=kv_per_tok * avg_context,  # read full cache
        act_bytes_per_token=2 * n_layers * d_model,
        kv_growth_bytes_per_token=kv_per_tok)
