"""HSA execution engine — the software realization of contribution C1.

The paper's Hybrid Systolic Array is one physical PE array with two dataflows,
selected per inference phase:

  * prefill  -> MMM dataflow (Fig. 4b): output-stationary systolic, W8A8,
                weight + activation reuse, compute-bound.
  * decode   -> MVM dataflow (Fig. 4c): 4 independent PE clusters, MXINT4
                weights dequantized in-array, memory-bound, 100 % utilization
                at batch 1.

On TPU the "array" is the MXU and the two dataflows become two compiled
execution paths over the *same* stored weights.  `HSAEngine` is the single
place that choice is made: models call ``engine.linear(...)`` and the engine
selects format + kernel from the phase, exactly like the accelerator's
sequencer reconfigures the PE array.  It also owns the utilization model that
quantifies why the hybrid beats either pure architecture (Fig. 2 / Table I).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quantized_linear as ql

PHASES = ("train", "prefill", "decode")


@dataclasses.dataclass(frozen=True)
class HSAConfig:
    """Phase -> numeric format policy (the paper's default = W8A8 / W4A8)."""

    prefill_format: str = "w8a8"        # 'w8a8' | 'fp'
    decode_format: str = "mxint4"       # 'mxint4' | 'w8a8' | 'fp'
    fuse_rmsnorm: bool = True           # C3: Eq. (4) epilogue fusion
    online_rope: bool = True            # C4: identity-update RoPE in decode
    out_dtype: str = "float32"
    kernel_impl: str = "auto"           # 'auto' | 'pallas' | 'ref'


class HSAEngine:
    """Phase-dependent linear-layer dispatcher (one per model instance).

    Accepts the model zoo's plain param dicts: any subset of
    ``{'w', 'b', 'w8_vals', 'w8_scale', 'mx_packed', 'mx_exps'}`` (the latter
    four attached by models/deploy.py).  Falls back gracefully: a format the
    config requests but deployment didn't produce degrades to the best
    available one — so training params (master-only) always run.
    """

    def __init__(self, config: HSAConfig | None = None):
        self.config = config or HSAConfig()

    def linear(
        self,
        p: dict,
        x: jax.Array,
        phase: str,
        *,
        row_scale: jax.Array | None = None,
        out_scale: jax.Array | float | None = None,
    ) -> jax.Array:
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r}; expected one of {PHASES}")
        cfg = self.config
        fmt = {"train": "fp", "prefill": cfg.prefill_format,
               "decode": cfg.decode_format}[phase]
        if fmt == "mxint4" and "mx_packed" not in p:
            fmt = "w8a8"
        if fmt == "w8a8" and "w8_vals" not in p:
            fmt = "fp"

        if not cfg.fuse_rmsnorm:
            # Unfused ablation: caller already normalized; drop the epilogue.
            row_scale = None

        mxw = None
        if fmt == "mxint4":
            packed = p["mx_packed"]
            mxw = ql.mx.MXINT4Weight(
                packed=packed, exps_packed=p["mx_exps"],
                shape=(packed.shape[0], packed.shape[1] * 2))
        params = ql.QuantizedLinearParams(
            w=p.get("w"),
            w8=(ql.mx.Int8Weight(p["w8_vals"], p["w8_scale"])
                if fmt == "w8a8" else None),
            mx=mxw,
            bias=p.get("b"),
        )
        eff_phase = {"fp": "train", "w8a8": "prefill", "mxint4": "decode"}[fmt]
        return ql.apply(
            params, x, eff_phase, row_scale=row_scale, out_scale=out_scale,
            impl=cfg.kernel_impl, out_dtype=jnp.dtype(cfg.out_dtype))


# ---------------------------------------------------------------------------
# Utilization model (Fig. 2 / Fig. 8 / Table I) — how busy is the PE array?
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArrayArch:
    """An abstract MAC-array architecture for the triple comparison."""

    name: str
    pe_rows: int = 16
    pe_cols: int = 16
    mvm_utilization: float = 1.0     # fraction of PEs busy at batch=1 decode
    mmm_utilization: float = 1.0
    weight_reuse_prefill: bool = True   # SA-style reuse (vs vector-unit SRAM refetch)
    decode_weight_bits: float = 8.0     # effective bits/weight streamed in decode


# Paper's three contenders (Fig. 2).  Conventional SA cannot keep its columns
# busy on MVM (one activation vector, no batching): only one PE row's worth of
# work per cycle reaches the array => utilization ~ 1/rows.  The vector unit is
# fully utilized both phases but re-fetches weights from SRAM during prefill
# (no systolic reuse, 36 % more energy per Fig. 8).  The HSA gets both.
CONV_SA = ArrayArch("conv_sa", mvm_utilization=1.0 / 16.0,
                    weight_reuse_prefill=True, decode_weight_bits=8.0)
VECTOR_UNIT = ArrayArch("vector_unit", mvm_utilization=1.0,
                        weight_reuse_prefill=False, decode_weight_bits=8.0)
HSA = ArrayArch("hsa", mvm_utilization=1.0, weight_reuse_prefill=True,
                decode_weight_bits=4.25)  # MXINT4: 4b mantissa + 4b/16 shift


def mvm_effective_macs_per_s(arch: ArrayArch, freq_hz: float,
                             macs_per_pe_cycle: float = 2.0) -> float:
    """Decode-phase effective MAC rate (utilization-discounted)."""
    pes = arch.pe_rows * arch.pe_cols
    return pes * freq_hz * macs_per_pe_cycle * arch.mvm_utilization


def mmm_effective_macs_per_s(arch: ArrayArch, freq_hz: float,
                             macs_per_pe_cycle: float = 2.0) -> float:
    pes = arch.pe_rows * arch.pe_cols
    return pes * freq_hz * macs_per_pe_cycle * arch.mmm_utilization
