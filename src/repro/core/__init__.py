"""Core library: the HSA paper's contributions as composable JAX modules.

C1 hsa.py — hybrid (phase-dependent) execution engine
C2 mxint4.py + smoothquant.py — MXINT4 W4A8 quantization (Eq. 1)
C3 fused_rmsnorm.py — layer-fused RMSNorm (Eq. 4)
C4 online_rope.py — Embed/Update-mode RoPE (Eq. 5-6)
C5 retention.py — RetNet retention forms
C6 edge_model.py — analytic edge latency/energy/area evaluation
"""

from repro.core import (  # noqa: F401
    edge_model,
    fused_rmsnorm,
    hsa,
    mxint4,
    online_rope,
    quantized_linear,
    retention,
    smoothquant,
)
