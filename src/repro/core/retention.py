"""Multi-scale retention (RetNet) — the paper's target model family (Sec. II).

RetNet replaces softmax attention with a *decaying causal mask* D:

    parallel  :  Y = (Q K^T  .*  D) V,          D[n, m] = gamma^(n-m)  (n >= m)
    recurrent :  S_n = gamma * S_{n-1} + k_n^T v_n ;   y_n = q_n S_n
    chunkwise :  cross-chunk via the state S, intra-chunk via the parallel form

The three forms are mathematically identical (a property test asserts this),
which is exactly why the paper picked RetNet for edge inference: prefill runs
the compute-friendly parallel/chunkwise form (MMM on the systolic array) while
decode runs the O(1)-state recurrent form (MVM) — no KV cache growth, no
softmax unit.

Per-head decay (multi-scale): ``gamma_h = 1 - 2^(-5-h)``, h = 0..H-1.

Shapes: q, k ``[B, H, S, dk]``; v ``[B, H, S, dv]``; state ``[B, H, dk, dv]``.
The 1/sqrt(dk) scale is folded into q by the caller (models/retnet.py).
These pure-jnp forms are the oracles for kernels/retention_kernel.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def head_decays(num_heads: int) -> jax.Array:
    """gamma_h = 1 - 2^(-5-h) — RetNet's multi-scale decay schedule."""
    h = jnp.arange(num_heads, dtype=jnp.float32)
    return 1.0 - jnp.exp2(-5.0 - h)


def decay_mask(seq_len: int, gamma: jax.Array) -> jax.Array:
    """D[h, n, m] = gamma_h^(n-m) for n >= m else 0  (computed in log space)."""
    n = jnp.arange(seq_len, dtype=jnp.float32)
    diff = n[:, None] - n[None, :]                      # [S, S]
    log_g = jnp.log(gamma)[:, None, None]               # [H, 1, 1]
    mask = diff >= 0
    d = jnp.exp(jnp.where(mask, diff * log_g, -jnp.inf))
    return jnp.where(mask, d, 0.0)                      # [H, S, S]


def retention_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                       gamma: jax.Array) -> jax.Array:
    """Parallel form (prefill / training): ``(QK^T .* D) V``."""
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    scores = jnp.einsum("bhnd,bhmd->bhnm", qf, kf)
    d = decay_mask(q.shape[2], gamma)                    # [H, S, S]
    return jnp.einsum("bhnm,bhmv->bhnv", scores * d[None], vf).astype(v.dtype)


def retention_recurrent_step(q_t: jax.Array, k_t: jax.Array, v_t: jax.Array,
                             state: jax.Array, gamma: jax.Array
                             ) -> tuple[jax.Array, jax.Array]:
    """One decode step.  q_t/k_t ``[B, H, dk]``, v_t ``[B, H, dv]``,
    state ``[B, H, dk, dv]`` -> (y_t ``[B, H, dv]``, new state).

    This is the O(1)-memory MVM workload the HSA decode dataflow targets.
    """
    qf, kf, vf = (t.astype(jnp.float32) for t in (q_t, k_t, v_t))
    new_state = gamma[None, :, None, None] * state + kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", qf, new_state)
    return y.astype(v_t.dtype), new_state


def retention_recurrent(q: jax.Array, k: jax.Array, v: jax.Array,
                        gamma: jax.Array,
                        state: jax.Array | None = None,
                        return_states: bool = False):
    """Scan the recurrent form over a sequence (oracle for equivalence tests).

    ``return_states=True`` additionally returns the state *after every step*,
    stacked on a new axis 1 (``[B, S, H, dk, dv]``) — the per-position state
    snapshots speculative decode rolls back to when a drafted token is
    rejected at an arbitrary depth inside the verified block.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(st, qkv):
        q_t, k_t, v_t = qkv
        y, st = retention_recurrent_step(q_t, k_t, v_t, st, gamma)
        return st, (y, st) if return_states else y

    qs, ks, vs = (jnp.moveaxis(t, 2, 0) for t in (q, k, v))
    state, ys = jax.lax.scan(step, state, (qs, ks, vs))
    if return_states:
        ys, states = ys
        return jnp.moveaxis(ys, 0, 2), state, jnp.moveaxis(states, 0, 1)
    return jnp.moveaxis(ys, 0, 2), state


def retention_chunkwise(q: jax.Array, k: jax.Array, v: jax.Array,
                        gamma: jax.Array, chunk: int = 128,
                        state: jax.Array | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Chunkwise form: O(S * c) memory, matmul-dense — the long-context path.

    Per chunk of length c (positions m = 1..c inside the chunk, state carried
    from previous chunks):
        inner  = (Q K^T .* D) V                          (parallel, in-chunk)
        cross  = (Q .* gamma^m) @ S_prev                 (contribution of past)
        S_new  = gamma^c * S_prev + sum_m gamma^(c-m) k_m^T v_m
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if s % chunk != 0:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    nchunks = s // chunk
    qc = q.reshape(b, h, nchunks, chunk, dk).astype(jnp.float32)
    kc = k.reshape(b, h, nchunks, chunk, dk).astype(jnp.float32)
    vc = v.reshape(b, h, nchunks, chunk, dv).astype(jnp.float32)

    m = jnp.arange(1, chunk + 1, dtype=jnp.float32)
    log_g = jnp.log(gamma)                                   # [H]
    in_decay = jnp.exp(m[None, :] * log_g[:, None])          # gamma^m    [H, c]
    out_decay = jnp.exp((chunk - m)[None, :] * log_g[:, None])  # gamma^(c-m)
    chunk_decay = jnp.exp(chunk * log_g)                     # gamma^c    [H]
    d = decay_mask(chunk, gamma)                             # [H, c, c]

    def step(st, qkv):
        qi, ki, vi = qkv                                     # [B, H, c, d*]
        scores = jnp.einsum("bhnd,bhmd->bhnm", qi, ki) * d[None]
        inner = jnp.einsum("bhnm,bhmv->bhnv", scores, vi)
        cross = jnp.einsum("bhnd,bhdv->bhnv", qi * in_decay[None, :, :, None], st)
        kv = jnp.einsum("bhmd,bhmv->bhdv", ki * out_decay[None, :, :, None], vi)
        st = chunk_decay[None, :, None, None] * st + kv
        return st, inner + cross

    qs, ks, vs = (jnp.moveaxis(t, 2, 0) for t in (qc, kc, vc))
    state, ys = jax.lax.scan(step, state, (qs, ks, vs))
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, dv)
    return y.astype(v.dtype), state


def group_norm_heads(y: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RetNet's per-head GroupNorm (scale-free), applied after retention."""
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps)).astype(y.dtype)
