"""retnet-6.7b — the RetNet size the paper profiles against Llama-2 7B (Fig. 3)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="retnet-6.7b",
    family="retnet",
    attn_type="retention",
    n_layers=32,
    d_model=4096,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=32768,
)
