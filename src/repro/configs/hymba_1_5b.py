"""hymba-1.5b — hybrid parallel attention + mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hybrid block: attention and SSM branches in parallel on the same input, each
branch output RMSNorm'd then averaged (simplified from Hymba's meta-token +
per-head scheme — DESIGN.md §8).  Sliding window 1024 everywhere except
first/middle/last layers (full attention in prefill; decode degrades those to
the window — the long_500k feasibility deviation noted in DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    ssm_state=16,
    d_inner=3200,
    dt_rank=100,
    ssm_chunk=128,
)
