"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596; hf].

12L (encoder) + 12L (decoder) d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206.  Audio frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings [B, T, D]; the backbone (conformer-less
simplification) is a standard transformer enc-dec with sinusoidal absolute
positions and LayerNorm.  Decode shapes exercise the decoder with cross-
attention over the (frontend_tokens)-frame encoder output.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    norm_type="layernorm",
    rope=False,
    abs_pos_embed=True,
    frontend="audio",
    frontend_tokens=1536,
)
