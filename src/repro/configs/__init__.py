"""Architecture registry + per-cell `input_specs()`.

`--arch <id>` anywhere in the launch layer resolves through `get_config`.
`input_specs(cfg, shape)` returns weak-type-correct ShapeDtypeStruct stand-ins
for every model input of that workload cell — shardable, no device allocation
(the multi-pod dry-run pattern).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                 PREFILL_32K, TRAIN_4K, InputShape, ModelConfig)

from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.qwen1_5_4b import CONFIG as _qwen15
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.retnet_1_3b import CONFIG as _retnet13
from repro.configs.retnet_6_7b import CONFIG as _retnet67

# The 10 assigned architectures (the 40-cell grid) + the paper's own models.
ASSIGNED = (
    "hymba-1.5b", "falcon-mamba-7b", "deepseek-v3-671b", "olmoe-1b-7b",
    "internlm2-1.8b", "qwen1.5-4b", "qwen3-8b", "starcoder2-15b",
    "seamless-m4t-medium", "llava-next-34b",
)

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in (
        _hymba, _falcon_mamba, _dsv3, _olmoe, _internlm2, _qwen15, _qwen3,
        _starcoder2, _seamless, _llava, _retnet13, _retnet67)
}

SHAPES: dict[str, InputShape] = {s.name: s for s in ALL_SHAPES}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def cell_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable?  (False, reason) documents skips."""
    if shape.kind == "decode" and shape.seq_len > 32768 and not cfg.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention (DESIGN.md §4)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: InputShape,
                local_batch: int | None = None) -> dict:
    """ShapeDtypeStructs for the data inputs of one workload cell.

    `local_batch` overrides the global batch (smoke tests / examples run the
    reduced batch on one host).  Decode caches are built separately via
    ``jax.eval_shape(lm.make_decode_cache, ...)`` in the launch layer.
    """
    b = local_batch or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.param_dtype)

    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        return specs

    specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    if cfg.frontend == "vision":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, min(cfg.frontend_tokens, s), cfg.d_model), dt)
    if cfg.is_encdec:
        specs["src_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    return specs
