"""starcoder2-15b — dense GQA, LayerNorm, RoPE [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
LayerNorm => the Eq. (4) fusion uses the centered variant
(core/fused_rmsnorm.py::fused_layernorm_emit — DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    norm_type="layernorm",
)
