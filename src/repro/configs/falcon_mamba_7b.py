"""falcon-mamba-7b — attention-free Mamba-1 [arXiv:2410.05355; unverified].

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
Pure SSM stack: each layer = RMSNorm + mamba block (no separate FFN; d_ff=0
per the assignment).  No RoPE (C4 unit gated off — DESIGN.md §4), O(1) decode
state => runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=16,          # unused (attn-free); kept for param-counting helpers
    n_kv_heads=16,
    d_ff=0,
    vocab_size=65024,
    rope=False,
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    ssm_chunk=128,
)
