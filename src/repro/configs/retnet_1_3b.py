"""retnet-1.3b — the HSA paper's own target LLM (RetNet [23], Sec. II).

24L d_model=2048 8 retention heads (d_k 256, d_v 512) ffn 4096 vocab 32768
~= 1.34B params — matching the paper's 1.3B setting.  Decode state is O(1)
(h x d_k x d_v per layer), the property the paper's memory-bound decode
dataflow exploits; q/k get the RoPE (xPos-style) rotation served by the
online RoPE unit (C4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="retnet-1.3b",
    family="retnet",
    attn_type="retention",
    n_layers=24,
    d_model=2048,
    n_heads=8,
    n_kv_heads=8,
    d_ff=4096,
    vocab_size=32768,
)
