"""llava-next-34b — VLM backbone, anyres tiling [hf:llava-hf; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Vision frontend is a STUB: anyres tiling at (2x2 + base) x 576 = 2880 patch
tokens provided as precomputed embeddings occupying the prompt head
(`frontend_tokens`); the backbone is a dense GQA decoder.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    frontend_tokens=2880,
)
