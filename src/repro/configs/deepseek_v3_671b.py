"""deepseek-v3-671b — MLA + 256-expert MoE + MTP [arXiv:2412.19437; hf].

61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280, MoE 256e top-8,
1 shared expert, MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128),
first 3 layers dense (d_ff 18432), depth-1 MTP head.  The assignment's
d_ff=2048 is the routed-expert hidden size (moe_d_ff); dense layers use the
published 18432.  Decode uses absorbed-MLA (DESIGN.md §8).  Full attention =>
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    first_dense_layers=3,
    mtp=True,
)
