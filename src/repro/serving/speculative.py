"""Multi-token speculative decode — turning the MVM phase back into MMM work.

The paper's premise is that decode is *memory-bound*: every generated token
re-reads the full MXINT4 weight stream, so external memory accesses per
token — not compute — cap tokens/s (Sec. II; SLIM, arXiv:2507.09201, makes
the same edge-DRAM argument).  Speculative decoding amortizes one weight
pass over a whole block: a cheap **drafter** proposes ``k`` tokens, the
target model scores all of them in ONE chunk-shaped **verify** dispatch
(`lm.forward_verify_chunk` — the MMM admission primitive from the chunked-
prefill path, pointed at a decode-resident cache), and the accepted prefix
plus one freshly sampled token are committed.  Each verify step emits
``1..k+1`` tokens for a single weight-stream read.

Drafters (both deterministic proposals):

  * `NgramDrafter` — model-free prompt-lookup: match the trailing n-gram of
    (history + pending token) against the request's own token history and
    propose the historical continuation.  Free, and very effective on
    repetitive output (code, extraction, self-looping generations).
  * `MTPDrafter` — deepseek-v3 self-speculation: the depth-1 multi-token-
    prediction head (trained by `lm._mtp_loss`, promoted here from a
    training-only auxiliary to a decode-time draft model) chained ``k``
    deep via `lm.mtp_decode_step`.

Acceptance uses **token matching**, which for a *deterministic* drafter is
exactly Leviathan-style rejection sampling: at every draft position the
target distribution is sampled once; a draft is accepted while the target's
sample equals it.  Accept probability is p(draft) (the same coin), and the
first mismatching sample is already distributed as the rejection-sampling
residual p(· | · != draft) — so the emitted stream is distributed *exactly*
as the target model's own autoregressive sampling, and greedy decoding is
token-identical to the non-speculative fused loop (test-enforced per cache
architecture, including rollback).

Rollback on rejection is cache-kind-aware (`lm.commit_verified_cache`):
position-pointer rewind for linear KV / MLA latents, masked slot restore
for sliding-window rings (`layers.ring_rollback`), and per-position state
snapshots for RetNet retention / Mamba recurrent state.

MoE caveat: verify dispatches run `moe_apply(no_drop=True)` so rejected
draft tokens can't evict real tokens from expert capacity — at batch 1 the
baseline per-token dispatch never drops either, so greedy identity holds
exactly (test-enforced).  A *batched* MoE baseline can drop under skewed
routing (cap scales with B) while the verify pass never does; that residual
capacity-granularity gap is the same class of difference as chunked
prefill's per-chunk A8 scales — distribution-level behavior, not an error.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol

import jax
import jax.numpy as jnp

from repro.core.hsa import HSAEngine
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving.sampling import GenerationConfig, SpeculativeConfig, sample

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------


def ngram_propose(hist: jax.Array, hist_len: jax.Array, tok: jax.Array,
                  *, k: int, m: int) -> jax.Array:
    """Prompt-lookup proposal: continue the most recent history match.

    ``hist`` [B, H] is the request's token history (prompt + committed
    output, zero-padded); ``hist_len`` (traced i32 scalar) its fill level;
    ``tok`` [B] the pending token (sampled but not yet committed).  The
    trailing ``m``-gram *ending in the pending token* is matched against
    every committed window; the ``k`` tokens that followed the most recent
    occurrence are the draft.  No match (or a match whose continuation runs
    off the committed end) falls back to repeating the pending token — the
    degenerate draft that wins exactly on constant/looping output.
    """
    b, cap = hist.shape
    if cap < m + 1:
        # History can never contain an m-gram plus a continuation token:
        # degenerate to the repeat-pending-token fallback (shapes are
        # static, so this is a trace-time branch, not a crash in jnp.max
        # over an empty window set).
        return jnp.broadcast_to(tok[:, None], (b, k)).astype(jnp.int32)
    if m > 1:
        sidx = hist_len - (m - 1) + jnp.arange(m - 1)
        sfx = jnp.take(hist, jnp.clip(sidx, 0, cap - 1), axis=1)
        sfx = jnp.where(sidx[None, :] >= 0, sfx, -1)     # -1 never matches
        suffix = jnp.concatenate([sfx, tok[:, None]], axis=1)   # [B, m]
    else:
        suffix = tok[:, None]
    starts = jnp.arange(cap - m + 1)
    win = hist[:, starts[:, None] + jnp.arange(m)[None, :]]     # [B, J, m]
    ok = jnp.all(win == suffix[:, None, :], axis=-1)
    ok &= (starts + m <= hist_len)[None, :]      # window fully committed
    j = jnp.max(jnp.where(ok, starts[None, :], -1), axis=1)     # [B]
    didx = j[:, None] + m + jnp.arange(k)[None, :]              # [B, k]
    drafts = jnp.take_along_axis(hist, jnp.clip(didx, 0, cap - 1), axis=1)
    good = (j >= 0)[:, None] & (didx < hist_len)
    return jnp.where(good, drafts, tok[:, None]).astype(jnp.int32)


class Drafter(Protocol):
    """Deterministic k-token proposer riding inside the jitted decode loop.

    A drafter owns a pytree ``state`` carried through the speculative
    ``lax.while_loop``; the loop calls ``draft`` before each verify dispatch
    and ``observe`` after each commit.  Proposals never affect correctness —
    verification preserves the target distribution for *any* draft — only
    the acceptance rate.
    """

    k: int

    def init(self, hist: jax.Array, hist_len: jax.Array,
             hidden: jax.Array) -> Params: ...

    def draft(self, params: Params, state: Params,
              tok: jax.Array) -> jax.Array: ...

    def observe(self, state: Params, block: jax.Array, n_commit: jax.Array,
                hidden_all: jax.Array, next_tok: jax.Array) -> Params: ...


@dataclasses.dataclass(frozen=True)
class NgramDrafter:
    """Model-free prompt-lookup drafter (any architecture, zero extra FLOPs)."""

    k: int
    m: int = 2

    def init(self, hist, hist_len, hidden):
        return {"hist": hist, "len": jnp.asarray(hist_len, jnp.int32)}

    def draft(self, params, state, tok):
        return ngram_propose(state["hist"], state["len"], tok,
                             k=self.k, m=self.m)

    def observe(self, state, block, n_commit, hidden_all, next_tok):
        hist, hlen = state["hist"], state["len"]
        b, w = block.shape
        old = jax.lax.dynamic_slice(hist, (0, hlen), (b, w))
        keep = jnp.arange(w)[None, :] < n_commit
        hist = jax.lax.dynamic_update_slice(
            hist, jnp.where(keep, block, old), (0, hlen))
        return {"hist": hist, "len": hlen + jnp.asarray(n_commit, jnp.int32)}


@dataclasses.dataclass(frozen=True)
class MTPDrafter:
    """deepseek-v3 self-speculation: chain the depth-1 MTP head k deep."""

    k: int
    cfg: ModelConfig
    hsa: HSAEngine

    def init(self, hist, hist_len, hidden):
        return {"h": hidden}

    def draft(self, params, state, tok):
        h = state["h"]
        drafts = []
        for _ in range(self.k):
            logits, h = lm.mtp_decode_step(params, h, tok, self.cfg, self.hsa)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            drafts.append(tok)
        return jnp.stack(drafts, axis=1)

    def observe(self, state, block, n_commit, hidden_all, next_tok):
        # Chain the next draft from the hidden at the acceptance boundary:
        # hidden_all[:, j] is x_t for t = the j-th verified position, and the
        # pending `next_tok` plays tok_{t+1} in the head's [x_t ; emb] input.
        h = jax.lax.dynamic_index_in_dim(hidden_all, n_commit - 1, axis=1,
                                         keepdims=False)
        return {"h": h}


def make_drafter(spec: SpeculativeConfig, cfg: ModelConfig,
                 hsa: HSAEngine) -> Drafter:
    if spec.drafter == "mtp":
        if not cfg.mtp:
            raise ValueError(f"{cfg.name}: the 'mtp' drafter needs a config "
                             "with an MTP head (cfg.mtp=True)")
        return MTPDrafter(k=spec.k, cfg=cfg, hsa=hsa)
    return NgramDrafter(k=spec.k, m=spec.ngram)


# ---------------------------------------------------------------------------
# The verify/accept core (shared by the engine loop and the scheduler lanes)
# ---------------------------------------------------------------------------


def verify_block(params: Params, block: jax.Array, cache: Params,
                 key: jax.Array, *, cfg: ModelConfig, hsa: HSAEngine,
                 gen: GenerationConfig):
    """Score one [B, k+1] block (pending token + k drafts) and decide.

    One MMM dispatch over the warm cache; the target distribution is sampled
    at every position (token-matching == Leviathan rejection sampling for
    deterministic drafters — see module docstring).  Returns
    ``(cand [B, k+1], acc [B], hidden_all [B, k+1, D], ver_cache)``:
    ``cand[:, j]`` is the target's sample after consuming block positions
    0..j, and ``acc`` counts each row's leading draft matches (0..k).  The
    caller picks a commit depth (lockstep min in the engine loop, per-lane in
    the scheduler) and passes it to `lm.commit_verified_cache`.
    """
    k = block.shape[1] - 1
    logits_all, hidden_all, ver = lm.forward_verify_chunk(
        params, {"tokens": block}, cache, cfg, hsa)
    cand = sample(logits_all, gen.sampling, key)             # [B, k+1]
    match = (cand[:, :k] == block[:, 1:]).astype(jnp.int32)
    acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)        # [B] in 0..k
    return cand, acc, hidden_all, ver


# ---------------------------------------------------------------------------
# The fused speculative decode loop
# ---------------------------------------------------------------------------


def speculative_loop(params: Params, logits0: jax.Array, hidden0: jax.Array,
                     hist0: jax.Array, hist_len0: jax.Array, cache: Params,
                     key: jax.Array, *, cfg: ModelConfig, hsa: HSAEngine,
                     gen: GenerationConfig):
    """The speculative sibling of `InferenceEngine._loop_impl`.

    One ``lax.while_loop`` whose body drafts ``k`` tokens, verifies the
    ``k+1``-token block (pending token + drafts) in one MMM dispatch,
    commits the accepted prefix with exact rollback, and emits a *variable*
    ``1..k+1`` tokens per step.  Batch rows advance in lockstep: the commit
    depth is the minimum acceptance over live rows (rows that accepted more
    simply re-derive those tokens next step — free under greedy, and an
    unbiased re-sample under stochastic decoding), which keeps the cache's
    single position pointer valid for the whole batch.

    Returns (tokens [B, max_new_tokens], lengths [B], cache, verify_steps,
    accepted_drafts) — the last two feed tokens/step + acceptance-rate
    reporting.
    """
    spec = gen.speculative
    if spec is None:
        raise TypeError("gen.speculative must be set for speculative decode")
    k = spec.k
    b = logits0.shape[0]
    n = gen.max_new_tokens
    drafter = make_drafter(spec, cfg, hsa)
    stop = (jnp.asarray(gen.stop_tokens, jnp.int32)
            if gen.stop_tokens else None)

    def hit_stop(blk):                           # [B, W] -> bool [B, W]
        if stop is None:
            return jnp.zeros(blk.shape, bool)
        return jnp.any(blk[..., None] == stop, axis=-1)

    key, sub = jax.random.split(key)
    tok0 = sample(logits0, gen.sampling, sub)
    out0 = jnp.full((b, n + k), gen.pad_token_id, jnp.int32)
    dstate0 = drafter.init(hist0, hist_len0, hidden0)
    state = (jnp.int32(0), tok0, cache, jnp.zeros((b,), bool), out0,
             jnp.zeros((b,), jnp.int32), key, dstate0,
             jnp.int32(0), jnp.int32(0))

    def cond(st):
        i, _, _, done, _, _, _, _, _, _ = st
        return (i < n) & ~jnp.all(done)

    def body(st):
        i, tok, cache, done, out, lengths, key, dstate, steps, accepted = st
        drafts = drafter.draft(params, dstate, tok)            # [B, k]
        block = jnp.concatenate([tok[:, None], drafts], axis=1)
        key, sub = jax.random.split(key)
        cand, acc, hidden_all, ver = verify_block(
            params, block, cache, sub, cfg=cfg, hsa=hsa, gen=gen)
        # Lockstep commit depth; done rows don't constrain it.
        a = jnp.min(jnp.where(done, k, acc))                   # scalar
        n_commit = a + 1
        cache = lm.commit_verified_cache(cache, ver, n_commit, k + 1, cfg)

        # Emit [tok, d_1..d_a]; stop tokens inside the block pad its tail.
        cols = jnp.arange(k + 1)
        valid = (cols[None, :] <= a) & (i + cols[None, :] < n)
        sh = hit_stop(block) & valid
        cum = jnp.cumsum(sh.astype(jnp.int32), axis=1)
        emit = valid & ~done[:, None] & ((cum - sh) == 0)
        old = jax.lax.dynamic_slice(out, (0, i), (b, k + 1))
        out = jax.lax.dynamic_update_slice(
            out, jnp.where(emit, block, old), (0, i))
        lengths = lengths + jnp.sum(emit, axis=1)
        done = done | jnp.any(sh & emit, axis=1)

        # The sample at the acceptance boundary is the next pending token:
        # the corrected draw on a mismatch, the bonus token when all match.
        tok = jax.lax.dynamic_index_in_dim(cand, a, axis=1, keepdims=False)
        dstate = drafter.observe(dstate, block, n_commit, hidden_all, tok)
        return (i + n_commit, tok, cache, done, out, lengths, key, dstate,
                steps + 1, accepted + a)

    (_, _, cache, _, out, lengths, _, _, steps, accepted) = \
        jax.lax.while_loop(cond, body, state)
    return out[:, :n], lengths, cache, steps, accepted
