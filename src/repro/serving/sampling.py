"""Typed generation/sampling configuration and the in-loop token sampler.

`SamplingParams` describes the per-token distribution transform (temperature,
top-k, top-p); `GenerationConfig` adds loop-level controls (length, stop
tokens, padding).  Both are frozen/hashable so they can ride through
``jax.jit`` as static arguments — the fused decode loop specializes on them
(greedy compiles to a pure argmax with no RNG traffic at all).

`sample` is pure jnp and is called once per decode step *inside* the jitted
loop; all shape-affecting decisions (is top-k on? is this greedy?) are Python
branches over the static dataclass, so nothing dynamic leaks into the HLO.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-token distribution transform.

    temperature <= 0 means greedy (argmax); top_k == 0 and top_p >= 1.0
    disable the respective filters.  Filters compose in the usual order:
    temperature -> top-k -> top-p -> categorical draw.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Multi-token speculative decode (serving/speculative.py).

    Each decode step drafts ``k`` tokens, scores them in ONE chunk-shaped
    verify dispatch (MMM dataflow — one weight-stream read amortized over
    the whole block instead of one per token), and commits the accepted
    prefix + one freshly sampled token.  Frozen/hashable so it rides through
    ``jax.jit`` inside `GenerationConfig`.

    ``drafter``: 'ngram' (model-free prompt-lookup — matches the trailing
    ``ngram``-gram against the request's own history and proposes its
    historical continuation) or 'mtp' (deepseek-v3 depth-1 multi-token-
    prediction head chained ``k`` deep; requires ``cfg.mtp``).
    """

    k: int = 4                      # drafted tokens per verify step
    drafter: str = "ngram"          # 'ngram' | 'mtp'
    ngram: int = 2                  # lookup n-gram length (ngram drafter)

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.drafter not in ("ngram", "mtp"):
            raise ValueError(f"unknown drafter {self.drafter!r}")
        if self.ngram < 1:
            raise ValueError(f"ngram must be >= 1, got {self.ngram}")


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    """Loop-level generation controls for `InferenceEngine.generate`.

    ``cache_format`` selects the decode-residency KV encoding
    (`core.kvq.FORMATS`: 'int8_tok' | 'mxint4_blk'); None keeps the engine's
    fp cache.  Monolithic prefill stays fp and the cache is encoded once at
    the prefill/decode boundary (`lm.quantize_cache`); chunked prefill
    appends directly into the encoded layout.  Encoding is row-local, so
    the same K/V rows produce the same bits on both paths — but chunked
    attention *reads* the encoded history while monolithic attention reads
    fp, so downstream activations (hence later rows and logits) carry the
    usual chunked-vs-monolithic quantization-granularity difference.
    """

    max_new_tokens: int = 16
    sampling: SamplingParams = SamplingParams()
    stop_tokens: tuple[int, ...] = ()
    pad_token_id: int = 0
    speculative: SpeculativeConfig | None = None
    cache_format: str | None = None

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.cache_format is not None:
            from repro.core import kvq
            kvq.check_format(self.cache_format)


GREEDY = GenerationConfig()


def _top_k_mask(logits: jax.Array, k: int) -> jax.Array:
    """Keep the k largest logits per row, -inf elsewhere."""
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < kth, -jnp.inf, logits)


def _top_p_mask(logits: jax.Array, p: float) -> jax.Array:
    """Nucleus filter: keep the smallest prefix of the sorted distribution
    whose cumulative probability reaches p (the crossing token included)."""
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # exclusive cumsum: a token survives if the mass *before* it is < p
    keep_sorted = (cum - probs) < p
    # threshold logit = smallest kept logit per row
    thresh = jnp.min(jnp.where(keep_sorted, sorted_logits, jnp.inf),
                     axis=-1, keepdims=True)
    return jnp.where(logits < thresh, -jnp.inf, logits)


def sample(logits: jax.Array, params: SamplingParams,
           key: jax.Array) -> jax.Array:
    """logits [..., V] -> int32 token ids [...]. Pure; jit/vmap-safe."""
    if params.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        logits = _top_k_mask(logits, params.top_k)
    if params.top_p < 1.0:
        logits = _top_p_mask(logits, params.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
