"""Injectable time for the async serving front end.

Everything latency-shaped in the serving stack — arrival pacing, SLO
windows, TTFT stamps, the stepper's inter-step yield — flows through one
seam: a `Clock` with ``now()`` (the timebase handed to `RequestScheduler`
and the metrics registry) and ``sleep()`` (the only way front-end code is
allowed to wait).  Two implementations:

  * `MonotonicClock` — real deployments: ``time.perf_counter`` +
    ``asyncio.sleep``.
  * `VirtualClock` — tests and CI smoke runs: time is a number this object
    owns.  ``run(coro)`` drives the coroutine on a private event loop whose
    ``time()`` is virtual and whose selector never blocks — when every task
    is waiting on a timer, the loop *jumps* virtual time to the earliest
    deadline instead of sleeping.  Async code under it is wall-clock-free
    (a 10-minute simulated load run finishes in milliseconds) and
    deterministic: asyncio's ready queue and timer heap are FIFO-stable, so
    two runs of the same coroutine see the same interleaving, timestamps
    and all.

The virtual loop still polls real file descriptors (with timeout 0), so
incidental I/O readiness keeps working; but if nothing is ready *and* no
timer is scheduled, every task is blocked forever — that is a deadlock,
and the loop raises instead of hanging the test.
"""

from __future__ import annotations

import asyncio
import time
from typing import Any, Coroutine

__all__ = ["Clock", "MonotonicClock", "VirtualClock"]


class Clock:
    """The front end's time seam: ``now()`` for stamps, ``sleep()`` for
    waits, ``run()`` to drive a coroutine to completion on a loop whose
    notion of time matches ``now()``."""

    def now(self) -> float:
        raise NotImplementedError

    async def sleep(self, dt: float) -> None:
        raise NotImplementedError

    def run(self, coro: Coroutine[Any, Any, Any]) -> Any:
        raise NotImplementedError


class MonotonicClock(Clock):
    """Real time.  ``now_fn`` defaults to ``time.perf_counter`` — the same
    default the scheduler uses — and may be overridden to adopt an existing
    scheduler's timebase (`ServingFrontend` does exactly that)."""

    def __init__(self, now_fn=None):
        self._now_fn = now_fn if now_fn is not None else time.perf_counter

    def now(self) -> float:
        return self._now_fn()

    async def sleep(self, dt: float) -> None:
        await asyncio.sleep(max(0.0, dt))

    def run(self, coro: Coroutine[Any, Any, Any]) -> Any:
        return asyncio.run(coro)


class _JumpingSelector:
    """Selector wrapper that never blocks.

    The event loop computes how long it *would* sleep in ``select()``; this
    wrapper polls real FDs with timeout 0 and, when nothing is ready,
    credits that whole duration to the virtual clock — timers then fire on
    schedule in virtual time.  A would-be infinite select (no timers, no
    ready FDs) can never make progress: raise loudly rather than hang.
    """

    def __init__(self, inner, clock: "VirtualClock"):
        self._inner = inner
        self._clock = clock

    def select(self, timeout=None):
        ready = self._inner.select(0)
        if not ready and timeout:
            self._clock._t += timeout
        if not ready and timeout is None:
            raise RuntimeError(
                "virtual-clock deadlock: every task is blocked and no timer "
                "is scheduled (an await that only real time could satisfy)")
        return ready

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _VirtualTimeLoop(asyncio.SelectorEventLoop):
    """SelectorEventLoop on `VirtualClock` time: ``loop.time()`` is the
    virtual clock, so every ``call_later``/``asyncio.sleep``/timeout in the
    program schedules in virtual time; `_JumpingSelector` advances it."""

    def __init__(self, clock: "VirtualClock"):
        super().__init__()
        self._virtual = clock
        self._selector = _JumpingSelector(self._selector, clock)

    def time(self) -> float:
        return self._virtual._t


class VirtualClock(Clock):
    """Deterministic virtual time.  ``now()`` reads the owned counter;
    ``sleep()`` is a plain ``asyncio.sleep`` that the virtual loop resolves
    by jumping the counter; ``run()`` builds the loop, drives the coroutine,
    and tears down like ``asyncio.run`` (pending tasks cancelled, async
    generators shut down)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    async def sleep(self, dt: float) -> None:
        await asyncio.sleep(max(0.0, dt))

    def run(self, coro: Coroutine[Any, Any, Any]) -> Any:
        loop = _VirtualTimeLoop(self)
        try:
            asyncio.set_event_loop(loop)
            return loop.run_until_complete(coro)
        finally:
            try:
                _cancel_pending(loop)
                loop.run_until_complete(loop.shutdown_asyncgens())
            finally:
                asyncio.set_event_loop(None)
                loop.close()


def _cancel_pending(loop: asyncio.AbstractEventLoop) -> None:
    tasks = [t for t in asyncio.all_tasks(loop) if not t.done()]
    for t in tasks:
        t.cancel()
    if tasks:
        loop.run_until_complete(
            asyncio.gather(*tasks, return_exceptions=True))
