"""`InferenceEngine` — the one public entry point for serving a model.

Owns the full deployment chain the paper describes for its accelerator and
that every caller used to hand-wire:

    lm.init -> deploy.deploy_quantize -> HSAEngine -> jitted prefill/decode

plus a *fused* decode loop: instead of one Python-level ``jax.jit`` dispatch
per generated token (host-bound; the seed `generate()` re-built its jits per
call on top of that), the whole MVM phase runs as a single jitted
``lax.while_loop`` that samples, checks stop tokens, and advances the online
RoPE unit on-device.  Greedy decoding through the fused loop is token-
identical to the per-token Python loop (tests/test_serving_engine.py).

Usage::

    from repro.serving import EngineSpec, GenerationConfig, InferenceEngine

    engine = InferenceEngine.from_config("retnet-1.3b", EngineSpec(reduced=True))
    result = engine.generate(prompts, GenerationConfig(max_new_tokens=32))
    result.tokens        # [B, max_new_tokens], pad-filled after stop tokens
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat, configs
from repro.core.hsa import HSAConfig, HSAEngine
from repro.models import deploy, lm
from repro.models.config import InputShape, ModelConfig
from repro.obs import ENGINE_TRACK, Observability
from repro.runtime import sharding as shd
from repro.serving import speculative as spec_mod
from repro.serving.sampling import (GenerationConfig, SpeculativeConfig,
                                    sample)

Params = dict[str, Any]

# Prompt-length bucket ladder: prompts pad (bucketed) or decompose (chunked)
# to powers of two >= this floor, so K distinct lengths hit at most
# ~log2(max_len) cached prefill compiles instead of K.
MIN_BUCKET = 8


class CacheCapacityError(ValueError):
    """A request needs more KV slots than its cache provides.

    Raised at admission (`ChunkedPrefill`, `RequestScheduler.submit`)
    instead of letting the linear-cache decode path hit its slot clamp:
    `layers.gqa_decode` writes token ``pos`` at ``min(pos, C-1)``, so an
    overflowing request would silently overwrite its own last cache row
    on every subsequent step — degraded output, no error.
    """


def _jit_cache_size(fn) -> int:
    """Entries in one jitted callable's XLA compile cache; -1 when this jax
    does not expose it (the audit then falls back to the shape-key proxy)."""
    try:
        return int(fn._cache_size())
    except (AttributeError, TypeError):
        return -1


def pytree_nbytes(tree, *, per_device: bool = False) -> int:
    """Total bytes across a pytree's array (or ShapeDtypeStruct) leaves —
    the currency of the host-spill tier's transfer accounting.

    ``per_device=True`` reports what ONE chip holds: sharded leaves count
    their local shard (`sharding.shard_shape`) instead of the global array —
    the number that has to fit a single device's DRAM on a mesh.  Unsharded
    / abstract leaves count in full either way.
    """
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = math.prod(leaf.shape)
        if per_device and getattr(leaf, "sharding", None) is not None:
            try:
                n = math.prod(leaf.sharding.shard_shape(leaf.shape))
            except (AttributeError, ValueError):
                pass                       # odd sharding: count globally
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def bucket_length(s: int, min_bucket: int = MIN_BUCKET) -> int:
    """Smallest ladder bucket (power of two >= min_bucket) holding s tokens."""
    if s < 1:
        raise ValueError(f"prompt length must be >= 1, got {s}")
    b = min_bucket
    while b < s:
        b *= 2
    return b


def chunk_schedule(s: int, chunk_size: int,
                   min_bucket: int = MIN_BUCKET) -> list[int]:
    """Decompose a prompt of length s into exact ladder-sized chunks.

    Full `chunk_size` chunks, then the remainder split into descending
    powers of two (its binary decomposition) — no padding, so recurrent
    caches (RetNet state, Mamba h/conv) continue exactly, and the set of
    compiled chunk shapes stays <= log2(chunk_size) + 1 across *all* prompt
    lengths.  `min_bucket` is not applied here: exactness beats one or two
    extra tiny-chunk compiles.
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    sched = [chunk_size] * (s // chunk_size)
    rem = s % chunk_size
    p = 1
    while p <= rem:
        p *= 2
    p //= 2
    while rem:
        if p <= rem:
            sched.append(p)
            rem -= p
        p //= 2
    return sched


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """How to build the serving stack around a model config.

    The default is the paper's deployment: SmoothQuant-ready W8A8 prefill
    (MMM dataflow) and MXINT4 W4A8 decode (MVM dataflow) with the Eq. (4)
    fused RMSNorm and the online RoPE unit, `kernel_impl='auto'` picking the
    Pallas kernels on TPU and the jnp reference path elsewhere.
    """

    quantize: bool = True               # PTQ-deploy master weights
    prefill_format: str = "w8a8"        # 'w8a8' | 'fp'
    decode_format: str = "mxint4"       # 'mxint4' | 'w8a8' | 'fp'
    fuse_rmsnorm: bool = True           # C3 ablation switch
    kernel_impl: str = "auto"           # 'auto' | 'pallas' | 'ref'
    reduced: bool = False               # use cfg.reduced() (CPU-scale)
    seed: int = 0                       # init key when params aren't supplied

    def hsa_config(self) -> HSAConfig:
        fmt_p = self.prefill_format if self.quantize else "fp"
        fmt_d = self.decode_format if self.quantize else "fp"
        return HSAConfig(prefill_format=fmt_p, decode_format=fmt_d,
                         fuse_rmsnorm=self.fuse_rmsnorm,
                         kernel_impl=self.kernel_impl)


@dataclasses.dataclass
class GenerationResult:
    """Output of `InferenceEngine.generate`."""

    tokens: jax.Array        # i32 [B, max_new_tokens]; pad after stop token
    lengths: jax.Array       # i32 [B] — emitted tokens incl. the stop token
    prefill_s: float         # wall-clock MMM phase (includes compile on miss)
    decode_s: float          # wall-clock MVM phase
    # Speculative-path stats (zero on the plain fused loop):
    verify_steps: int = 0    # verify dispatches (weight-stream reads)
    accepted_drafts: int = 0  # drafted tokens the target model accepted
    drafted: int = 0         # total drafted tokens (verify_steps * k)

    @property
    def tokens_per_step(self) -> float:
        """Committed tokens per verify step (1.0 means no speculation win)."""
        if not self.verify_steps:
            return 1.0
        return 1.0 + self.accepted_drafts / self.verify_steps

    @property
    def acceptance_rate(self) -> float:
        return self.accepted_drafts / self.drafted if self.drafted else 0.0


class ChunkedPrefill:
    """One in-flight chunked prompt admission (MMM phase, cache-resident).

    Built by `InferenceEngine.begin_chunked_prefill`; the sequencer calls
    `advance()` once per cycle, so a long prompt overlaps ~n_chunks decode
    steps instead of blocking them.  After the final chunk, `logits` holds
    the last-token logits and `cache` the warm decode cache (identical — up
    to fp summation order — to a monolithic `prefill` of the same prompt).

    Shared-prefix adoption: with ``start_offset=p`` and an ``initial_cache``
    already warm over positions [0, p) (assembled by the pool's
    `PrefixCache` from shared pages or a snapshot), only the suffix
    ``tokens[:, p:]`` is scheduled — the first p tokens of prefill are
    skipped outright.  The chunk step reads its start position from the
    traced ``cache["pos"]``, so a nonzero offset reuses the same compiled
    ladder as a cold admission.  ``initial_cache`` is consumed (the chunk
    jit donates its cache argument): callers must hand in a private copy,
    never a shared/registered pytree.
    """

    def __init__(self, engine: "InferenceEngine", tokens: jax.Array,
                 cache_len: int, chunk_size: int, cache_dtype=jnp.float32,
                 *, initial_cache=None, start_offset: int = 0):
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.ndim != 2:
            raise ValueError(f"tokens must be [B, S], got {tokens.shape}")
        s = tokens.shape[1]
        if s < 1:
            raise ValueError("prompt must have at least one token")
        if s > cache_len:
            raise CacheCapacityError(
                f"prompt ({s}) exceeds cache_len ({cache_len})")
        if not 0 <= start_offset < s:
            raise ValueError(f"start_offset ({start_offset}) must be in "
                             f"[0, prompt length {s})")
        if start_offset and initial_cache is None:
            raise ValueError("start_offset > 0 requires an initial_cache "
                             "warm over the adopted prefix")
        w = engine.cfg.sliding_window
        if w:
            chunk_size = min(chunk_size, w)   # ring scatter: chunk <= window
        self.engine = engine
        self.tokens = tokens
        self.schedule = chunk_schedule(s - start_offset, chunk_size)
        if initial_cache is None:
            initial_cache = lm.make_decode_cache(
                engine.cfg, tokens.shape[0], cache_len, cache_dtype,
                start_pos=0)
        self.cache = engine.shard_cache(initial_cache)
        self.cache_len = cache_len
        self.start_offset = start_offset
        self.logits: jax.Array | None = None
        self._off = start_offset
        self._next = 0

    @property
    def done(self) -> bool:
        return self._next >= len(self.schedule)

    @property
    def n_chunks(self) -> int:
        return len(self.schedule)

    def advance(self) -> jax.Array | None:
        """Run one chunk; returns the final logits once all chunks ran."""
        if self.done:
            return self.logits
        c = self.schedule[self._next]
        chunk = self.tokens[:, self._off:self._off + c]
        eng = self.engine
        eng.prefill_shape_keys.add(("chunk", c, self.cache_len))
        with eng.obs.annotation("engine.prefill_chunk"):
            self.logits, self.cache = eng._run_prefill_chunk(
                {"tokens": chunk}, self.cache)
        eng.obs.metrics.counter("engine.prefill_chunks").inc()
        eng.obs.metrics.histogram("engine.prefill_chunk_tokens").record(c)
        self._off += c
        self._next += 1
        return self.logits if self.done else None


class InferenceEngine:
    """Deployed model + HSA engine + jit-cached prefill / fused decode.

    Construct via `from_config`.  All jitted callables are built once per
    engine; repeated `generate` calls with the same shapes and
    `GenerationConfig` hit jax's compilation cache instead of re-tracing
    (the `GenerationConfig` itself is a hashable static argument).
    """

    def __init__(self, cfg: ModelConfig, params: Params, spec: EngineSpec,
                 hsa: HSAEngine | None = None, *, mesh: Mesh | None = None,
                 policy: "shd.ShardingPolicy | None" = None, cell=None,
                 obs: Observability | None = None):
        self.cfg = cfg
        self.spec = spec
        self.hsa = hsa or HSAEngine(spec.hsa_config())
        # Observability: host-side only (metrics registry + span tracer +
        # profiler annotations around jit dispatch).  The A7 program audit
        # proves the compiled decode/verify programs are byte-identical
        # whether this is the default bundle or a live tracer.
        self.obs = obs if obs is not None else Observability()

        # Multi-chip serving: with a mesh, the whole stack runs sharded —
        # params live under the `ServeCell` shardings, caches under
        # `lm.cache_axes` resolved by the same rules engine, and every jit
        # below is re-issued through `compat.jit_sharded` with explicit
        # in/out shardings (see `_sjit`), so prefill -> decode -> spill ->
        # resume never bounces through an unsharded host round trip.
        self.mesh = mesh
        self.cell = cell
        self.policy = None
        self._cache_axes = lm.cache_axes(cfg)
        if mesh is not None:
            self.policy = (policy or (cell.policy if cell is not None
                                      else None) or shd.ShardingPolicy())
            if cell is not None:
                self.param_shardings = cell.param_shardings
            else:
                axes = self._infer_param_axes(params)
                self.param_shardings = shd.tree_shardings(params, axes, mesh,
                                                          self.policy)
            params = jax.device_put(params, self.param_shardings)
            self._rep = NamedSharding(mesh, P())
            self._sjits: dict = {}
            self._sjit_entries: list[dict] = []
            self._csh_cache: dict = {}
        self.params = params

        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("cache_len",
                                                 "return_hidden"))
        # The chunk step's resident cache is donated on every path: the
        # caller (ChunkedPrefill) rebinds to the returned cache, so the
        # input buffer is dead — donation makes the append in-place instead
        # of a full cache copy per chunk (the program audit's donation leg
        # verifies the compiled executable actually aliases it).
        self._prefill_chunk = jax.jit(self._prefill_chunk_impl,
                                      donate_argnums=(2,))
        self._decode = jax.jit(self._decode_impl)
        self._loop = jax.jit(self._loop_impl, static_argnames=("gen",))
        self._resume_loop = jax.jit(self._resume_loop_impl,
                                    static_argnames=("gen",))
        self._spec_loop = jax.jit(self._spec_loop_impl,
                                  static_argnames=("gen",))
        # Distinct prefill-entry shape keys = XLA compiles triggered by this
        # engine's admission paths (the bench/tests watch the ladder keep
        # this ~log-sized as distinct prompt lengths grow).
        self.prefill_shape_keys: set[tuple] = set()

    # -- construction -------------------------------------------------------

    @classmethod
    def from_config(cls, cfg: ModelConfig | str,
                    spec: EngineSpec = EngineSpec(), *,
                    params: Params | None = None,
                    linear_paths: list[tuple[str, ...]] | None = None,
                    mesh: Mesh | None = None,
                    policy: "shd.ShardingPolicy | None" = None,
                    obs: Observability | None = None,
                    ) -> "InferenceEngine":
        """Build the serving stack: init (or adopt) params, PTQ-deploy, wire
        the HSA engine.

        ``cfg`` may be an architecture name (``configs.get_config``) or a
        ready `ModelConfig`.  Pass ``params`` (+ the matching
        ``linear_paths`` from `lm.init`) to serve trained weights; otherwise
        fresh ones are initialized from ``spec.seed``.  Already-deployed
        trees (no master ``'w'`` under the lm_head) are adopted as-is.

        ``mesh`` switches the engine to multi-chip serving: a `ServeCell`
        plan is built (``engine.cell``), params are `jax.device_put` under
        its shardings, and every generate path (plain, chunked prefill,
        speculative, warm resume) runs with explicit in/out shardings on the
        mesh — greedy output stays token-identical to the single-device
        engine (tests/test_serving_sharded.py).
        """
        if isinstance(cfg, str):
            cfg = configs.get_config(cfg)
        if spec.reduced:
            cfg = cfg.reduced()

        if params is None:
            params, _, linear_paths = lm.init(cfg, jax.random.key(spec.seed))
        if spec.quantize and _is_master_tree(params):
            if linear_paths is None:
                _, _, linear_paths = lm.init(cfg, jax.random.key(spec.seed),
                                             abstract=True)
            params = deploy.deploy_quantize(params, linear_paths)
        cell = None
        if mesh is not None:
            from repro.serving import cell as cell_mod   # deferred: cycle
            cell = cell_mod.build_serve(
                cfg, mesh,
                InputShape("serve", seq_len=128, global_batch=1,
                           kind="decode"),
                policy=policy, kernel_impl=spec.kernel_impl,
                quantize=not _is_master_tree(params))
        return cls(cfg, params, spec, mesh=mesh, policy=policy, cell=cell,
                   obs=obs)

    # -- jitted building blocks --------------------------------------------

    def _prefill_impl(self, params, batch, cache_len: int,
                      return_hidden: bool = False):
        return lm.forward_prefill(params, batch, self.cfg, self.hsa,
                                  cache_len=cache_len,
                                  return_hidden=return_hidden)

    def _prefill_chunk_impl(self, params, batch, cache):
        return lm.forward_prefill_chunk(params, batch, cache, self.cfg,
                                        self.hsa)

    def _decode_impl(self, params, tokens, cache):
        return lm.forward_decode(params, tokens, cache, self.cfg, self.hsa)

    def _loop_impl(self, params, logits0, cache, key,
                   gen: GenerationConfig):
        """The fused MVM phase: sample/emit/stop/decode in one while_loop.

        Matches the reference Python loop exactly: ``out[:, i]`` is sampled
        from the logits *before* decode step ``i`` (the first token comes
        from the prefill logits), and the loop exits as soon as every
        sequence has hit a stop token — the remaining slots stay
        ``pad_token_id``.
        """
        key, sub = jax.random.split(key)
        tok0 = sample(logits0, gen.sampling, sub)
        return self._loop_from(params, tok0, cache, key, gen)

    def _resume_loop_impl(self, params, tok0, cache, key,
                          gen: GenerationConfig):
        """The fused loop entered from a *pending token* instead of prefill
        logits — the host-spill warm-resume path: the first emitted token is
        ``tok0`` itself (it was sampled before the preemption)."""
        return self._loop_from(params, tok0, cache, key, gen)

    def _loop_from(self, params, tok0, cache, key, gen: GenerationConfig):
        b = tok0.shape[0]
        n = gen.max_new_tokens
        stop = (jnp.asarray(gen.stop_tokens, jnp.int32)
                if gen.stop_tokens else None)

        def hit_stop(tok):                       # tok i32 [B]
            if stop is None:
                return jnp.zeros((b,), bool)
            return jnp.any(tok[:, None] == stop[None, :], axis=-1)

        out0 = jnp.full((b, n), gen.pad_token_id, jnp.int32)
        state = (jnp.int32(0), tok0, cache, jnp.zeros((b,), bool), out0,
                 jnp.zeros((b,), jnp.int32), key)

        def cond(st):
            i, _, _, done, _, _, _ = st
            return (i < n) & ~jnp.all(done)

        def body(st):
            i, tok, cache, done, out, lengths, key = st
            out = out.at[:, i].set(jnp.where(done, gen.pad_token_id, tok))
            lengths = lengths + (~done).astype(jnp.int32)
            done = done | hit_stop(tok)
            logits, cache = lm.forward_decode(params, tok[:, None], cache,
                                              self.cfg, self.hsa)
            key, sub = jax.random.split(key)
            tok = sample(logits, gen.sampling, sub)
            return (i + 1, tok, cache, done, out, lengths, key)

        _, _, cache, _, out, lengths, _ = jax.lax.while_loop(cond, body, state)
        return out, lengths, cache

    def _spec_loop_impl(self, params, logits0, hidden0, hist0, hist_len0,
                        cache, key, gen: GenerationConfig):
        """The speculative MVM phase: draft k / verify-in-one-MMM-dispatch /
        commit-with-rollback, emitting 1..k+1 tokens per while_loop step
        (serving/speculative.py)."""
        return spec_mod.speculative_loop(params, logits0, hidden0, hist0,
                                         hist_len0, cache, key, cfg=self.cfg,
                                         hsa=self.hsa, gen=gen)

    # -- multi-chip placement -----------------------------------------------

    def _infer_param_axes(self, params: Params) -> Params:
        """Logical axes matching ``params``' deployment state (master fp
        tree vs PTQ-deployed tree) — used when no `ServeCell` was built."""
        _, axes, paths = lm.init(self.cfg, jax.random.key(self.spec.seed),
                                 abstract=True)
        if not _is_master_tree(params):
            axes = deploy.deployed_axes(axes, paths)
        return axes

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    def cache_shardings(self, cache: Params) -> Params:
        """NamedSharding tree for a cache pytree under the cell's policy
        (`lm.cache_axes` through the divisibility-fallback rules engine).

        Memoized by (treedef, leaf shapes) — the full resolution is a
        Python tree walk, and per-token callers (`decode_step`) would
        otherwise pay it on every emitted token.
        """
        leaves, treedef = jax.tree.flatten(cache)
        key = (treedef, tuple(jnp.shape(l) for l in leaves))
        sh = self._csh_cache.get(key)
        if sh is None:
            sh = shd.tree_shardings(cache, self._cache_axes, self.mesh,
                                    self.policy)
            self._csh_cache[key] = sh
        return sh

    def shard_cache(self, cache: Params) -> Params:
        """Place a cache pytree on the mesh (no-op on a single device)."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, self.cache_shardings(cache))

    def _trace_ctx(self):
        """Sharding context active while a sharded jit traces, so model-
        internal logical constraints (`shd.constrain`) resolve on-mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.sharding_ctx(self.mesh, self.policy)

    def _sjit(self, name, impl, in_shardings, out_shardings, *,
              donate_argnums=()):
        """`compat.jit_sharded` with a per-placement cache: one jit object
        (hence one XLA compile cache) per distinct (name, shardings) key, so
        varying cache shapes reuse jits whenever they resolve to the same
        placement instead of re-tracing every call.

        ``impl`` must take positional dynamic args only (pjit rejects kwargs
        under explicit in_shardings) — static knobs are pre-bound with
        `functools.partial` and folded into ``name``.
        """
        key = (name, donate_argnums, shd.shardings_key(in_shardings),
               shd.shardings_key(out_shardings))
        fn = self._sjits.get(key)
        if fn is None:
            fn = compat.jit_sharded(impl, in_shardings=in_shardings,
                                    out_shardings=out_shardings,
                                    donate_argnums=donate_argnums)
            self._sjits[key] = fn
            # Introspection registry: what this entry point promised the
            # mesh (repro.analysis.program_audit replays these against the
            # ServeCell plan — the sharding audit).
            self._sjit_entries.append({
                "name": name if isinstance(name, tuple) else (name,),
                "fn": fn,
                "in_shardings": in_shardings,
                "out_shardings": out_shardings,
                "donate_argnums": donate_argnums,
            })
        return fn

    def _batch_shardings(self, batch: Params) -> Params:
        """Input placement for a token batch: leading dim over the DP axes
        where divisible (B=1 serving falls through to replicated)."""
        return shd.shardings_from_specs(
            shd.batch_specs(batch, self.mesh, self.policy), self.mesh)

    def _sharded_prefill(self, batch: Params, cache_len: int,
                         return_hidden: bool):
        impl = functools.partial(self._prefill_impl, cache_len=cache_len,
                                 return_hidden=return_hidden)
        with self._trace_ctx():
            out_abs = jax.eval_shape(impl, self.params, batch)
        csh = self.cache_shardings(out_abs[1])
        out_sh = (self._rep, csh) + ((self._rep,) if return_hidden else ())
        fn = self._sjit(("prefill", cache_len, return_hidden), impl,
                        (self.param_shardings, self._batch_shardings(batch)),
                        out_sh)
        with self._trace_ctx():
            return fn(self.params, batch)

    def _run_prefill_chunk(self, batch: Params, cache: Params):
        """Chunk step dispatcher: sharded, the resident cache is a donated
        arg with matching in/out shardings (in-place on-mesh append)."""
        if self.mesh is None:
            return self._prefill_chunk(self.params, batch, cache)
        csh = self.cache_shardings(cache)
        fn = self._sjit("prefill_chunk", self._prefill_chunk_impl,
                        (self.param_shardings, self._batch_shardings(batch),
                         csh),
                        (self._rep, csh), donate_argnums=(2,))
        with self._trace_ctx():
            return fn(self.params, batch, cache)

    def _run_loop(self, logits0, cache, key, gen: GenerationConfig):
        if self.mesh is None:
            return self._loop(self.params, logits0, cache, key, gen=gen)
        csh = self.cache_shardings(cache)
        fn = self._sjit(("loop", gen),
                        functools.partial(self._loop_impl, gen=gen),
                        (self.param_shardings, self._rep, csh, self._rep),
                        (self._rep, self._rep, csh))
        with self._trace_ctx():
            return fn(self.params, logits0, cache, key)

    def _run_resume_loop(self, tok0, cache, key, gen: GenerationConfig):
        if self.mesh is None:
            return self._resume_loop(self.params, tok0, cache, key, gen=gen)
        cache = self.shard_cache(cache)       # e.g. fetched from host tier
        csh = self.cache_shardings(cache)
        fn = self._sjit(("resume_loop", gen),
                        functools.partial(self._resume_loop_impl, gen=gen),
                        (self.param_shardings, self._rep, csh, self._rep),
                        (self._rep, self._rep, csh))
        with self._trace_ctx():
            return fn(self.params, tok0, cache, key)

    def _run_spec_loop(self, logits0, hidden0, hist0, hist_len0, cache, key,
                       gen: GenerationConfig):
        if self.mesh is None:
            return self._spec_loop(self.params, logits0, hidden0, hist0,
                                   hist_len0, cache, key, gen=gen)
        csh = self.cache_shardings(cache)
        rep = self._rep
        fn = self._sjit(("spec_loop", gen),
                        functools.partial(self._spec_loop_impl, gen=gen),
                        (self.param_shardings, rep, rep, rep, rep, csh, rep),
                        (rep, rep, csh, rep, rep))
        with self._trace_ctx():
            return fn(self.params, logits0, hidden0, hist0, hist_len0,
                      cache, key)

    # -- introspection hooks (repro.analysis) --------------------------------

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill shapes this engine has dispatched (compile proxy)."""
        return len(self.prefill_shape_keys)

    def jit_entries(self) -> list[dict]:
        """The sharded jit registry: one record per distinct `_sjit` entry
        (name tuple, jit object, in/out shardings, donated argnums).  Empty
        on a single-device engine.  The program audit's sharding leg checks
        every record against the `ServeCell` plan."""
        return list(getattr(self, "_sjit_entries", ()))

    def compile_counts(self) -> dict[str, int]:
        """Compiled-signature count per entry point — the real XLA compile
        cache sizes, not the shape-key proxy.  Sharded entry points
        aggregate over their `_sjit` placements under the same root name
        (``prefill``, ``prefill_chunk``, ``decode``, ``loop``, ...), so the
        number means the same thing on one chip and on a mesh.

        `bench_serving` records this next to every trajectory point; the
        recompile audit asserts it stays O(log max_len) under the ladder.
        """
        counts: dict[str, int] = {}
        for root, fn in (("prefill", self._prefill),
                         ("prefill_chunk", self._prefill_chunk),
                         ("decode", self._decode),
                         ("loop", self._loop),
                         ("resume_loop", self._resume_loop),
                         ("spec_loop", self._spec_loop)):
            counts[root] = _jit_cache_size(fn)
        for entry in self.jit_entries():
            root = entry["name"][0]
            root = root if isinstance(root, str) else str(root)
            counts[root] = counts.get(root, 0) + _jit_cache_size(entry["fn"])
        return counts

    def _abstract_prefill(self, s_in: int, cache_len: int, *,
                          return_hidden: bool = False, batch: int = 1):
        """(logits, cache[, hidden]) ShapeDtypeStructs of a prefill — the
        abstract operands the lowering hooks below feed the hot-path jits."""
        tokens = jax.ShapeDtypeStruct((batch, s_in), jnp.int32)
        impl = functools.partial(self._prefill_impl, cache_len=cache_len,
                                 return_hidden=return_hidden)
        with self._trace_ctx():
            return jax.eval_shape(impl, self.params, {"tokens": tokens})

    def lower_prefill_chunk(self, *, batch: int = 1, chunk: int = 16,
                            cache_len: int = 64, cache_dtype=jnp.float32):
        """Lowering of the chunked-prefill step on abstract operands.

        The donation audit compiles this and verifies the executable aliases
        the resident cache's buffers (input_output_alias) instead of
        silently copying a whole cache per chunk.
        """
        tokens = {"tokens": jax.ShapeDtypeStruct((batch, chunk), jnp.int32)}
        cache = jax.eval_shape(
            lambda: lm.make_decode_cache(self.cfg, batch, cache_len,
                                         cache_dtype, start_pos=0))
        if self.mesh is None:
            return self._prefill_chunk.lower(self.params, tokens, cache)
        csh = self.cache_shardings(cache)
        fn = self._sjit("prefill_chunk", self._prefill_chunk_impl,
                        (self.param_shardings, self._batch_shardings(tokens),
                         csh),
                        (self._rep, csh), donate_argnums=(2,))
        with self._trace_ctx():
            return fn.lower(self.params, tokens, cache)

    def lower_decode_loop(self, gen: GenerationConfig, *, batch: int = 1,
                          s_in: int = 8, cache_len: int | None = None):
        """Lowering of the fused decode ``while_loop`` on abstract operands
        (the transfer audit scans its HLO for host callbacks / transfers)."""
        cache_len = cache_len or s_in + gen.max_new_tokens
        logits, cache = self._abstract_prefill(s_in, cache_len, batch=batch)
        key = jax.eval_shape(lambda: jax.random.key(0))
        if self.mesh is None:
            return self._loop.lower(self.params, logits, cache, key, gen=gen)
        csh = self.cache_shardings(cache)
        fn = self._sjit(("loop", gen),
                        functools.partial(self._loop_impl, gen=gen),
                        (self.param_shardings, self._rep, csh, self._rep),
                        (self._rep, self._rep, csh))
        with self._trace_ctx():
            return fn.lower(self.params, logits, cache, key)

    def lower_spec_loop(self, gen: GenerationConfig, *, batch: int = 1,
                        s_in: int = 8):
        """Lowering of the speculative draft/verify ``while_loop`` on
        abstract operands — the verify-path twin of `lower_decode_loop`."""
        if gen.speculative is None:
            raise ValueError("lower_spec_loop needs gen.speculative")
        k = gen.speculative.k
        cache_len = s_in + gen.max_new_tokens + k
        logits, cache, hidden = self._abstract_prefill(
            s_in, cache_len, return_hidden=True, batch=batch)
        hist = jax.ShapeDtypeStruct(
            (batch, s_in + gen.max_new_tokens + k + 1), jnp.int32)
        hist_len = jax.ShapeDtypeStruct((), jnp.int32)
        key = jax.eval_shape(lambda: jax.random.key(0))
        if self.mesh is None:
            return self._spec_loop.lower(self.params, logits, hidden, hist,
                                         hist_len, cache, key, gen=gen)
        csh = self.cache_shardings(cache)
        rep = self._rep
        fn = self._sjit(("spec_loop", gen),
                        functools.partial(self._spec_loop_impl, gen=gen),
                        (self.param_shardings, rep, rep, rep, rep, csh, rep),
                        (rep, rep, csh, rep, rep))
        with self._trace_ctx():
            return fn.lower(self.params, logits, hidden, hist, hist_len,
                            cache, key)

    # -- public API ---------------------------------------------------------

    def prefill(self, tokens: jax.Array, *, cache_len: int | None = None,
                extras: Params | None = None, bucket: bool = False,
                return_hidden: bool = False):
        """MMM phase: prompts [B, S] -> (last-token logits [B, V], caches).

        ``bucket=True`` pads the prompt up to the power-of-two ladder and
        passes the real length in as a *traced* scalar, so every prompt
        length in a bucket shares one compile; logits/cache positions are
        taken at the real prompt end (token-identical to the exact-length
        call).  ``cache_len`` is rounded up onto the same ladder — it is a
        static jit argument, so a per-request value (prompt + budget) would
        otherwise re-trigger one compile per length, defeating the bucket.
        The cache is at least bucket-sized so the padded tail stays
        addressable (decode masks, then overwrites it).
        """
        tokens = jnp.asarray(tokens, jnp.int32)
        s = tokens.shape[1]
        batch = {"tokens": tokens, **(extras or {})}
        if bucket:
            b = bucket_length(s)
            if b > s:
                batch["tokens"] = jnp.pad(tokens, ((0, 0), (0, b - s)))
            batch["prompt_len"] = jnp.int32(s)
            cache_len = bucket_length(max(cache_len or s, b))
            self.prefill_shape_keys.add(("bucket", b, cache_len))
        else:
            cache_len = cache_len or s
            self.prefill_shape_keys.add(("prefill", s, cache_len))
        if self.mesh is not None:
            return self._sharded_prefill(batch, cache_len, return_hidden)
        return self._prefill(self.params, batch, cache_len=cache_len,
                             return_hidden=return_hidden)

    def decode_step(self, tokens: jax.Array, cache: Params
                    ) -> tuple[jax.Array, Params]:
        """One MVM step: tokens [B, 1] + warm cache -> (logits [B, V], cache)."""
        if self.mesh is None:
            return self._decode(self.params, tokens, cache)
        csh = self.cache_shardings(cache)
        fn = self._sjit("decode", self._decode_impl,
                        (self.param_shardings, self._rep, csh),
                        (self._rep, csh))
        with self._trace_ctx():
            return fn(self.params, tokens, cache)

    def begin_chunked_prefill(self, tokens: jax.Array, *, cache_len: int,
                              chunk_size: int = 32, cache_dtype=jnp.float32,
                              initial_cache=None,
                              start_offset: int = 0) -> ChunkedPrefill:
        """Start a chunk-granular admission; the caller paces `advance()`.

        ``initial_cache``/``start_offset`` adopt an already-warm prefix:
        only ``tokens[:, start_offset:]`` is prefilled (see ChunkedPrefill).
        """
        return ChunkedPrefill(self, tokens, cache_len, chunk_size, cache_dtype,
                              initial_cache=initial_cache,
                              start_offset=start_offset)

    def prefill_chunked(self, tokens: jax.Array, *, cache_len: int,
                        chunk_size: int = 32, cache_dtype=jnp.float32
                        ) -> tuple[jax.Array, Params]:
        """Drive a chunked prefill to completion: (last logits [B,V], cache)."""
        cp = self.begin_chunked_prefill(tokens, cache_len=cache_len,
                                        chunk_size=chunk_size,
                                        cache_dtype=cache_dtype)
        while not cp.done:
            cp.advance()
        return cp.logits, cp.cache

    def generate(self, prompts: jax.Array,
                 gen: GenerationConfig = GenerationConfig(), *,
                 key: jax.Array | None = None,
                 extras: Params | None = None,
                 speculative: SpeculativeConfig | None = None
                 ) -> GenerationResult:
        """Prefill + fused decode.  prompts [B, S_in] -> GenerationResult.

        ``key`` seeds stochastic sampling; it is ignored under greedy
        decoding and defaults to a fixed key so greedy calls never touch
        host RNG state.  ``speculative`` (or ``gen.speculative``) switches
        the MVM phase to the multi-token draft/verify loop; greedy output is
        token-identical to the plain loop, stochastic output is distributed
        identically (see serving/speculative.py).
        """
        prompts = jnp.asarray(prompts, jnp.int32)
        if speculative is not None:
            gen = dataclasses.replace(gen, speculative=speculative)
        if gen.speculative is not None:
            return self._generate_speculative(prompts, gen, key=key,
                                              extras=extras)
        cache_len = prompts.shape[1] + gen.max_new_tokens
        if key is None:
            key = jax.random.key(0)

        tr = self.obs.tracer
        with tr.span("generate", ENGINE_TRACK,
                     batch=prompts.shape[0],
                     prompt_len=prompts.shape[1]):
            t0 = time.perf_counter()
            with tr.span("prefill", ENGINE_TRACK), \
                    self.obs.annotation("engine.prefill"):
                logits, cache = self.prefill(prompts, cache_len=cache_len,
                                             extras=extras)
                cache = self._encode_cache(cache, gen)
                jax.block_until_ready(logits)
            t_prefill = time.perf_counter() - t0

            t0 = time.perf_counter()
            with tr.span("decode_loop", ENGINE_TRACK), \
                    self.obs.annotation("engine.decode_loop"):
                tokens, lengths, _ = self._run_loop(logits, cache, key, gen)
                jax.block_until_ready(tokens)
            t_decode = time.perf_counter() - t0
            tr.instant("finish", ENGINE_TRACK, lengths=lengths)
        res = GenerationResult(tokens=tokens, lengths=lengths,
                               prefill_s=t_prefill, decode_s=t_decode)
        self._observe_generate(res)
        return res

    def resume_generate(self, pending: jax.Array, cache: Params,
                        gen: GenerationConfig = GenerationConfig(), *,
                        key: jax.Array | None = None) -> GenerationResult:
        """Warm-resume the fused MVM loop from a pending token and a warm
        decode cache — the host-spill re-entry point: no prefill runs and no
        prefill shape compiles; the cache (e.g. fetched back from the pool's
        host tier) is consumed as-is.

        ``pending`` is i32 ``[B]`` (or a scalar for a batch-1 cache): the
        token sampled *before* the interruption, which becomes the first
        emitted token — matching the fused loop's convention that step i's
        token was sampled from step i-1's logits.  Under greedy decoding the
        resumed stream is token-identical to the uninterrupted run.
        """
        pending = jnp.asarray(pending, jnp.int32)
        if pending.ndim == 0:
            pending = pending[None]
        if key is None:
            key = jax.random.key(0)
        t0 = time.perf_counter()
        with self.obs.tracer.span("resume_loop", ENGINE_TRACK), \
                self.obs.annotation("engine.resume_loop"):
            tokens, lengths, _ = self._run_resume_loop(pending, cache, key,
                                                       gen)
            jax.block_until_ready(tokens)
        res = GenerationResult(tokens=tokens, lengths=lengths,
                               prefill_s=0.0,
                               decode_s=time.perf_counter() - t0)
        self._observe_generate(res)
        return res

    def _observe_generate(self, res: GenerationResult) -> None:
        """Record one finished generate into the engine's metrics registry.

        Runs strictly *after* the fused loop's `block_until_ready`, so the
        `lengths` read costs a drained-buffer copy, not a new device sync.
        The fused loop commits every token in one dispatch, so TTFT at this
        level is the prefill wall, and inter-token latency the decode wall
        per loop iteration (iterations = the longest sequence emitted).
        """
        m = self.obs.metrics
        b = res.tokens.shape[0]
        emitted = int(jnp.sum(res.lengths))
        steps = int(jnp.max(res.lengths))
        m.counter("engine.requests").inc(b)
        m.counter("engine.emitted").inc(emitted)
        if res.prefill_s:
            m.histogram("engine.ttft_s").record(res.prefill_s)
        m.histogram("engine.decode_s").record(res.decode_s)
        if steps > 0:
            m.histogram("engine.inter_token_s").record(res.decode_s / steps)
        if res.verify_steps:
            m.counter("engine.verify_steps").inc(res.verify_steps)
            m.counter("engine.accepted_drafts").inc(res.accepted_drafts)
            m.histogram("engine.tokens_per_verify_step").record(
                res.tokens_per_step)

    def _encode_cache(self, cache: Params, gen: GenerationConfig) -> Params:
        """Apply ``gen.cache_format`` at the prefill/decode boundary: the
        MMM phase ran fp, the MVM residency streams packed bytes.  No-op
        when the request keeps the fp cache."""
        if gen.cache_format is None:
            return cache
        cache = lm.quantize_cache(cache, self.cfg, gen.cache_format)
        return self.shard_cache(cache)

    def cache_nbytes(self, cache_len: int, *, batch: int = 1,
                     dtype=jnp.float32) -> int:
        """Bytes of one decode cache at ``cache_len`` — what a `CachePool`
        lane holds on device and what one host spill moves.  Computed from
        abstract shapes (`jax.eval_shape`): no cache is materialized."""
        tree = jax.eval_shape(
            lambda: lm.make_decode_cache(self.cfg, batch, cache_len, dtype))
        return pytree_nbytes(tree)

    def _generate_speculative(self, prompts: jax.Array, gen: GenerationConfig,
                              *, key: jax.Array | None = None,
                              extras: Params | None = None
                              ) -> GenerationResult:
        spec = gen.speculative
        cfg = self.cfg
        if cfg.is_encdec or cfg.frontend:
            raise NotImplementedError("speculative decode targets text "
                                      "decoder-only models")
        if cfg.sliding_window and spec.k + 1 > cfg.sliding_window:
            raise ValueError(
                f"verify block k+1 ({spec.k + 1}) must fit the sliding "
                f"window ({cfg.sliding_window}): a larger block would "
                "overwrite its own ring writes")
        b, s_in = prompts.shape
        n = gen.max_new_tokens
        # Verify may append up to k tokens past the last committed budget
        # position before rolling back — reserve them.
        cache_len = s_in + n + spec.k
        if key is None:
            key = jax.random.key(0)

        tr = self.obs.tracer
        with tr.span("generate", ENGINE_TRACK, batch=b, prompt_len=s_in,
                     speculative=True, k=spec.k):
            t0 = time.perf_counter()
            with tr.span("prefill", ENGINE_TRACK), \
                    self.obs.annotation("engine.prefill"):
                logits, cache, hidden = self.prefill(prompts,
                                                     cache_len=cache_len,
                                                     extras=extras,
                                                     return_hidden=True)
                cache = self._encode_cache(cache, gen)
                jax.block_until_ready(logits)
            t_prefill = time.perf_counter() - t0

            hist0 = jnp.zeros((b, s_in + n + spec.k + 1),
                              jnp.int32).at[:, :s_in].set(prompts)
            t0 = time.perf_counter()
            with tr.span("spec_loop", ENGINE_TRACK), \
                    self.obs.annotation("engine.spec_loop"):
                tokens, lengths, _, steps, accepted = self._run_spec_loop(
                    logits, hidden, hist0, jnp.int32(s_in), cache, key, gen)
                jax.block_until_ready(tokens)
            t_decode = time.perf_counter() - t0
            steps, accepted = int(steps), int(accepted)
            tr.instant("finish", ENGINE_TRACK, verify_steps=steps,
                       accepted_drafts=accepted)
        res = GenerationResult(tokens=tokens, lengths=lengths,
                               prefill_s=t_prefill, decode_s=t_decode,
                               verify_steps=steps, accepted_drafts=accepted,
                               drafted=steps * spec.k)
        self._observe_generate(res)
        return res


def _is_master_tree(params: Params) -> bool:
    """True when the tree still carries master linear weights (pre-deploy)."""
    head = params.get("lm_head")
    return isinstance(head, dict) and "w" in head and "w8_vals" not in head
