"""Refcounted cache pages + radix prefix index: shared-prefix reuse with COW.

At production scale most traffic re-prefills the *same* bytes — long shared
system/template prefixes.  This module gives `CachePool` a second residency
tier for those bytes: an index from token prefixes to **immutable cache
pages** (fixed-size runs of KV rows sliced on the cache axis), so admission
can adopt the longest cached prefix, skip that much prefill entirely, and
chunk-prefill only the suffix.

Two index shapes, selected per architecture by `lm.prefix_sharing_mode`:

  * `RadixPageIndex` — a radix tree with page-granular edges: each node owns
    up to ``page_size`` tokens and the cache rows those tokens produced
    (every pageable cache group, quantized layouts included).  Lookup walks
    the longest matching page run; registration appends new pages under the
    deepest full match (no edge splitting — divergence inside a page creates
    a sibling, trading a little row duplication for never rewriting a shared
    page).  Pages carry **refcounts** (leases held by adopting requests) and
    an LRU clock; eviction only ever removes unreferenced leaves, and a host
    tier lets cold pages park in CPU DRAM instead of being dropped.

  * `SnapshotPrefixIndex` — ring/recurrent caches (SWA rings, RetNet S,
    Mamba h/conv) fold history into position-aliased or O(1) state, so token
    pages cannot represent them; instead the *whole cache pytree* at a
    finished prompt is registered as an adoptable snapshot at that exact
    token boundary, with the same lease/LRU/host-tier accounting.

Copy-on-write is by construction: pages are never handed to the engine —
adoption *assembles* a fresh batch-1 cache (`lm.assemble_prefix_cache`) by
copying page rows under a cold scaffold, so the donated-cache chunk step can
never touch a shared page.  The partial tail page an unaligned adoption
slices off is the COW event the ``pool.cow_bytes`` histogram prices; a full
divergent write never happens because divergent requests simply stop
matching at the divergence point and prefill their own suffix.

`PrefixCache` is the facade `CachePool` owns: mode selection, MoE
chunk-alignment (expert-capacity routing is per-dispatch, so adoption
boundaries must land on chunk boundaries there), lease bookkeeping by slot
id, page budgets (`maintain` proactively spills cold pages to host and
LRU-evicts past ``max_pages``), and the `repro.obs` counters/gauges.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.obs import Observability
from repro.serving.engine import pytree_nbytes

Params = dict[str, Any]


class PageLeaseError(ValueError):
    """Refcount misuse: releasing a never-leased page / negative refs."""


def token_key(prompt) -> tuple[int, ...]:
    """Normalize a prompt (list / array of token ids) into the hashable
    token tuple the prefix indexes key on."""
    return tuple(int(t) for t in prompt)


def _tree_concat_rows(parts: list[Params]) -> Params:
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=2), *parts)


# ---------------------------------------------------------------------------
# Paged tier: radix tree over token prefixes -> immutable page runs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PageNode:
    """One page: up to ``page_size`` tokens and their cache rows.

    ``rows`` (device) / ``host_rows`` (CPU DRAM) are mutually exclusive for
    a resident page; both None only on the root sentinel.  ``refs`` counts
    live leases (requests whose adoption walked through this page); a page
    with ``refs > 0`` is pinned — never evicted, never spilled.
    """

    tokens: tuple[int, ...]
    rows: Params | None = None
    host_rows: Params | None = None
    nbytes: int = 0
    refs: int = 0
    tick: int = 0
    children: list["PageNode"] = dataclasses.field(default_factory=list)
    parent: "PageNode | None" = None

    @property
    def on_device(self) -> bool:
        return self.rows is not None


def _common_prefix(a: tuple[int, ...], b: tuple[int, ...]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixPageIndex:
    """Radix tree with page-granular edges over token prefixes.

    Pure bookkeeping over page pytrees — it never touches the engine; the
    `PrefixCache` facade owns assembly, metrics, and device/host transfers
    (the ``spill``/``fetch`` callables injected here keep this class free of
    jax transfer primitives, which also keeps it trivially property-testable
    with numpy rows).
    """

    def __init__(self, page_size: int = 16):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.root = PageNode(tokens=())
        self._tick = 0

    # -- traversal ----------------------------------------------------------

    def nodes(self) -> list[PageNode]:
        """Every page (excluding the root sentinel), preorder."""
        out: list[PageNode] = []
        stack = list(self.root.children)
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children)
        return out

    @property
    def n_pages(self) -> int:
        return len(self.nodes())

    def _touch(self, node: PageNode) -> None:
        self._tick += 1
        node.tick = self._tick

    # -- match / insert ------------------------------------------------------

    def match(self, key: tuple[int, ...]) -> list[tuple[PageNode, int]]:
        """*Maximal* page-run match: ``[(node, tokens_used), ...]`` walking
        from the root; every entry but the last uses its page fully — the
        last may be a partial (mid-page) match.

        Sibling pages (created when prompts diverge mid-page) can share
        leading tokens, so the walk must compare whole descent chains, not
        single children: a short fully-matched page that allows deeper
        descent beats a longer partial match.  Ties prefer the chain whose
        final page is fully used — that is the chain a re-insert of the
        same key descends, which keeps insertion idempotent.
        """
        def go(node: PageNode, i: int) -> list[tuple[PageNode, int]]:
            best: list[tuple[PageNode, int]] = []
            best_rank = (0, True)
            for child in node.children:
                m = _common_prefix(child.tokens, key[i:])
                if m < 1:
                    continue
                if m == len(child.tokens):
                    cand = [(child, m)] + go(child, i + m)
                else:
                    cand = [(child, m)]
                last, used = cand[-1]
                rank = (sum(u for _, u in cand), used == len(last.tokens))
                if rank > best_rank:
                    best, best_rank = cand, rank
            return best

        return go(self.root, 0)

    def insert(self, key: tuple[int, ...],
               rows_of: Callable[[int, int], Params],
               nbytes_of: Callable[[Params], int] = pytree_nbytes
               ) -> list[PageNode]:
        """Register ``key``'s pages, reusing every fully-matching existing
        page and creating new nodes for the remainder.  ``rows_of(a, b)``
        produces the rows for token positions [a, b).  Returns the nodes
        created (empty when the whole prefix was already resident).

        A partial overlap with an existing page creates a *sibling* rather
        than splitting the shared page — shared pages are immutable, so the
        few duplicated rows are the price of never rewriting one.
        """
        node, i = self.root, 0
        for child, m in self.match(key):
            if m < len(child.tokens):
                break                                # diverged mid-page
            self._touch(child)
            node, i = child, i + m
        created: list[PageNode] = []
        while i < len(key):
            stop = min(i + self.page_size, len(key))
            rows = rows_of(i, stop)
            child = PageNode(tokens=key[i:stop], rows=rows,
                             nbytes=nbytes_of(rows), parent=node)
            node.children.append(child)
            self._touch(child)
            created.append(child)
            node, i = child, stop
        return created

    # -- leases --------------------------------------------------------------

    def lease(self, nodes: list[PageNode]) -> None:
        for n in nodes:
            n.refs += 1
            self._touch(n)

    def release(self, nodes: list[PageNode]) -> None:
        for n in nodes:
            if n.refs < 1:
                raise PageLeaseError("page lease released more times than "
                                     "acquired (refcount would go negative)")
            n.refs -= 1

    # -- eviction / host migration ------------------------------------------

    def _detach(self, node: PageNode) -> None:
        node.parent.children.remove(node)
        node.parent = None
        node.rows = node.host_rows = None

    def evict_lru(self) -> PageNode | None:
        """Drop the least-recently-used unreferenced *leaf* page (interior
        pages are pinned by their children — a child's rows are meaningless
        without the prefix above them)."""
        victims = [n for n in self.nodes()
                   if n.refs == 0 and not n.children]
        if not victims:
            return None
        victim = min(victims, key=lambda n: n.tick)
        self._detach(victim)
        return victim

    def spill_lru(self, spill: Callable[[Params], Params]) -> PageNode | None:
        """Move the coldest unreferenced device-resident page's rows to the
        host tier (proactive migration — before capacity pressure forces a
        synchronous eviction).  Spilled pages stay matchable; adoption
        fetches them back."""
        victims = [n for n in self.nodes() if n.refs == 0 and n.on_device]
        if not victims:
            return None
        victim = min(victims, key=lambda n: n.tick)
        victim.host_rows = spill(victim.rows)
        victim.rows = None
        return victim


# ---------------------------------------------------------------------------
# Snapshot tier: whole-cache prefix states for ring/recurrent archs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Snapshot:
    """A whole warm cache pytree at one finished-prompt boundary."""

    key: tuple[int, ...]
    cache_len: int
    cache: Params | None = None          # device-resident
    host_cache: Params | None = None     # spilled to CPU DRAM
    nbytes: int = 0
    refs: int = 0
    tick: int = 0

    @property
    def on_device(self) -> bool:
        return self.cache is not None


class SnapshotPrefixIndex:
    """Prefix states for architectures whose caches cannot page.

    Entries are keyed by (token tuple, cache_len): a snapshot is only
    adoptable into the same cache class it was produced in (the pytree
    shapes ARE the class).  Lookup returns the longest registered prompt
    that strictly prefixes the query — adoption happens at exact snapshot
    boundaries only, which is what makes it exact for recurrent state.
    """

    def __init__(self):
        self._entries: dict[tuple[tuple[int, ...], int], Snapshot] = {}
        self._tick = 0

    def nodes(self) -> list[Snapshot]:
        return list(self._entries.values())

    @property
    def n_pages(self) -> int:
        return len(self._entries)

    def _touch(self, snap: Snapshot) -> None:
        self._tick += 1
        snap.tick = self._tick

    def match(self, key: tuple[int, ...], cache_len: int) -> Snapshot | None:
        best = None
        for (k, clen), snap in self._entries.items():
            if clen != cache_len or len(k) >= len(key):
                continue                   # strict prefix: >= 1 suffix token
            if key[:len(k)] == k and (best is None or len(k) > len(best.key)):
                best = snap
        return best

    def insert(self, key: tuple[int, ...], cache_len: int, cache: Params
               ) -> Snapshot | None:
        ix = (key, cache_len)
        if ix in self._entries:
            self._touch(self._entries[ix])
            return None
        snap = Snapshot(key=key, cache_len=cache_len, cache=cache,
                        nbytes=pytree_nbytes(cache))
        self._entries[ix] = snap
        self._touch(snap)
        return snap

    def lease(self, snaps: list[Snapshot]) -> None:
        for s in snaps:
            s.refs += 1
            self._touch(s)

    def release(self, snaps: list[Snapshot]) -> None:
        for s in snaps:
            if s.refs < 1:
                raise PageLeaseError("snapshot lease released more times "
                                     "than acquired")
            s.refs -= 1

    def evict_lru(self) -> Snapshot | None:
        victims = [s for s in self._entries.values() if s.refs == 0]
        if not victims:
            return None
        victim = min(victims, key=lambda s: s.tick)
        del self._entries[(victim.key, victim.cache_len)]
        victim.cache = victim.host_cache = None
        return victim

    def spill_lru(self, spill: Callable[[Params], Params]) -> Snapshot | None:
        victims = [s for s in self._entries.values()
                   if s.refs == 0 and s.on_device]
        if not victims:
            return None
        victim = min(victims, key=lambda s: s.tick)
        victim.host_cache = spill(victim.cache)
        victim.cache = None
        return victim


# ---------------------------------------------------------------------------
# The facade CachePool owns
# ---------------------------------------------------------------------------


class PrefixCache:
    """Shared-prefix reuse for one model config: lookup at admission,
    registration at prefill completion, leases tied to pool slot ids.

    ``max_pages`` bounds the total page count (LRU eviction past it);
    ``device_pages`` bounds the *device-resident* page count — `maintain`
    proactively migrates the coldest unreferenced pages to host DRAM past
    that budget, so capacity pressure never forces a synchronous eviction
    of a still-useful prefix.  In snapshot mode both budgets count
    snapshots (one snapshot ~ one "page" of bookkeeping; its bytes are
    whatever the cache class costs).
    """

    def __init__(self, cfg, dtype, *, enabled: bool = False,
                 page_size: int = 16, max_pages: int | None = None,
                 device_pages: int | None = None,
                 obs: Observability | None = None):
        self.cfg = cfg
        self.dtype = dtype
        self.mode = lm.prefix_sharing_mode(cfg) if enabled else None
        self.enabled = self.mode is not None
        self.page_size = page_size
        self.max_pages = max_pages
        self.device_pages = device_pages
        self.obs = obs if obs is not None else Observability()
        # MoE expert-capacity dropping is per-dispatch: tokens routed in a
        # different chunk decomposition can drop differently, so adoption
        # boundaries must be chunk-aligned there to keep the suffix's
        # dispatches identical to the cold run's.
        self._align_chunks = any(
            kind == "moe" for _, _, kind in lm.layer_groups(cfg))
        self._index = (RadixPageIndex(page_size) if self.mode == "paged"
                       else SnapshotPrefixIndex())
        self._leases: dict[int, list] = {}        # slot id -> leased nodes
        self.stats = self.obs.metrics.counter_view(
            "pool.", ["prefix_lookups", "prefix_hits", "prefix_hit_tokens",
                      "prefix_insert_pages", "cow_copies", "page_spills",
                      "page_fetches", "page_evictions"])

    # -- metrics -------------------------------------------------------------

    @property
    def n_pages(self) -> int:
        return self._index.n_pages

    @property
    def shared_pages(self) -> int:
        return sum(1 for n in self._index.nodes() if n.refs > 0)

    @property
    def free_pages(self) -> int:
        return sum(1 for n in self._index.nodes() if n.refs == 0)

    @property
    def device_resident_pages(self) -> int:
        return sum(1 for n in self._index.nodes() if n.on_device)

    @property
    def host_pages(self) -> int:
        return self.n_pages - self.device_resident_pages

    @property
    def prefix_bytes(self) -> int:
        return sum(n.nbytes for n in self._index.nodes())

    def _set_gauges(self) -> None:
        g = self.obs.metrics.gauge
        g("pool.pages_shared").set(self.shared_pages)
        g("pool.pages_free").set(self.free_pages)
        g("pool.pages_host").set(self.host_pages)
        g("pool.prefix_bytes").set(self.prefix_bytes)

    # -- host transfers (the allowlisted gather sites) ----------------------

    def _spill(self, tree: Params) -> Params:
        # device_get is the cross-sharding-safe gather (matches
        # CachePool.spill); the host copy is plain numpy.
        self.stats["page_spills"] += 1
        return jax.device_get(tree)

    def _fetch(self, tree: Params) -> Params:
        self.stats["page_fetches"] += 1
        return jax.tree.map(jnp.asarray, tree)

    def _node_rows(self, node: PageNode) -> Params:
        """A page's device rows, fetching (and re-promoting) a host-resident
        page — adoption touches it, so it is hot again by definition."""
        if node.rows is None:
            node.rows = self._fetch(node.host_rows)
            node.host_rows = None
        return node.rows

    # -- admission-side API --------------------------------------------------

    def lookup(self, prompt, cache_len: int, slot: int, *,
               chunk_size: int = 1) -> tuple[int, Params | None]:
        """Longest adoptable cached prefix of ``prompt`` for a slot of class
        ``cache_len``: returns ``(n_tokens, warm_cache)`` — the assembled
        batch-1 cache covering positions [0, n_tokens) — or ``(0, None)``.

        The hit is capped at ``len(prompt) - 1`` (at least one suffix token
        must run so admission still produces last-token logits), and floored
        to a ``chunk_size`` multiple on MoE archs (routing exactness).  A
        hit shorter than one full page is treated as a miss: a tiny
        adoption costs more than it saves (the gather-copy assembly plus a
        fresh odd-offset suffix entry in the prefill ladder outweigh a few
        skipped prefill tokens — a chance 1-token overlap between unrelated
        prompts must not trigger any of that).  The pages (or snapshot)
        backing the hit are leased under ``slot`` until `release(slot)`.
        """
        if not self.enabled:
            return 0, None
        key = token_key(prompt)
        self.stats["prefix_lookups"] += 1
        if self.mode == "snapshot":
            return self._lookup_snapshot(key, cache_len, slot)
        matched = self._index.match(key)
        total = sum(m for _, m in matched)
        p = min(total, len(key) - 1)
        if self._align_chunks:
            p -= p % max(chunk_size, 1)
        if p < self.page_size:
            return 0, None
        parts: list[Params] = []
        used: list[PageNode] = []
        taken = 0
        for node, m in matched:
            take = min(m, p - taken)
            if take < 1:
                break
            rows = self._node_rows(node)
            if take < len(node.tokens):
                # The COW event: the adopter copies the shared tail page's
                # first `take` rows into its own cache; the page itself is
                # never written.
                rows = jax.tree.map(lambda x: x[:, :, :take], rows)
                self.stats["cow_copies"] += 1
                self.obs.metrics.histogram("pool.cow_bytes").record(
                    pytree_nbytes(rows))
            parts.append(rows)
            used.append(node)
            taken += take
            if taken >= p:
                break
        cache = lm.assemble_prefix_cache(
            self.cfg, _tree_concat_rows(parts), p, cache_len, self.dtype)
        self._index.lease(used)
        self._leases.setdefault(slot, []).extend(used)
        self.stats["prefix_hits"] += 1
        self.stats["prefix_hit_tokens"] += p
        self._set_gauges()
        return p, cache

    def _lookup_snapshot(self, key, cache_len: int, slot: int
                         ) -> tuple[int, Params | None]:
        snap = self._index.match(key, cache_len)
        if snap is None or len(snap.key) < self.page_size:
            return 0, None
        if snap.cache is None:
            snap.cache = self._fetch(snap.host_cache)
            snap.host_cache = None
        # The chunk step donates its cache argument, so the adopter gets a
        # fresh copy — the registered snapshot must survive for the next
        # adopter (this is the snapshot tier's COW).
        cache = jax.tree.map(jnp.copy, snap.cache)
        self.stats["cow_copies"] += 1
        self.obs.metrics.histogram("pool.cow_bytes").record(snap.nbytes)
        self._index.lease([snap])
        self._leases.setdefault(slot, []).append(snap)
        p = len(snap.key)
        self.stats["prefix_hits"] += 1
        self.stats["prefix_hit_tokens"] += p
        self._set_gauges()
        return p, cache

    def register(self, prompt, cache: Params, cache_len: int) -> int:
        """Index a finished prompt's cache for future adopters; returns the
        number of new pages (snapshot mode: 1 for a new boundary, 0 for an
        already-registered one).  The rows are sliced out (copied) here, so
        the caller's cache stays free to be donated / scattered afterwards.
        """
        if not self.enabled:
            return 0
        key = token_key(prompt)
        if self.mode == "snapshot":
            snap = self._index.insert(key, cache_len, cache)
            n_new = 1 if snap is not None else 0
        else:
            created = self._index.insert(
                key, lambda a, b: lm.slice_cache_rows(cache, self.cfg, a, b))
            n_new = len(created)
        self.stats["prefix_insert_pages"] += n_new
        self._set_gauges()
        return n_new

    def release(self, slot: int) -> None:
        """Drop every page lease a slot holds (idempotent per slot — the
        pool calls this on all release paths: retire, cancel, preempted
        cancel)."""
        held = self._leases.pop(slot, None)
        if held:
            self._index.release(held)
            self._set_gauges()

    @property
    def leased_slots(self) -> int:
        return len(self._leases)

    # -- background maintenance ---------------------------------------------

    def maintain(self) -> None:
        """One bookkeeping cycle (the scheduler calls this once per step):
        proactively spill cold unreferenced pages past the device budget,
        LRU-evict past ``max_pages``, refresh the occupancy gauges."""
        if not self.enabled:
            return
        if self.device_pages is not None:
            while (self.device_resident_pages > self.device_pages
                   and self._index.spill_lru(self._spill) is not None):
                pass
        if self.max_pages is not None:
            while (self.n_pages > self.max_pages
                   and self._index.evict_lru() is not None):
                self.stats["page_evictions"] += 1
        self._set_gauges()
