"""Async serving front end: open-loop arrivals over `RequestScheduler`.

`ServingFrontend` is the seam between callers that arrive whenever they
like and the scheduler's synchronous sequencer cycle:

  * **submit** is non-blocking: it runs the SLO admission policy, enqueues
    the request, and hands back a `TokenStream` — an async iterator that
    yields tokens as the scheduler commits them and resolves to the
    request's `FinishedRequest`.  ``await stream.aclose()`` (or
    ``frontend.cancel(uid)``) cancels mid-stream: the scheduler drops the
    slot (and any prefix-page leases) and the stream finishes with
    ``cancelled=True``.
  * a **stepper task** owns the scheduler: one `step()` per loop iteration
    while work is pending, a cooperative ``clock.sleep(step_period_s)``
    between cycles, and an idle wait when the pool drains — requests from
    any number of concurrent submitters serialize through it, so the
    scheduler itself stays single-threaded and lock-free.
  * the **SLO admission policy** reads the live windowed p99 of
    ``sched.ttft_s`` from the PR 8 metrics registry and sheds (or
    deprioritizes) new arrivals while the tail breaches the target —
    goodput protection under open-loop overload.  A guaranteed-admit floor
    and a minimum-evidence threshold keep it from shedding an idle or
    cold system; a shed without a justifying breach would be a policy bug
    and is counted separately (``frontend.shed_unexplained`` — the CI
    smoke asserts it stays zero).

Everything time-shaped goes through the injectable `Clock` (clock.py): the
frontend requires its clock and the scheduler's latency timebase to be the
same object's ``now`` — windowed percentiles filter recorded timestamps
against the policy's "now", and mixing timebases would silently empty or
flood the window.  Under `VirtualClock` the whole stack is wall-clock-free
and deterministic (tests/test_serving_frontend.py); under the default
`MonotonicClock` it serves real arrivals (`serve.py --frontend`).

Metrics (`frontend.*`): submitted / admitted / shed / shed_unexplained /
deprioritized / completed / cancelled counters, an ``inflight`` gauge, and
a ``ttft_p99_s`` gauge tracking what the policy last saw — catalog in
docs/observability.md.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import Callable, Sequence

from repro.obs.metrics import MetricsRegistry, percentile
from repro.serving.clock import Clock, MonotonicClock
from repro.serving.scheduler import (FinishedRequest, Request,
                                     RequestScheduler)

__all__ = ["AdmissionDecision", "FrontendConfig", "RequestShed",
           "SLOAdmissionPolicy", "ServingFrontend", "TokenStream"]

_SHED_ACTIONS = ("shed", "deprioritize", "off")


class RequestShed(RuntimeError):
    """Raised by `ServingFrontend.submit` when the admission policy sheds
    the arrival.  Carries what the policy saw so callers (and the load
    generator's goodput report) can attribute the decision."""

    def __init__(self, uid: int, p99: float | None, target: float):
        tail = "no window evidence" if p99 is None else f"p99 {p99:.4f}s"
        super().__init__(f"request {uid} shed: recent TTFT {tail} vs "
                         f"{target:.4f}s SLO target")
        self.uid = uid
        self.p99 = p99
        self.target = target


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Knobs for the SLO admission policy and the stepper.

    ``ttft_slo_s`` is the target the windowed ``sched.ttft_s`` tail is held
    against; ``slo_quantile``/``slo_window_s`` define "the tail";
    ``min_slo_samples`` is the evidence floor below which the policy always
    admits (a cold window proves nothing); ``guaranteed_admit`` is the
    inflight floor below which arrivals are *never* shed (an idle server
    must take work no matter what the trailing window says);
    ``shed_action`` picks the breach response — refuse (``'shed'``), admit
    at ``deprioritize_level`` (``'deprioritize'``, pairs with the
    scheduler's priority admission/preemption), or ``'off'`` (policy
    disabled, every arrival admits — the token-identity tests run here).
    ``step_period_s`` spaces sequencer cycles (0 = cooperative yield only);
    ``journal=True`` records a deterministic per-event text log.
    """

    ttft_slo_s: float = 1.0
    slo_quantile: float = 99.0
    slo_window_s: float = 30.0
    min_slo_samples: int = 8
    guaranteed_admit: int = 1
    shed_action: str = "shed"
    deprioritize_level: int = -1
    step_period_s: float = 0.0
    journal: bool = False

    def __post_init__(self):
        if self.shed_action not in _SHED_ACTIONS:
            raise ValueError(f"shed_action must be one of {_SHED_ACTIONS}, "
                             f"got {self.shed_action!r}")
        if self.ttft_slo_s <= 0:
            raise ValueError(f"ttft_slo_s must be > 0, got {self.ttft_slo_s}")
        if not 0.0 <= self.slo_quantile <= 100.0:
            raise ValueError(f"slo_quantile must be in [0, 100], got "
                             f"{self.slo_quantile}")
        if self.slo_window_s <= 0:
            raise ValueError(f"slo_window_s must be > 0, got "
                             f"{self.slo_window_s}")
        if self.min_slo_samples < 0 or self.guaranteed_admit < 0:
            raise ValueError("min_slo_samples and guaranteed_admit must be "
                             ">= 0")
        if self.step_period_s < 0:
            raise ValueError(f"step_period_s must be >= 0, got "
                             f"{self.step_period_s}")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """What the policy decided and the evidence it decided on."""

    action: str                  # 'admit' | 'shed' | 'deprioritize'
    p99: float | None            # windowed TTFT quantile (None: empty window)
    n_samples: int               # samples inside the window
    inflight: int                # frontend-accepted, not yet finished

    def justified(self, cfg: FrontendConfig) -> bool:
        """A non-admit is *explained* iff every gate actually passed: enough
        evidence, above the floor, and a real breach.  Anything else is a
        policy bug (`frontend.shed_unexplained`)."""
        return (self.p99 is not None
                and self.n_samples >= cfg.min_slo_samples
                and self.inflight >= cfg.guaranteed_admit
                and self.p99 > cfg.ttft_slo_s)


class SLOAdmissionPolicy:
    """Windowed-tail admission: shed/deprioritize while recent TTFT p99
    breaches the target.  Stateless between calls — every decision re-reads
    the live histogram, so recovery is automatic once the breach samples
    age out of the window."""

    def __init__(self, cfg: FrontendConfig, metrics: MetricsRegistry,
                 now: Callable[[], float]):
        self.cfg = cfg
        self._metrics = metrics
        self._now = now

    def decide(self, inflight: int) -> AdmissionDecision:
        cfg = self.cfg
        window = self._metrics.histogram("sched.ttft_s").window_samples(
            cfg.slo_window_s, self._now())
        p99 = (percentile(window, cfg.slo_quantile) if window else None)
        d = AdmissionDecision("admit", p99, len(window), inflight)
        if cfg.shed_action == "off":
            return d
        if d.justified(cfg):
            return dataclasses.replace(d, action=cfg.shed_action)
        return d


class TokenStream:
    """One submitted request's token stream.

    ``async for tok in stream`` yields tokens in commit order;
    ``await stream.result()`` resolves to the `FinishedRequest` (set for
    every terminal state — drained, cancelled, queued-cancel);
    ``await stream.aclose()`` cancels the request mid-stream.  If the
    frontend's stepper dies, the failure is re-raised here rather than
    leaving consumers waiting forever.
    """

    _DONE = object()

    def __init__(self, frontend: "ServingFrontend", uid: int,
                 prompt_len: int):
        self._frontend = frontend
        self.uid = uid
        self.prompt_len = prompt_len
        self._queue: asyncio.Queue = asyncio.Queue()
        self._done = asyncio.Event()
        self._result: FinishedRequest | None = None
        self._error: BaseException | None = None
        self._saw_token = False

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        tok = await self._queue.get()
        if tok is TokenStream._DONE:
            self._queue.put_nowait(TokenStream._DONE)  # keep re-iterable
            if self._error is not None:
                raise self._error
            raise StopAsyncIteration
        return tok

    async def result(self) -> FinishedRequest:
        await self._done.wait()
        if self._error is not None:
            raise self._error
        if self._result is None:
            raise RuntimeError(f"stream {self.uid} finished without a result")
        return self._result

    async def aclose(self) -> None:
        await self._frontend.cancel(self.uid)

    # -- frontend-side completion --------------------------------------------

    def _push(self, tok: int) -> None:
        self._queue.put_nowait(tok)

    def _finish(self, fr: FinishedRequest) -> None:
        self._result = fr
        self._done.set()
        self._queue.put_nowait(TokenStream._DONE)

    def _fail(self, exc: BaseException) -> None:
        if self._done.is_set():
            return
        self._error = exc
        self._done.set()
        self._queue.put_nowait(TokenStream._DONE)


class ServingFrontend:
    """Asyncio front end over one `RequestScheduler` (module docstring has
    the full story).  Use as an async context manager::

        async with ServingFrontend(sched, config=cfg, clock=clock) as fe:
            stream = fe.submit(prompt)          # may raise RequestShed
            async for tok in stream: ...
            finished = await stream.result()
    """

    def __init__(self, scheduler: RequestScheduler, *,
                 config: FrontendConfig | None = None,
                 clock: Clock | None = None):
        if scheduler.on_token is not None or scheduler.on_finish is not None:
            raise ValueError("ServingFrontend needs exclusive use of the "
                             "scheduler's on_token/on_finish callbacks")
        self.scheduler = scheduler
        self.config = config if config is not None else FrontendConfig()
        if clock is None:
            # Adopt the scheduler's timebase (perf_counter unless the
            # scheduler itself was built with an injected clock).
            clock = MonotonicClock(scheduler._now)
        elif clock.now != scheduler._now and not (
                isinstance(clock, MonotonicClock)
                and clock._now_fn == scheduler._now):
            raise ValueError(
                "frontend clock and scheduler timebase differ: build the "
                "scheduler with clock=<clock>.now so windowed SLO "
                "percentiles and the policy's `now` share one timebase")
        self.clock = clock
        self.obs = scheduler.obs
        self._now = scheduler._now
        m = self.obs.metrics
        self.stats = m.counter_view(
            "frontend.", ["submitted", "admitted", "shed", "shed_unexplained",
                          "deprioritized", "completed", "cancelled"])
        self.policy = SLOAdmissionPolicy(self.config, m, self._now)
        self.journal: list[str] = []
        self._streams: dict[int, TokenStream] = {}
        self._next_uid = 0
        self._wake: asyncio.Event | None = None
        self._stepper_task: asyncio.Task | None = None
        self._stepper_error: BaseException | None = None
        scheduler.on_token = self._on_token
        scheduler.on_finish = self._on_finish

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> None:
        if self._stepper_task is not None:
            raise RuntimeError("frontend already started")
        self._wake = asyncio.Event()
        if self.scheduler.pending:
            self._wake.set()
        self._stepper_task = asyncio.ensure_future(self._stepper())

    async def stop(self) -> None:
        """Stop the stepper.  In-flight requests stay resident in the
        scheduler (a restarted frontend, or a direct ``run()``, can drain
        them); streams of a *crashed* stepper have already been failed."""
        task, self._stepper_task = self._stepper_task, None
        if task is None:
            return
        task.cancel()
        try:
            await task
        except asyncio.CancelledError:
            pass

    async def __aenter__(self) -> "ServingFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    @property
    def inflight(self) -> int:
        """Accepted and not yet finished (queued + admitting + active +
        preempted, as seen from the frontend)."""
        return len(self._streams)

    # -- submission / cancellation -------------------------------------------

    def submit(self, prompt: Sequence[int], *, uid: int | None = None,
               max_new_tokens: int | None = None,
               priority: int = 0) -> TokenStream:
        """Admit one open-loop arrival (non-blocking).  Raises `RequestShed`
        when the SLO policy refuses it; propagates the scheduler's
        submission-time validation errors (e.g. `CacheCapacityError`)."""
        if self._stepper_task is None:
            raise RuntimeError("frontend not started — use "
                               "`async with frontend:` or await start()")
        if self._stepper_error is not None:
            raise RuntimeError("frontend stepper failed") \
                from self._stepper_error
        if uid is None:
            uid = self._next_uid
        if uid in self._streams:
            raise ValueError(f"uid {uid} is already in flight")
        self._next_uid = max(self._next_uid, uid + 1)
        self.stats["submitted"] += 1
        d = self.policy.decide(self.inflight)
        m = self.obs.metrics
        if d.p99 is not None:
            m.gauge("frontend.ttft_p99_s").set(d.p99)
        if d.action == "shed":
            self.stats["shed"] += 1
            if not d.justified(self.config):
                self.stats["shed_unexplained"] += 1
            self._journal("shed", uid, p99=_fmt(d.p99), n=d.n_samples)
            raise RequestShed(uid, d.p99, self.config.ttft_slo_s)
        if d.action == "deprioritize":
            self.stats["deprioritized"] += 1
            priority = min(priority, self.config.deprioritize_level)
            self._journal("deprioritize", uid, p99=_fmt(d.p99),
                          level=priority)
        stream = TokenStream(self, uid, len(prompt))
        self._streams[uid] = stream
        try:
            self.scheduler.submit(Request(uid=uid, prompt=list(prompt),
                                          max_new_tokens=max_new_tokens,
                                          priority=priority))
        except Exception:
            del self._streams[uid]
            raise
        self.stats["admitted"] += 1
        self._journal("submit", uid, prompt=len(prompt))
        self._set_gauges()
        self._wake.set()
        return stream

    async def cancel(self, uid: int) -> bool:
        """Cancel an in-flight request.  The stream resolves with
        ``cancelled=True`` (partial tokens preserved); returns False when
        the uid is unknown or already finished."""
        stream = self._streams.get(uid)
        if stream is None:
            return False
        self.stats["cancelled"] += 1
        self._journal("cancel", uid)
        self.scheduler.cancel(uid)
        if stream._result is None:
            # Queued-but-unstarted cancels record no FinishedRequest in the
            # scheduler (nothing ever held a slot); synthesize the terminal
            # record so `result()` awaiters resolve.
            self._finish_stream(FinishedRequest(
                uid=uid, prompt_len=stream.prompt_len, tokens=[], slot=-1,
                cache_len=0, cancelled=True))
        return True

    # -- scheduler callbacks (fire inside step()/cancel()) -------------------

    def _on_token(self, uid: int, tok: int) -> None:
        stream = self._streams.get(uid)
        if stream is not None:
            if not stream._saw_token:
                stream._saw_token = True
                self._journal("first_token", uid)
            stream._push(tok)

    def _on_finish(self, fr: FinishedRequest) -> None:
        self._finish_stream(fr)

    def _finish_stream(self, fr: FinishedRequest) -> None:
        stream = self._streams.pop(fr.uid, None)
        if stream is None:
            return
        if not fr.cancelled:
            self.stats["completed"] += 1
        self._journal("finish", fr.uid, tokens=len(fr.tokens),
                      cancelled=fr.cancelled)
        stream._finish(fr)
        self._set_gauges()

    # -- the stepper ---------------------------------------------------------

    async def _stepper(self) -> None:
        """The one task allowed to call ``scheduler.step()``: drains while
        work is pending, parks on the wake event when idle, and on failure
        fails every live stream (consumers see the exception, not a hang)."""
        sched = self.scheduler
        try:
            while True:
                if not sched.pending:
                    self._wake.clear()
                    if not sched.pending:       # nothing raced in before clear
                        await self._wake.wait()
                    continue
                sched.step()
                self._set_gauges()
                await self.clock.sleep(self.config.step_period_s)
        except asyncio.CancelledError:
            raise
        except BaseException as e:
            self._stepper_error = e
            for stream in list(self._streams.values()):
                stream._fail(e)
            self._streams.clear()
            raise

    # -- misc ----------------------------------------------------------------

    def _set_gauges(self) -> None:
        self.obs.metrics.gauge("frontend.inflight").set(len(self._streams))

    def _journal(self, event: str, uid: int, **kw) -> None:
        if not self.config.journal:
            return
        extra = "".join(f" {k}={kw[k]}" for k in sorted(kw))
        self.journal.append(f"{self._now():.9f} {event} uid={uid}{extra}")


def _fmt(x: float | None) -> str:
    return "none" if x is None else f"{x:.9f}"
