"""`repro.serving` — the unified inference API for the HSA reproduction.

One import gives the whole serving surface:

  * `InferenceEngine` / `EngineSpec` — init -> PTQ deploy -> HSA engine ->
    jit-cached prefill + a fused, jitted decode loop (engine.py).
  * `GenerationConfig` / `SamplingParams` / `SpeculativeConfig` — greedy,
    temperature, top-k, top-p, stop tokens, max_new_tokens, and the
    multi-token speculative-decode switch (sampling.py).
  * `NgramDrafter` / `MTPDrafter` / `Drafter` — the draft models behind
    speculative decode: model-free prompt lookup and deepseek-v3 MTP
    self-speculation, verified in one MMM dispatch with exact cache
    rollback (speculative.py).
  * `RequestScheduler` / `CachePool` / `Request` — continuous batching over a
    *paged* slot pool (per-class cache lengths) with chunk-granular MMM
    admissions overlapping MVM decode, like the paper's sequencer; priority
    admission, per-slot speculative multi-token steps, and a host-memory
    spill tier (`host_spill=True`) that preempts low-priority lanes to CPU
    DRAM and resumes them bit-exactly — oversubscription instead of a hard
    admission failure (scheduler.py).
  * `PrefixCache` / `RadixPageIndex` / `SnapshotPrefixIndex` — shared-prefix
    reuse (`prefix_cache=True` on the scheduler/pool): refcounted immutable
    cache pages under a radix index (whole-cache snapshots on recurrent
    archs), adopted at admission so shared prompt prefixes skip their
    prefill, with COW tail-page copies, LRU eviction, and a host tier for
    cold pages (paging.py).
  * `ChunkedPrefill` / `bucket_length` / `chunk_schedule` — the ladder-
    bucketed, chunked prompt-admission machinery (engine.py).
  * `ServingFrontend` / `FrontendConfig` / `TokenStream` — the asyncio
    open-loop front end: non-blocking `submit` -> per-request async token
    stream, a stepper task owning the sequencer cycle, SLO-aware admission
    (shed/deprioritize on windowed TTFT p99 breach) — all on an injectable
    `Clock` (`MonotonicClock` live, `VirtualClock` for wall-clock-free
    deterministic tests) (frontend.py, clock.py).
  * `Workload` / `PoissonArrivals` / `BurstyArrivals` / `LengthMix` /
    `run_open_loop` — seeded open-loop load generation and the
    goodput-under-load driver (loadgen.py).
  * `ServeCell` / `build_serve` — typed sharding/shape plan for multi-chip
    deployments (cell.py; `runtime.serve_step` re-exports it).
    `InferenceEngine.from_config(mesh=...)` *executes* the plan: params
    under the cell's shardings, caches under `lm.cache_axes`, every jit
    issued with explicit in/out shardings — greedy token-identical to the
    single-device engine per cache arch (tests/test_serving_sharded.py).
"""

from repro.serving.cell import (ServeCell, build_serve,
                                prefill_chunk_step_fn, serving_engine,
                                verify_chunk_step_fn)
from repro.serving.clock import Clock, MonotonicClock, VirtualClock
from repro.serving.engine import (CacheCapacityError, ChunkedPrefill,
                                  EngineSpec, GenerationResult,
                                  InferenceEngine, bucket_length,
                                  chunk_schedule, pytree_nbytes)
from repro.serving.frontend import (FrontendConfig, RequestShed,
                                    SLOAdmissionPolicy, ServingFrontend,
                                    TokenStream)
from repro.serving.loadgen import (BurstyArrivals, GoodputReport, LengthMix,
                                   PoissonArrivals, Workload, run_open_loop)
from repro.serving.paging import (PageLeaseError, PrefixCache,
                                  RadixPageIndex, SnapshotPrefixIndex)
from repro.serving.sampling import (GREEDY, GenerationConfig, SamplingParams,
                                    SpeculativeConfig, sample)
from repro.serving.scheduler import (CachePool, FinishedRequest, Request,
                                     RequestScheduler)
from repro.serving.speculative import (Drafter, MTPDrafter, NgramDrafter,
                                       make_drafter, ngram_propose)

__all__ = [
    "BurstyArrivals",
    "CacheCapacityError", "CachePool", "ChunkedPrefill", "Clock", "Drafter",
    "EngineSpec",
    "FinishedRequest", "FrontendConfig", "GenerationConfig",
    "GenerationResult", "GoodputReport", "GREEDY",
    "InferenceEngine", "LengthMix", "MonotonicClock", "MTPDrafter",
    "NgramDrafter", "PageLeaseError",
    "PoissonArrivals", "PrefixCache", "RadixPageIndex", "Request",
    "RequestScheduler", "RequestShed", "SamplingParams", "ServeCell",
    "ServingFrontend", "SLOAdmissionPolicy", "SnapshotPrefixIndex",
    "SpeculativeConfig", "TokenStream", "VirtualClock", "Workload",
    "bucket_length", "build_serve", "chunk_schedule", "make_drafter",
    "ngram_propose", "prefill_chunk_step_fn", "pytree_nbytes",
    "run_open_loop", "sample",
    "serving_engine", "verify_chunk_step_fn",
]
