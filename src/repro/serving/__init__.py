"""`repro.serving` — the unified inference API for the HSA reproduction.

One import gives the whole serving surface:

  * `InferenceEngine` / `EngineSpec` — init -> PTQ deploy -> HSA engine ->
    jit-cached prefill + a fused, jitted decode loop (engine.py).
  * `GenerationConfig` / `SamplingParams` / `SpeculativeConfig` — greedy,
    temperature, top-k, top-p, stop tokens, max_new_tokens, and the
    multi-token speculative-decode switch (sampling.py).
  * `NgramDrafter` / `MTPDrafter` / `Drafter` — the draft models behind
    speculative decode: model-free prompt lookup and deepseek-v3 MTP
    self-speculation, verified in one MMM dispatch with exact cache
    rollback (speculative.py).
  * `RequestScheduler` / `CachePool` / `Request` — continuous batching over a
    *paged* slot pool (per-class cache lengths) with chunk-granular MMM
    admissions overlapping MVM decode, like the paper's sequencer; priority
    admission, per-slot speculative multi-token steps, and a host-memory
    spill tier (`host_spill=True`) that preempts low-priority lanes to CPU
    DRAM and resumes them bit-exactly — oversubscription instead of a hard
    admission failure (scheduler.py).
  * `PrefixCache` / `RadixPageIndex` / `SnapshotPrefixIndex` — shared-prefix
    reuse (`prefix_cache=True` on the scheduler/pool): refcounted immutable
    cache pages under a radix index (whole-cache snapshots on recurrent
    archs), adopted at admission so shared prompt prefixes skip their
    prefill, with COW tail-page copies, LRU eviction, and a host tier for
    cold pages (paging.py).
  * `ChunkedPrefill` / `bucket_length` / `chunk_schedule` — the ladder-
    bucketed, chunked prompt-admission machinery (engine.py).
  * `ServeCell` / `build_serve` — typed sharding/shape plan for multi-chip
    deployments (cell.py; `runtime.serve_step` re-exports it).
    `InferenceEngine.from_config(mesh=...)` *executes* the plan: params
    under the cell's shardings, caches under `lm.cache_axes`, every jit
    issued with explicit in/out shardings — greedy token-identical to the
    single-device engine per cache arch (tests/test_serving_sharded.py).
"""

from repro.serving.cell import (ServeCell, build_serve,
                                prefill_chunk_step_fn, serving_engine,
                                verify_chunk_step_fn)
from repro.serving.engine import (CacheCapacityError, ChunkedPrefill,
                                  EngineSpec, GenerationResult,
                                  InferenceEngine, bucket_length,
                                  chunk_schedule, pytree_nbytes)
from repro.serving.paging import (PageLeaseError, PrefixCache,
                                  RadixPageIndex, SnapshotPrefixIndex)
from repro.serving.sampling import (GREEDY, GenerationConfig, SamplingParams,
                                    SpeculativeConfig, sample)
from repro.serving.scheduler import (CachePool, FinishedRequest, Request,
                                     RequestScheduler)
from repro.serving.speculative import (Drafter, MTPDrafter, NgramDrafter,
                                       make_drafter, ngram_propose)

__all__ = [
    "CacheCapacityError", "CachePool", "ChunkedPrefill", "Drafter",
    "EngineSpec",
    "FinishedRequest", "GenerationConfig", "GenerationResult", "GREEDY",
    "InferenceEngine", "MTPDrafter", "NgramDrafter", "PageLeaseError",
    "PrefixCache", "RadixPageIndex", "Request",
    "RequestScheduler", "SamplingParams", "ServeCell", "SnapshotPrefixIndex",
    "SpeculativeConfig",
    "bucket_length", "build_serve", "chunk_schedule", "make_drafter",
    "ngram_propose", "prefill_chunk_step_fn", "pytree_nbytes", "sample",
    "serving_engine", "verify_chunk_step_fn",
]
