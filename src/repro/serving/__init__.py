"""`repro.serving` — the unified inference API for the HSA reproduction.

One import gives the whole serving surface:

  * `InferenceEngine` / `EngineSpec` — init -> PTQ deploy -> HSA engine ->
    jit-cached prefill + a fused, jitted decode loop (engine.py).
  * `GenerationConfig` / `SamplingParams` — greedy, temperature, top-k,
    top-p, stop tokens, max_new_tokens (sampling.py).
  * `RequestScheduler` / `CachePool` / `Request` — continuous batching over a
    slot-based decode-cache pool: MMM-phase prefill admissions overlapping
    MVM-phase decode, like the paper's sequencer (scheduler.py).
  * `ServeCell` / `build_serve` — typed sharding/shape plan for multi-chip
    deployments (cell.py; `runtime.serve_step` re-exports it).
"""

from repro.serving.cell import ServeCell, build_serve, serving_engine
from repro.serving.engine import (EngineSpec, GenerationResult,
                                  InferenceEngine)
from repro.serving.sampling import (GREEDY, GenerationConfig, SamplingParams,
                                    sample)
from repro.serving.scheduler import (CachePool, FinishedRequest, Request,
                                     RequestScheduler)

__all__ = [
    "CachePool", "EngineSpec", "FinishedRequest", "GenerationConfig",
    "GenerationResult", "GREEDY", "InferenceEngine", "Request",
    "RequestScheduler", "SamplingParams", "ServeCell", "build_serve",
    "sample", "serving_engine",
]
