"""`repro.serving` — the unified inference API for the HSA reproduction.

One import gives the whole serving surface:

  * `InferenceEngine` / `EngineSpec` — init -> PTQ deploy -> HSA engine ->
    jit-cached prefill + a fused, jitted decode loop (engine.py).
  * `GenerationConfig` / `SamplingParams` — greedy, temperature, top-k,
    top-p, stop tokens, max_new_tokens (sampling.py).
  * `RequestScheduler` / `CachePool` / `Request` — continuous batching over a
    *paged* slot pool (per-class cache lengths) with chunk-granular MMM
    admissions overlapping MVM decode, like the paper's sequencer
    (scheduler.py).
  * `ChunkedPrefill` / `bucket_length` / `chunk_schedule` — the ladder-
    bucketed, chunked prompt-admission machinery (engine.py).
  * `ServeCell` / `build_serve` — typed sharding/shape plan for multi-chip
    deployments (cell.py; `runtime.serve_step` re-exports it).
"""

from repro.serving.cell import (ServeCell, build_serve,
                                prefill_chunk_step_fn, serving_engine)
from repro.serving.engine import (ChunkedPrefill, EngineSpec,
                                  GenerationResult, InferenceEngine,
                                  bucket_length, chunk_schedule)
from repro.serving.sampling import (GREEDY, GenerationConfig, SamplingParams,
                                    sample)
from repro.serving.scheduler import (CachePool, FinishedRequest, Request,
                                     RequestScheduler)

__all__ = [
    "CachePool", "ChunkedPrefill", "EngineSpec", "FinishedRequest",
    "GenerationConfig", "GenerationResult", "GREEDY", "InferenceEngine",
    "Request", "RequestScheduler", "SamplingParams", "ServeCell",
    "bucket_length", "build_serve", "chunk_schedule",
    "prefill_chunk_step_fn", "sample", "serving_engine",
]
