"""Open-loop load generation for the serving front end.

Closed-loop benches (submit N, drain, divide) measure a server that is
never actually under pressure: the arrival process *is* the completion
process.  Production traffic is open-loop — arrivals come when they come —
and the number that matters is **goodput**: requests that met their SLO
per second, against the offered rate, with the shed rate alongside.

This module supplies the three pieces:

  * arrival processes — seeded `PoissonArrivals` and `BurstyArrivals`
    (a 2-state Markov-modulated Poisson process: calm/burst rates with a
    geometric dwell, parametrized so the *stationary mean* rate equals the
    configured ``rate_rps`` — burstiness changes variance, not offered
    load);
  * `LengthMix` — shareGPT-shaped lognormal prompt/output lengths clipped
    to a configured support (so cache-class sizing stays honest);
  * `Workload` (a fully seeded request set: uid, arrival time, prompt,
    budget) and `run_open_loop`, the driver that paces submissions on the
    frontend's clock — virtual in tests/CI smoke, monotonic in the bench —
    consumes every admitted stream concurrently, and folds the outcomes
    into a `GoodputReport`.

Everything is reproducible by construction: one `numpy` Generator seeded
from `Workload.seed` drives arrivals, lengths, and prompt tokens, and the
driver never consults any other randomness.
"""

from __future__ import annotations

import asyncio
import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.obs.metrics import percentile
from repro.serving.frontend import RequestShed, ServingFrontend

__all__ = ["ArrivalProcess", "BurstyArrivals", "GoodputReport", "LengthMix",
           "OpenLoopRequest", "PoissonArrivals", "RequestOutcome", "Workload",
           "run_open_loop"]


class ArrivalProcess:
    """Seeded interarrival sampler; ``rate_rps`` is the stationary mean."""

    rate_rps: float

    def interarrivals(self, n: int, rng: np.random.Generator) -> list[float]:
        raise NotImplementedError

    def times(self, n: int, rng: np.random.Generator) -> list[float]:
        """Cumulative arrival times of the first ``n`` requests."""
        out, t = [], 0.0
        for dt in self.interarrivals(n, rng):
            t += dt
            out.append(t)
        return out


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals: i.i.d. exponential interarrivals."""

    rate_rps: float

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")

    def interarrivals(self, n: int, rng: np.random.Generator) -> list[float]:
        return rng.exponential(1.0 / self.rate_rps, size=n).tolist()


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """2-state Markov-modulated Poisson process.

    Arrivals alternate between a *calm* regime and a *burst* regime whose
    instantaneous rate is ``burst_factor`` times the calm rate; regime
    dwell is geometric with ``mean_burst_len`` arrivals per burst, and
    ``p_burst`` is the stationary fraction of arrivals drawn in the burst
    regime.  The calm/burst rates are solved so the stationary mean
    interarrival is exactly ``1 / rate_rps`` — the same offered load as
    `PoissonArrivals(rate_rps)`, with the variance (and queue pain)
    concentrated into bursts.
    """

    rate_rps: float
    burst_factor: float = 4.0
    p_burst: float = 0.25
    mean_burst_len: float = 8.0

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.burst_factor < 1.0:
            raise ValueError(f"burst_factor must be >= 1, got "
                             f"{self.burst_factor}")
        if not 0.0 < self.p_burst < 1.0:
            raise ValueError(f"p_burst must be in (0, 1), got {self.p_burst}")
        if self.mean_burst_len < 1.0:
            raise ValueError(f"mean_burst_len must be >= 1, got "
                             f"{self.mean_burst_len}")

    def interarrivals(self, n: int, rng: np.random.Generator) -> list[float]:
        # E[dt] = p_burst/rate_burst + (1-p_burst)/rate_calm = 1/rate_rps
        # with rate_burst = burst_factor * rate_calm.
        rate_calm = self.rate_rps * (
            1.0 - self.p_burst + self.p_burst / self.burst_factor)
        rate_burst = self.burst_factor * rate_calm
        # Per-arrival switch probabilities whose stationary occupancy of the
        # burst state is p_burst with geometric mean dwell mean_burst_len.
        q_leave = 1.0 / self.mean_burst_len
        q_enter = q_leave * self.p_burst / (1.0 - self.p_burst)
        in_burst = bool(rng.random() < self.p_burst)
        out = []
        for _ in range(n):
            rate = rate_burst if in_burst else rate_calm
            out.append(float(rng.exponential(1.0 / rate)))
            if rng.random() < (q_leave if in_burst else q_enter):
                in_burst = not in_burst
        return out


@dataclasses.dataclass(frozen=True)
class LengthMix:
    """shareGPT-shaped request sizes: lognormal around the geometric middle
    of the support, clipped to ``[min, max]`` — most requests modest, a
    heavy right tail, and a hard ceiling the cache classes can be sized
    against."""

    prompt_min: int = 4
    prompt_max: int = 64
    new_min: int = 2
    new_max: int = 16
    sigma: float = 0.6

    def __post_init__(self):
        for lo, hi, what in ((self.prompt_min, self.prompt_max, "prompt"),
                             (self.new_min, self.new_max, "new")):
            if not 1 <= lo <= hi:
                raise ValueError(f"need 1 <= {what}_min <= {what}_max, got "
                                 f"[{lo}, {hi}]")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    def sample(self, n: int,
               rng: np.random.Generator) -> list[tuple[int, int]]:
        """``n`` (prompt_len, max_new_tokens) pairs within the support."""

        def draw(lo: int, hi: int) -> list[int]:
            median = math.sqrt(lo * hi)
            raw = median * rng.lognormal(0.0, self.sigma, size=n)
            return [int(min(hi, max(lo, round(x)))) for x in raw.tolist()]

        return list(zip(draw(self.prompt_min, self.prompt_max),
                        draw(self.new_min, self.new_max)))


@dataclasses.dataclass(frozen=True)
class OpenLoopRequest:
    uid: int
    at_s: float                  # arrival offset from the run's t0
    prompt: tuple[int, ...]
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class Workload:
    """A fully materialized, seeded open-loop request set."""

    arrivals: ArrivalProcess
    lengths: LengthMix = LengthMix()
    n_requests: int = 16
    vocab_size: int = 256
    seed: int = 0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got "
                             f"{self.n_requests}")
        if self.vocab_size < 2:
            raise ValueError(f"vocab_size must be >= 2, got "
                             f"{self.vocab_size}")

    def requests(self) -> list[OpenLoopRequest]:
        rng = np.random.default_rng(self.seed)
        times = self.arrivals.times(self.n_requests, rng)
        sizes = self.lengths.sample(self.n_requests, rng)
        out = []
        for uid, (at, (plen, budget)) in enumerate(zip(times, sizes)):
            prompt = tuple(int(t) for t in rng.integers(
                1, self.vocab_size, size=plen).tolist())
            out.append(OpenLoopRequest(uid=uid, at_s=float(at),
                                       prompt=prompt,
                                       max_new_tokens=budget))
        return out


@dataclasses.dataclass
class RequestOutcome:
    """One request as the driver saw it."""

    uid: int
    status: str                  # 'ok' | 'shed' | 'cancelled'
    submitted_s: float           # offset from the run's t0
    ttft_s: float | None = None
    latency_s: float | None = None
    n_tokens: int = 0
    met_slo: bool = False


@dataclasses.dataclass
class GoodputReport:
    """Offered load vs delivered: the goodput-under-load result block."""

    offered_rps: float
    ttft_slo_s: float
    elapsed_s: float
    outcomes: list[RequestOutcome]
    sheds_unexplained: int

    @property
    def n_requests(self) -> int:
        return len(self.outcomes)

    @property
    def completed(self) -> int:
        return sum(1 for o in self.outcomes if o.status == "ok")

    @property
    def shed(self) -> int:
        return sum(1 for o in self.outcomes if o.status == "shed")

    @property
    def met_slo(self) -> int:
        return sum(1 for o in self.outcomes if o.met_slo)

    @property
    def shed_rate(self) -> float:
        return self.shed / self.n_requests if self.outcomes else 0.0

    @property
    def goodput_rps(self) -> float:
        return self.met_slo / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def to_dict(self) -> dict:
        """JSON-ready block for `bench_serving` / `serve.py`."""
        ttfts = [o.ttft_s for o in self.outcomes if o.ttft_s is not None]
        out = {
            "offered_rps": self.offered_rps,
            "ttft_slo_s": self.ttft_slo_s,
            "elapsed_s": self.elapsed_s,
            "n_requests": self.n_requests,
            "completed": self.completed,
            "shed": self.shed,
            "shed_rate": self.shed_rate,
            "met_slo": self.met_slo,
            "goodput_rps": self.goodput_rps,
            "sheds_unexplained": self.sheds_unexplained,
        }
        if ttfts:
            out["ttft"] = {"p50": percentile(ttfts, 50.0),
                           "p95": percentile(ttfts, 95.0),
                           "p99": percentile(ttfts, 99.0)}
        return out


async def run_open_loop(frontend: ServingFrontend, workload: Workload, *,
                        ttft_slo_s: float | None = None) -> GoodputReport:
    """Drive ``workload`` through ``frontend`` open-loop.

    Submission times follow the workload's arrival process on the
    frontend's clock regardless of completions (that is what makes it open
    loop); every admitted stream is consumed by its own task, so slow
    requests never delay later arrivals.  ``ttft_slo_s`` defaults to the
    frontend's configured target and defines ``met_slo``.
    """
    slo = (ttft_slo_s if ttft_slo_s is not None
           else frontend.config.ttft_slo_s)
    clock = frontend.clock
    requests = workload.requests()
    outcomes: list[RequestOutcome] = []
    consumers: list[asyncio.Task] = []
    t0 = clock.now()

    async def consume(stream, t_sub: float) -> None:
        o = RequestOutcome(uid=stream.uid, status="ok",
                           submitted_s=t_sub - t0)
        async for _tok in stream:
            if o.ttft_s is None:
                o.ttft_s = clock.now() - t_sub
            o.n_tokens += 1
        fr = await stream.result()
        o.latency_s = clock.now() - t_sub
        if fr.cancelled:
            o.status = "cancelled"
        else:
            o.met_slo = o.ttft_s is not None and o.ttft_s <= slo
        outcomes.append(o)

    for req in requests:
        await clock.sleep(t0 + req.at_s - clock.now())
        t_sub = clock.now()
        try:
            stream = frontend.submit(req.prompt, uid=req.uid,
                                     max_new_tokens=req.max_new_tokens)
        except RequestShed:
            outcomes.append(RequestOutcome(uid=req.uid, status="shed",
                                           submitted_s=t_sub - t0))
            continue
        consumers.append(asyncio.ensure_future(consume(stream, t_sub)))
    if consumers:
        await asyncio.gather(*consumers)
    outcomes.sort(key=lambda o: o.uid)
    return GoodputReport(offered_rps=workload.arrivals.rate_rps,
                         ttft_slo_s=slo,
                         elapsed_s=clock.now() - t0,
                         outcomes=outcomes,
                         sheds_unexplained=frontend.stats["shed_unexplained"])
