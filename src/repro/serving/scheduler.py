"""Continuous-batching request scheduler over a slot-based cache pool.

Mirrors the HSA sequencer (paper Sec. IV): the engine's *prefill* path (MMM
dataflow) admits new requests into free cache slots while the resident slots
advance through the *decode* path (MVM dataflow) one token per step.  The two
phases interleave at step granularity — a long-running decode batch never has
to drain before new prompts enter, which is exactly the LISO/SILO mix the
paper evaluates.

`CachePool` owns N slots of decode state behind one interface over
`lm.make_decode_cache`: every per-model cache kind (KV rings, MXINT4-decoded
MoE experts, Mamba conv state, RetNet's O(1) retention state, the online RoPE
angle memory, the per-sequence position) is just a pytree leaf with a leading
``[n_slots]`` axis.  The decode step vmaps `lm.forward_decode` over that axis,
so slots at *different* positions (staggered admissions) batch into one
dispatch — per-slot ``pos`` and RoPE state are vmapped scalars, not a shared
host counter.

The pool steps all N lanes every iteration (free lanes compute garbage that is
never read) — one compiled shape, no re-trace as occupancy fluctuates, the
same trade the fixed-size PE array makes in silicon.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import GenerationConfig, sample

Params = dict[str, Any]


@dataclasses.dataclass
class Request:
    """One generation request; `max_new_tokens` overrides the scheduler's."""

    uid: int
    prompt: Any                          # int sequence [S_in]
    max_new_tokens: int | None = None


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt_len: int
    tokens: list[int]                    # emitted tokens incl. any stop token
    slot: int                            # pool slot it ran in (for tests/stats)


class CachePool:
    """N decode-cache slots as one stacked pytree ([n_slots, ...] per leaf).

    Built over `lm.make_decode_cache` (batch=1 per slot), so the slot layout
    is identical for every cache kind the model zoo produces.  Prefilled
    batch-1 caches are scattered into a slot with ``write``; the whole pool is
    advanced in one vmapped decode step by the scheduler.
    """

    def __init__(self, cfg, n_slots: int, cache_len: int,
                 dtype=jnp.float32):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        template = lm.make_decode_cache(cfg, 1, cache_len, dtype)
        self.store = jax.tree.map(
            lambda x: jnp.zeros((n_slots,) + x.shape, x.dtype), template)
        self._free = list(range(n_slots))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> int | None:
        return self._free.pop(0) if self._free else None

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self._free.append(slot)

    def write(self, slot: int, cache: Params) -> None:
        """Scatter one batch-1 cache (e.g. fresh from prefill) into a slot."""
        self.store = jax.tree.map(
            lambda pool, c: pool.at[slot].set(c.astype(pool.dtype)),
            self.store, cache)


class RequestScheduler:
    """Admit-while-decoding serving loop around one `InferenceEngine`.

    ``step()`` performs one sequencer cycle: (1) admit queued requests into
    free slots via the MMM prefill path, (2) advance every resident slot one
    token through the vmapped MVM decode path, (3) retire slots that hit a
    stop token or their token budget.  ``run()`` drains the queue.

    Stochastic sampling stays per-request reproducible: each request draws
    from ``fold_in(key, uid)`` regardless of which slot it lands in or what
    else shares the batch.
    """

    def __init__(self, engine: InferenceEngine, *, n_slots: int = 4,
                 cache_len: int = 128,
                 gen: GenerationConfig = GenerationConfig(),
                 key: jax.Array | None = None):
        self.engine = engine
        self.gen = gen
        self.pool = CachePool(engine.cfg, n_slots, cache_len)
        self.base_key = key if key is not None else jax.random.key(0)

        self._queue: list[Request] = []
        self._active: dict[int, dict] = {}       # slot -> per-request state
        self._finished: list[FinishedRequest] = []
        # Current token per slot [N, 1, 1] (lane-major so vmap sees [1, 1],
        # the [B=1, T=1] shape forward_decode expects).
        self._tokens = jnp.zeros((n_slots, 1, 1), jnp.int32)
        self._keys = jax.random.split(self.base_key, n_slots)  # set on admit

        # Same split-then-sample order as the engine's fused loop, so a
        # request's token stream is identical whether it runs here or through
        # engine.generate with key = fold_in(base_key, uid).
        def pool_step(params, tokens, store, keys):
            def one(tok, cache, key):
                logits, new_cache = lm.forward_decode(
                    params, tok, cache, engine.cfg, engine.hsa)
                key, sub = jax.random.split(key)
                nxt = sample(logits[0], gen.sampling, sub)
                return nxt, new_cache, key
            return jax.vmap(one)(tokens, store, keys)

        self._pool_step = jax.jit(pool_step)

    # -- queue management ---------------------------------------------------

    def submit(self, request: Request) -> None:
        self._queue.append(request)

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._active)

    # -- the sequencer cycle ------------------------------------------------

    def _admit(self) -> None:
        """MMM phase: prefill queued requests into free slots."""
        while self._queue and self.pool.free_slots:
            req = self._queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            budget = req.max_new_tokens or self.gen.max_new_tokens
            # Decode writes cache positions s .. s+budget-1; past-capacity
            # positions would silently clamp onto the last linear-cache slot
            # (gqa_decode), so reject instead of corrupting attention.
            if prompt.shape[1] + budget > self.pool.cache_len:
                raise ValueError(
                    f"request {req.uid}: prompt ({prompt.shape[1]}) + "
                    f"max_new_tokens ({budget}) exceeds the pool cache_len "
                    f"({self.pool.cache_len})")
            slot = self.pool.acquire()
            logits, cache = self.engine.prefill(
                prompt, cache_len=self.pool.cache_len)
            self.pool.write(slot, cache)

            key = jax.random.fold_in(self.base_key, req.uid)
            key, sub = jax.random.split(key)
            tok = sample(logits[0], self.gen.sampling, sub)
            self._tokens = self._tokens.at[slot, 0, 0].set(tok)
            self._keys = self._keys.at[slot].set(key)
            self._active[slot] = {"req": req, "emitted": [], "budget": budget}

    def _retire(self, slot: int) -> None:
        st = self._active.pop(slot)
        self._finished.append(FinishedRequest(
            uid=st["req"].uid, prompt_len=len(st["req"].prompt),
            tokens=st["emitted"], slot=slot))
        self.pool.release(slot)

    def step(self) -> int:
        """One admit+decode cycle; returns the number of tokens emitted."""
        self._admit()
        if not self._active:
            return 0

        # Snapshot this step's token per active slot *before* decoding: like
        # the fused loop, the token emitted at step i is the one sampled from
        # the previous step's (or prefill's) logits.
        emitted = 0
        stepped = np.asarray(jax.device_get(self._tokens[:, 0, 0]))
        next_toks, self.pool.store, self._keys = self._pool_step(
            self.engine.params, self._tokens, self.pool.store, self._keys)
        self._tokens = next_toks[:, None, None]

        for slot in list(self._active):
            st = self._active[slot]
            tok = int(stepped[slot])
            st["emitted"].append(tok)
            emitted += 1
            if tok in self.gen.stop_tokens or len(st["emitted"]) >= st["budget"]:
                self._retire(slot)
        return emitted

    def run(self) -> dict[int, FinishedRequest]:
        """Drain queue + active slots; returns results keyed by request uid."""
        while self.pending:
            self.step()
        return {f.uid: f for f in self._finished}
