"""Continuous-batching request scheduler over a paged, slot-based cache pool.

Mirrors the HSA sequencer (paper Sec. IV): the engine's *prefill* path (MMM
dataflow) admits new requests into free cache slots while the resident slots
advance through the *decode* path (MVM dataflow) one token per step.  Two
refinements over the original slot pool make the admission path match the
paper's LISO scenario (750-token prompts entering a busy decode batch):

  * **Chunk-granular admission** — `_admit` advances at most ONE prefill
    chunk per `step()` (`InferenceEngine.begin_chunked_prefill`), so a long
    prompt overlaps ~n_chunks decode cycles instead of stalling every lane
    for one monolithic MMM pass, and the ladder-sized chunks keep the number
    of compiled prefill shapes logarithmic in prompt length.

  * **Paged pool** — `CachePool` holds *classes* of slots (small/medium/
    large cache lengths over the same stacked-pytree layout) instead of one
    global `cache_len`; admission picks the smallest class that fits
    ``prompt + budget``, so short requests stop paying the longest request's
    KV memory.

  * **Host spill tier + preemption** — the capacity rung below the device
    slots is host DRAM (the paper's edge memory hierarchy): `CachePool.spill`
    parks a slot's whole cache pytree in host memory bit-exactly and frees
    its lane, `fetch` restores it, and with ``host_spill=True`` the
    scheduler preempts the lowest-priority resident lane when a
    higher-priority request finds the pool full — oversubscription instead
    of a hard admission failure.

`CachePool` builds each class over `lm.make_decode_cache`: every per-model
cache kind (KV rings, MXINT4-decoded MoE experts, Mamba conv state, RetNet's
O(1) retention state, the online RoPE angle memory, the per-sequence
position) is just a pytree leaf with a leading ``[n_slots]`` axis.  The
decode step vmaps `lm.forward_decode` over that axis — one dispatch per
*class* with at least one resident request (free lanes still compute garbage
that is never read: one compiled shape per class, no re-trace as occupancy
fluctuates, the same trade the fixed-size PE array makes in silicon).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.obs import SCHED_TRACK, Observability, request_track
from repro.serving import speculative as spec_mod
from repro.serving.engine import (CacheCapacityError, InferenceEngine,
                                  pytree_nbytes)
from repro.serving.sampling import GenerationConfig, sample

Params = dict[str, Any]


@dataclasses.dataclass
class Request:
    """One generation request; `max_new_tokens` overrides the scheduler's.

    ``priority``: higher admits first; FIFO among equal priorities (0 is the
    default class, negative deprioritizes).
    """

    uid: int
    prompt: Any                          # int sequence [S_in]
    max_new_tokens: int | None = None
    priority: int = 0


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt_len: int
    tokens: list[int]                    # emitted tokens incl. any stop token
    slot: int                            # pool slot handle (for tests/stats)
    cache_len: int = 0                   # cache class the request ran in
    cancelled: bool = False              # retired early via `cancel(uid)`
    # Speculative-decode stats (zero on the per-token path):
    verify_steps: int = 0                # verify dispatches while resident
    accepted_drafts: int = 0             # drafted tokens verification accepted

    @property
    def tokens_per_step(self) -> float:
        if not self.verify_steps:
            return 1.0
        return 1.0 + self.accepted_drafts / self.verify_steps


class CachePool:
    """Paged decode-cache pool: slot *classes* of increasing cache length,
    backed by a device tier and a host (CPU DRAM) spill tier.

    ``classes`` is a sequence of ``(n_slots, cache_len)`` pairs; the legacy
    single-class form ``CachePool(cfg, n_slots, cache_len)`` still works.
    Each class is one stacked pytree (``[n_slots_c, ...]`` per leaf) over
    `lm.make_decode_cache` (batch=1 per slot), so the slot layout is
    identical for every cache kind the model zoo produces.  Prefilled
    batch-1 caches are scattered into a slot with ``write``; the scheduler
    advances each class in one vmapped decode step.

    Slot ids are *request-lifetime handles*, not lane indices: ``acquire``
    binds a fresh id to a free device lane in the smallest fitting class,
    ``spill`` moves the slot's whole cache pytree to host memory via
    ``jax.device_put`` (freeing the lane for another request — this is what
    lets the pool oversubscribe its device capacity), and ``fetch`` binds a
    free lane again and restores the cache bit-exactly.  ``residency(slot)``
    reports which tier a slot lives in; ``spill_stats`` counts spills,
    fetches, and bytes moved each way.

    With ``prefix_cache=True`` the pool also owns a `PrefixCache`
    (serving/paging.py): a refcounted-page radix index (or whole-cache
    snapshot index on recurrent archs) over finished prompt prefixes.
    ``prefix_lookup`` at admission returns an adopted-prefix length plus an
    assembled warm cache; ``prefix_register`` indexes a finished prefill;
    page leases are tied to slot ids and dropped in ``release``;
    ``prefix_maintain`` runs the LRU/host-migration cycle once per
    scheduler step.
    """

    def __init__(self, cfg, n_slots: int | None = None,
                 cache_len: int | None = None, *,
                 classes: Sequence[tuple[int, int]] | None = None,
                 dtype=jnp.float32, mesh=None, policy=None,
                 obs: Observability | None = None,
                 prefix_cache: bool = False, prefix_page_size: int = 16,
                 max_prefix_pages: int | None = None,
                 device_prefix_pages: int | None = None):
        if classes is None:
            classes = [(n_slots if n_slots is not None else 4,
                        cache_len if cache_len is not None else 128)]
        classes = sorted(classes, key=lambda c: c[1])
        if not classes or any(n < 1 or length < 1 for n, length in classes):
            raise ValueError(f"bad cache classes: {classes}")
        if len({length for _, length in classes}) != len(classes):
            raise ValueError(f"duplicate class cache_len: {classes}")
        self.cfg = cfg
        self.classes = [(int(n), int(length)) for n, length in classes]
        self.n_slots = sum(n for n, _ in self.classes)
        self.cache_len = self.classes[-1][1]      # largest class (compat)
        self.dtype = dtype

        # Multi-chip pool: with a mesh, every class store lives sharded under
        # `lm.cache_axes` (lane axis never sharded — it is addressing, not
        # distribution), spill gathers a slot to host memory, and fetch
        # re-places it under the same cache shardings bit-exactly.
        from repro.runtime import sharding as shd
        self.mesh = mesh
        self.policy = (policy or shd.ShardingPolicy()) if mesh is not None \
            else None
        self._axes = lm.cache_axes(cfg)
        self._store_shardings: dict[int, Params] = {}

        self._stores: dict[int, Params] = {}
        self._lanes: dict[int, list[int]] = {}          # clen -> free lanes
        self._lane_of: dict[int, tuple[int, int]] = {}  # sid -> (clen, lane)
        self._class_of: dict[int, int] = {}             # live sid -> clen
        self._host: dict[int, Params] = {}              # sid -> host cache
        # Slot ids are issued monotonically, so "released" vs "unknown" is a
        # generation check against _next_sid — no per-request tombstones, so
        # a long-running pool's bookkeeping stays O(live slots).
        self._next_sid = 0
        for n, clen in self.classes:
            template = lm.make_decode_cache(cfg, 1, clen, dtype)
            store = jax.tree.map(
                lambda x: jnp.zeros((n,) + x.shape, x.dtype), template)
            if mesh is not None:
                sh = shd.tree_shardings(store, shd.stacked_axes(self._axes),
                                        mesh, self.policy)
                store = jax.device_put(store, sh)
                self._store_shardings[clen] = sh
            self._stores[clen] = store
            self._lanes[clen] = list(range(n))
        # The spill target: host CPU memory.  On a CPU-only backend the
        # "transfer" is a same-device copy — the tiering logic (and its
        # bit-exactness) is identical, which is what the tests pin.
        try:
            self._host_device = jax.devices("cpu")[0]
        except RuntimeError:                             # no cpu backend
            self._host_device = None
        if mesh is None:
            leaf = jax.tree.leaves(self._stores[self.classes[0][1]])[0]
            self._device = getattr(leaf, "device", None) or next(iter(
                leaf.devices()))
        else:
            self._device = None          # fetch re-places by sharding tree
        # Observability: the historical `spill_stats` dict survives as a
        # live view over the metrics registry (same keys, same `+=`
        # spelling); per-transfer byte histograms ride alongside.  A pool
        # built by a `RequestScheduler` shares the scheduler's bundle, so
        # one registry carries the whole serving stack's metrics.
        self.obs = obs if obs is not None else Observability()
        self.spill_stats = self.obs.metrics.counter_view(
            "pool.", ["spills", "fetches", "bytes_to_host",
                      "bytes_to_device"])
        for n, clen in self.classes:
            g = self.obs.metrics.gauge(f"pool.device_bytes[{clen}]")
            g.set(pytree_nbytes(self._stores[clen]))
        # Shared-prefix tier (opt-in): refcounted pages / snapshots indexed
        # by token prefix, leased per slot, maintained once per step.
        from repro.serving.paging import PrefixCache
        self.prefix = PrefixCache(cfg, self.dtype, enabled=prefix_cache,
                                  page_size=prefix_page_size,
                                  max_pages=max_prefix_pages,
                                  device_pages=device_prefix_pages,
                                  obs=self.obs)

    # -- slot accounting ----------------------------------------------------

    @property
    def free_slots(self) -> int:
        """Free *device lanes* (host-resident slots hold no lane)."""
        return sum(len(f) for f in self._lanes.values())

    @property
    def host_resident(self) -> int:
        return len(self._host)

    @property
    def host_bytes(self) -> int:
        """Bytes currently parked in the host tier."""
        return sum(pytree_nbytes(c) for c in self._host.values())

    @property
    def device_bytes(self) -> int:
        """Bytes of the device-resident stacked stores (all lanes, global
        across the mesh — the whole distributed working set)."""
        return sum(pytree_nbytes(s) for s in self._stores.values())

    @property
    def device_bytes_per_device(self) -> int:
        """Bytes ONE chip holds of the stacked stores: sharded leaves count
        their local shard only — the number that must fit a single edge
        device's DRAM.  Equals `device_bytes` on a single device."""
        return sum(pytree_nbytes(s, per_device=True)
                   for s in self._stores.values())

    def slot_shardings(self, slot: int) -> Params:
        """NamedSharding tree for one slot's batch-1 cache pytree (what
        `fetch` restores a host-resident slot under)."""
        from repro.runtime import sharding as shd
        if self.mesh is None:
            raise ValueError("slot_shardings needs a mesh-backed pool")
        template = jax.eval_shape(
            lambda: lm.make_decode_cache(self.cfg, 1, self.slot_len(slot),
                                         self.dtype))
        return shd.tree_shardings(template, self._axes, self.mesh,
                                  self.policy)

    def fits(self, min_len: int) -> bool:
        """Could a request needing `min_len` cache positions EVER be placed?"""
        return min_len <= self.cache_len

    def slot_len(self, slot: int) -> int:
        """Cache length of a *live* (device- or host-resident) slot."""
        if slot not in self._class_of:
            raise ValueError(f"slot {slot} is not live ({self._where(slot)})")
        return self._class_of[slot]

    def locate(self, slot: int) -> tuple[int, int]:
        """(cache_len, lane) of a *device-resident* slot."""
        if slot not in self._lane_of:
            raise ValueError(f"slot {slot} is not device-resident "
                             f"({self._where(slot)})")
        return self._lane_of[slot]

    def residency(self, slot: int) -> str:
        """'device' | 'host' for a live slot; ValueError otherwise."""
        where = self._where(slot)
        if where not in ("device", "host"):
            raise ValueError(f"slot {slot} is not resident ({where})")
        return where

    def _where(self, slot: int) -> str:
        if slot in self._lane_of:
            return "device"
        if slot in self._host:
            return "host"
        return "released" if 0 <= slot < self._next_sid else "unknown"

    def has_free_lane(self, clen: int) -> bool:
        return bool(self._lanes[clen])

    def acquire(self, min_len: int = 0) -> int | None:
        """Smallest-class-first placement: the cheapest free lane that fits.

        Returns a fresh slot id bound to that lane, or None when every
        fitting class is busy (the caller may then `spill` a victim).
        """
        for _, clen in self.classes:
            if clen >= min_len and self._lanes[clen]:
                lane = self._lanes[clen].pop(0)
                sid = self._next_sid
                self._next_sid += 1
                self._lane_of[sid] = (clen, lane)
                self._class_of[sid] = clen
                return sid
        return None

    def release(self, slot: int) -> None:
        """Retire a slot: free its device lane, or drop its host copy.
        Any prefix-page leases the slot holds are dropped with it (this is
        the single refcount-decrement path — retire, cancel, and preempted
        cancel all route through here)."""
        if slot in self._lane_of:
            clen, lane = self._lane_of.pop(slot)
            self._lanes[clen].append(lane)
        elif slot in self._host:
            del self._host[slot]
        elif 0 <= slot < self._next_sid:
            raise ValueError(f"slot {slot} double-released")
        else:
            raise ValueError(f"release of unknown slot id {slot}")
        del self._class_of[slot]
        self.prefix.release(slot)

    # -- shared-prefix tier --------------------------------------------------

    def prefix_lookup(self, prompt, slot: int, *, chunk_size: int = 1
                      ) -> tuple[int, Params | None]:
        """Longest adoptable cached prefix of ``prompt`` for ``slot``:
        ``(n_tokens, warm_batch1_cache)`` or ``(0, None)``.  Leases the
        backing pages under the slot until `release`."""
        return self.prefix.lookup(prompt, self.slot_len(slot), slot,
                                  chunk_size=chunk_size)

    def prefix_register(self, prompt, cache: Params, slot: int) -> int:
        """Index a finished prefill's prefix for future adopters."""
        return self.prefix.register(prompt, cache, self.slot_len(slot))

    def prefix_maintain(self) -> None:
        """One prefix-tier bookkeeping cycle (LRU eviction, proactive host
        migration of cold unreferenced pages, gauge refresh)."""
        self.prefix.maintain()

    # -- host spill tier ----------------------------------------------------

    def spill(self, slot: int) -> None:
        """Move a slot's full cache pytree (KV/rings, recurrent state, RoPE
        angle memory, position) to host memory and free its device lane.

        The transfer is bit-exact (`jax.device_put` round trip); the freed
        lane's stale contents are overwritten by the next `write`.
        """
        if slot in self._host:
            raise ValueError(f"slot {slot} already spilled")
        clen, lane = self.locate(slot)
        cache = jax.tree.map(lambda x: x[lane], self._stores[clen])
        if self.mesh is not None:
            # Sharded slot: gather every leaf's shards into one host copy
            # (device_get is the cross-sharding-safe gather on every jax
            # this repo targets; device_put onto one device is not).
            host = jax.device_get(cache)
        else:
            host = jax.block_until_ready(
                jax.device_put(cache, self._host_device))
        del self._lane_of[slot]
        self._lanes[clen].append(lane)
        self._host[slot] = host
        nbytes = pytree_nbytes(host)
        self.spill_stats["spills"] += 1
        self.spill_stats["bytes_to_host"] += nbytes
        self.obs.metrics.histogram("pool.spill_bytes").record(nbytes)

    def fetch(self, slot: int) -> None:
        """Bind a spilled slot to a free lane in its class and restore its
        cache to the device, bit-exactly.  The caller checks
        ``has_free_lane(slot_len(slot))`` first (or handles the raise)."""
        if slot not in self._host:
            raise ValueError(f"slot {slot} is not spilled to host "
                             f"({self._where(slot)})")
        clen = self._class_of[slot]
        if not self._lanes[clen]:
            raise ValueError(f"no free lane in class {clen} to fetch "
                             f"slot {slot} into")
        host = self._host.pop(slot)
        lane = self._lanes[clen].pop(0)
        self._lane_of[slot] = (clen, lane)
        nbytes = pytree_nbytes(host)
        self.spill_stats["fetches"] += 1
        self.spill_stats["bytes_to_device"] += nbytes
        self.obs.metrics.histogram("pool.fetch_bytes").record(nbytes)
        if self.mesh is not None:
            # Re-place under the slot's cache shardings — the round trip
            # restores both the bits and the distribution.
            self.write(slot, jax.device_put(host, self.slot_shardings(slot)))
        else:
            self.write(slot, jax.device_put(host, self._device))

    # -- stacked stores -----------------------------------------------------

    def placement_mismatches(self) -> list[str]:
        """Array leaves of the stacked class stores whose on-device sharding
        departs from the pool's plan — the sharding audit's pool leg.
        Empty on a single-device pool (no plan to depart from)."""
        from repro.runtime import sharding as shd
        if self.mesh is None:
            return []
        bad: list[str] = []
        for clen, store in self._stores.items():
            for m in shd.sharding_mismatches(store,
                                             self._store_shardings[clen]):
                bad.append(f"class[{clen}]/{m}")
        return bad

    @property
    def store(self) -> Params:
        """Legacy single-class view of the stacked store."""
        if len(self.classes) != 1:
            raise ValueError("`store` is single-class; use get_store(clen)")
        return self._stores[self.classes[0][1]]

    def get_store(self, clen: int) -> Params:
        return self._stores[clen]

    def set_store(self, clen: int, store: Params) -> None:
        if self.mesh is not None:
            # Keep the stacked-store placement invariant regardless of what
            # sharding the producing computation's outputs resolved to (a
            # no-op when they already match).
            store = jax.device_put(store, self._store_shardings[clen])
        self._stores[clen] = store

    def write(self, slot: int, cache: Params) -> None:
        """Scatter one batch-1 cache (e.g. fresh from prefill) into a slot.

        The incoming pytree must match the slot class's structure and leaf
        shapes — a cache built for another class would silently corrupt the
        stacked store otherwise.
        """
        clen, lane = self.locate(slot)
        store = self._stores[clen]
        if jax.tree.structure(cache) != jax.tree.structure(store):
            raise ValueError(
                f"cache pytree structure does not match slot {slot}'s "
                f"class (cache_len {clen})")
        for p, c in zip(jax.tree.leaves(store), jax.tree.leaves(cache)):
            if tuple(p.shape[1:]) != tuple(jnp.shape(c)):
                raise ValueError(
                    f"cache leaf shape {tuple(jnp.shape(c))} does not match "
                    f"slot {slot}'s class shape {tuple(p.shape[1:])} "
                    f"(cache_len {clen})")
        new = jax.tree.map(
            lambda pool, c: pool.at[lane].set(
                jnp.asarray(c).astype(pool.dtype)), store, cache)
        if self.mesh is not None:
            new = jax.device_put(new, self._store_shardings[clen])
        self._stores[clen] = new


class RequestScheduler:
    """Admit-while-decoding serving loop around one `InferenceEngine`.

    ``step()`` performs one sequencer cycle: (1) advance the in-flight
    admission by at most one prefill chunk (starting the next queued request
    that fits a free slot class when idle), (2) advance every resident class
    one token through the vmapped MVM decode path, (3) retire slots that hit
    a stop token or their token budget.  ``run()`` drains the queue.

    ``on_token(uid, token)`` streams tokens as they are emitted;
    ``on_finish(finished)`` fires once per terminal `FinishedRequest`
    (retire, in-flight cancel) — the async front end's completion hook;
    ``cancel(uid)`` drops a queued request, aborts an in-flight admission, or
    retires an active slot (its partial output is returned with
    ``cancelled=True``).  ``clock`` injects the timebase for every latency
    stamp (virtual time in tests; monotonic by default).

    Admission order is FIFO with skip: a request whose smallest fitting class
    is momentarily full does not block later requests that fit elsewhere.

    ``host_spill=True`` adds priority preemption over the pool's host tier:
    when a queued request finds no free lane, the lowest-priority (tie:
    oldest-admitted) resident lane of *strictly lower* priority is spilled —
    its cache pytree moves to host memory (``CachePool.spill``) along with
    its sampling key, pending token, and speculative history — and parks on
    a resumable list.  Resume re-enters the vmapped decode through the
    pool's ``fetch`` + slot ``write``: no re-prefill, no new compiles, and
    greedy output is token-identical to an unpreempted run.

    Stochastic sampling stays per-request reproducible: each request draws
    from ``fold_in(key, uid)`` regardless of which slot it lands in or what
    else shares the batch.
    """

    def __init__(self, engine: InferenceEngine, *, n_slots: int = 4,
                 cache_len: int = 128,
                 classes: Sequence[tuple[int, int]] | None = None,
                 gen: GenerationConfig = GenerationConfig(),
                 key: jax.Array | None = None,
                 chunk_size: int = 32,
                 host_spill: bool = False,
                 cache_dtype=None,
                 on_token: Callable[[int, int], None] | None = None,
                 on_finish: Callable[[FinishedRequest], None] | None = None,
                 obs: Observability | None = None,
                 clock: Callable[[], float] | None = None,
                 prefix_cache: bool = False, prefix_page_size: int = 16,
                 max_prefix_pages: int | None = None,
                 device_prefix_pages: int | None = None):
        self.engine = engine
        self.gen = gen
        # The timebase for every latency stamp (submit, queue-wait, TTFT,
        # inter-token, request latency).  Injectable so the async front end
        # can run the whole serving loop on a virtual clock in tests; real
        # deployments keep the monotonic default.  Histogram records carry
        # `t=self._now()` so windowed percentiles share this timebase.
        self._now = clock if clock is not None else time.perf_counter
        # Each scheduler defaults to its OWN bundle (schedulers built over a
        # shared engine must not accumulate into one registry); pass the
        # engine's bundle explicitly (`obs=engine.obs`) to unify them, as
        # `repro.launch.serve` does.  The pool shares the scheduler's bundle.
        self.obs = obs if obs is not None else Observability()
        self._tr = self.obs.tracer
        # The pool-wide cache dtype policy: an explicit ``cache_dtype`` wins;
        # otherwise `gen.cache_format` (the request-level knob) selects the
        # quantized residency for every class; fp32 is the legacy default.
        # Chunked admission appends straight into the encoded layout
        # (`ChunkedPrefill(cache_dtype=pool.dtype)` below), so the stacked
        # stores never hold an fp copy.
        if cache_dtype is None:
            cache_dtype = gen.cache_format or jnp.float32
        self.pool = CachePool(engine.cfg, n_slots, cache_len, classes=classes,
                              dtype=cache_dtype,
                              mesh=getattr(engine, "mesh", None),
                              policy=getattr(engine, "policy", None),
                              obs=self.obs,
                              prefix_cache=prefix_cache,
                              prefix_page_size=prefix_page_size,
                              max_prefix_pages=max_prefix_pages,
                              device_prefix_pages=device_prefix_pages)
        self.base_key = key if key is not None else jax.random.key(0)
        self.chunk_size = chunk_size
        self.host_spill = host_spill
        self.on_token = on_token
        self.on_finish = on_finish
        self._class_nbytes: dict[int, int] = {}   # clen -> lane bytes memo

        self._queue: list[Request] = []
        self._admitting: dict | None = None      # the one in-flight prefill
        self._active: dict[int, dict] = {}       # sid -> per-request state
        self._preempted: list[dict] = []         # parked, host-resident
        self._seq = 0                            # admission order stamp
        self._finished: list[FinishedRequest] = []
        # Per class: current token per slot [N_c, 1, 1] (lane-major so vmap
        # sees [1, 1], the [B=1, T=1] shape forward_decode expects) and the
        # per-slot sampling keys (set on admit).
        self._tokens = {clen: jnp.zeros((n, 1, 1), jnp.int32)
                        for n, clen in self.pool.classes}
        self._keys = {clen: jax.random.split(self.base_key, n)
                      for n, clen in self.pool.classes}
        # The historical stats dict is now a live view over the metrics
        # registry: same keys, same `+=` spelling, and a `snapshot()` of the
        # registry sees every count under the `sched.` prefix.
        self.stats = self.obs.metrics.counter_view(
            "sched.", ["steps", "emitted", "prefill_chunks", "admitted",
                       "cancelled", "decode_stall_steps", "verify_steps",
                       "accepted_drafts", "preempted", "resumed"])
        self._t_submit: dict[int, float] = {}    # uid -> submit wall clock

        # Speculative decode: each slot is its own batch lane, so acceptance
        # depth is per-request (no lockstep min over the batch like the
        # engine's fused loop) and each lane carries its own token history
        # for the prompt-lookup drafter.
        self._spec = gen.speculative
        if self._spec is not None:
            if self._spec.drafter != "ngram":
                raise ValueError(
                    "RequestScheduler speculative decode supports the "
                    "model-free 'ngram' drafter (the MTP drafter needs "
                    "per-lane hidden state; use engine.generate)")
            w = engine.cfg.sliding_window
            if w and self._spec.k + 1 > w:
                raise ValueError(f"verify block k+1 ({self._spec.k + 1}) "
                                 f"must fit the sliding window ({w})")
            cap = self._spec.k + 1
            self._hist = {clen: jnp.zeros((n, clen + cap), jnp.int32)
                          for n, clen in self.pool.classes}
            self._hist_len = {clen: jnp.zeros((n,), jnp.int32)
                              for n, clen in self.pool.classes}

        # Same split-then-sample order as the engine's fused loop, so a
        # request's token stream is identical whether it runs here or through
        # engine.generate with key = fold_in(base_key, uid).
        def pool_step(params, tokens, store, keys):
            def one(tok, cache, key):
                logits, new_cache = lm.forward_decode(
                    params, tok, cache, engine.cfg, engine.hsa)
                key, sub = jax.random.split(key)
                nxt = sample(logits[0], gen.sampling, sub)
                return nxt, new_cache, key
            return jax.vmap(one)(tokens, store, keys)

        self._pool_step = jax.jit(pool_step)

        # Speculative sibling: per slot, draft k from the lane's history,
        # verify the k+1 block in ONE chunk-shaped dispatch against the
        # lane's resident cache, commit the accepted prefix (exact rollback)
        # and hand the Python side a variable-length token block.  Built on
        # the same `NgramDrafter`/`verify_block` core as the engine's fused
        # loop — each lane is a batch-1 instance, so the commit depth is the
        # lane's own acceptance (no lockstep min over the batch).
        def spec_pool_step(params, tokens, store, keys, hist, hlen):
            spec = self._spec
            k = spec.k
            drafter = spec_mod.NgramDrafter(k=k, m=spec.ngram)

            def one(tok, cache, key, h, hl):
                pend = tok[:, 0]                              # [1]
                dstate = {"hist": h[None, :], "len": hl}
                drafts = drafter.draft(params, dstate, pend)
                block = jnp.concatenate([pend[:, None], drafts], axis=1)
                key, sub = jax.random.split(key)
                cand, acc, hidden_all, ver = spec_mod.verify_block(
                    params, block, cache, sub, cfg=engine.cfg,
                    hsa=engine.hsa, gen=gen)
                a = acc[0]
                n_commit = a + 1
                new_cache = lm.commit_verified_cache(cache, ver, n_commit,
                                                     k + 1, engine.cfg)
                nxt = jax.lax.dynamic_index_in_dim(cand[0], a,
                                                   keepdims=False)
                dstate = drafter.observe(dstate, block, n_commit, hidden_all,
                                         nxt[None])
                return (block[0], n_commit, nxt[None, None], new_cache, key,
                        dstate["hist"][0], dstate["len"])
            return jax.vmap(one)(tokens, store, keys, hist, hlen)

        self._spec_pool_step = jax.jit(spec_pool_step)

    def compile_counts(self) -> dict[str, int]:
        """Compiled-signature count per scheduler dispatch (one per resident
        class is the contract; `repro.analysis` and the bench watch it)."""
        from repro.serving.engine import _jit_cache_size
        return {"pool_step": _jit_cache_size(self._pool_step),
                "spec_pool_step": _jit_cache_size(self._spec_pool_step)}

    # -- queue management ---------------------------------------------------

    def submit(self, request: Request, priority: int | None = None) -> None:
        """Enqueue; ``priority`` (or ``request.priority``) orders admission:
        higher priorities admit first, FIFO within a level.  A ``priority``
        argument is submission-scoped: the caller's Request is not mutated
        (the queue holds a copy carrying the effective priority).

        Sizing is validated *here*, at the submission boundary: a request
        whose ``max_new_tokens`` is invalid or that could never fit any pool
        class raises immediately, so the drain loop (`run`) can never throw
        mid-flight and abandon queued + resident work.
        """
        if priority is not None:
            request = dataclasses.replace(request, priority=priority)
        if request.max_new_tokens is not None and request.max_new_tokens < 1:
            raise ValueError(f"request {request.uid}: max_new_tokens must be "
                             f">= 1, got {request.max_new_tokens}")
        need, budget = self._request_need(request)
        if not self.pool.fits(need):
            # Decode writes cache positions s .. s+budget-1; past-capacity
            # positions would silently clamp onto the last linear-cache slot
            # (gqa_decode), so reject instead of corrupting attention.
            # Speculative verify blocks write up to k tokens past the last
            # budget position before rolling back — reserved in `need` too.
            raise CacheCapacityError(
                f"request {request.uid}: prompt ({len(request.prompt)}) + "
                f"max_new_tokens ({budget}) exceeds every pool class "
                f"(largest cache_len {self.pool.cache_len})")
        i = len(self._queue)
        while i > 0 and self._queue[i - 1].priority < request.priority:
            i -= 1
        self._queue.insert(i, request)
        self._t_submit[request.uid] = self._now()
        rt = request_track(request.uid)
        self._tr.begin("request", rt, prompt_len=len(request.prompt),
                       priority=request.priority)
        self._tr.begin("queued", rt)

    def _request_need(self, req: Request) -> tuple[int, int]:
        """(cache positions needed, effective token budget).  An explicit
        ``max_new_tokens`` always wins — ``0`` must not silently fall back
        to the scheduler default (it is rejected at submit)."""
        budget = (req.max_new_tokens if req.max_new_tokens is not None
                  else self.gen.max_new_tokens)
        need = len(req.prompt) + budget
        if self._spec is not None:
            need += self._spec.k
        return need, budget

    @property
    def pending(self) -> int:
        return (len(self._queue) + len(self._active) + len(self._preempted)
                + (1 if self._admitting is not None else 0))

    def cancel(self, uid: int) -> bool:
        """Drop a queued request / abort its admission / retire its slot —
        including a preempted slot parked in the host tier."""
        for i, req in enumerate(self._queue):
            if req.uid == uid:
                self._queue.pop(i)
                self.stats["cancelled"] += 1
                self._t_submit.pop(uid, None)
                rt = request_track(uid)
                self._tr.end("queued", rt)
                self._tr.instant("cancel", rt)
                self._tr.end("request", rt)
                return True
        if self._admitting is not None and self._admitting["req"].uid == uid:
            # Abort mid-chunked-prefill.  Clear `_admitting` *before* the
            # release and record a FinishedRequest like every other in-flight
            # cancel path: release drops the slot's lane and any prefix-page
            # leases the partial prefill adopted, and `_finish`'s on_finish
            # callback may re-enter the scheduler — it must observe the
            # admission already gone, and a front end awaiting this uid needs
            # the terminal record (previously this path recorded nothing and
            # `run()` silently forgot the request).
            adm = self._admitting
            self._admitting = None
            clen = self.pool.slot_len(adm["slot"])
            self.pool.release(adm["slot"])
            self.stats["cancelled"] += 1
            self._t_submit.pop(uid, None)
            rt = request_track(uid)
            self._tr.end("admit", rt)
            self._tr.instant("cancel", rt)
            self._tr.end("request", rt)
            self._finish(FinishedRequest(
                uid=uid, prompt_len=len(adm["req"].prompt), tokens=[],
                slot=adm["slot"], cache_len=clen, cancelled=True))
            return True
        for slot, st in self._active.items():
            if st["req"].uid == uid:
                self._retire(slot, cancelled=True)
                self.stats["cancelled"] += 1
                return True
        for entry in self._preempted:
            if entry["req"].uid == uid:
                self._preempted.remove(entry)
                clen = self.pool.slot_len(entry["slot"])
                self.pool.release(entry["slot"])   # drops the host copy
                self.stats["cancelled"] += 1
                rt = request_track(uid)
                self._tr.end("preempted", rt)
                self._tr.instant("cancel", rt)
                self._tr.end("request", rt)
                self._finish(FinishedRequest(
                    uid=uid, prompt_len=len(entry["req"].prompt),
                    tokens=entry["emitted"], slot=entry["slot"],
                    cache_len=clen, cancelled=True,
                    verify_steps=entry["verify_steps"],
                    accepted_drafts=entry["accepted_drafts"]))
                return True
        return False

    # -- the sequencer cycle ------------------------------------------------

    def _start_admission(self) -> None:
        """Pick the next admission: resume a parked (preempted) request or
        start the first queued request that fits a free slot class.

        Sizing was validated at `submit`, so this loop never throws under
        load — the drain loop cannot abandon queued + resident work.  Any
        failure after acquisition releases the slot (no slot leaks).

        With ``host_spill``, a queued request that finds no free lane may
        preempt the lowest-priority (tie: oldest-admitted) resident lane of
        strictly lower priority — `_preempt` spills it to the pool's host
        tier and parks it.  Parked requests resume ahead of queued arrivals
        at the same or lower priority; a strictly higher-priority arrival
        admits first (and may itself preempt).
        """
        best_queued = self._queue[0].priority if self._queue else None
        for entry in self._resume_order():
            if (best_queued is not None
                    and best_queued > entry["req"].priority):
                break              # the higher-priority arrival admits first
            if self._try_resume(entry):
                return
        for i, req in enumerate(self._queue):
            need, budget = self._request_need(req)
            slot = self.pool.acquire(need)
            if slot is None and self.host_spill:
                victim = self._pick_victim(req.priority, need)
                if victim is not None:
                    self._preempt(victim)
                    slot = self.pool.acquire(need)
            if slot is None:
                continue                 # fitting classes all busy: try next
            self._queue.pop(i)
            t_sub = self._t_submit.get(req.uid)
            if t_sub is not None:
                t_adm = self._now()
                self.obs.metrics.histogram("sched.queue_wait_s").record(
                    t_adm - t_sub, t=t_adm)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            try:
                # Shared-prefix adoption: walk the pool's prefix index and
                # start the chunked prefill at the longest cached prefix —
                # those tokens are never prefilled.  The assembled warm
                # cache is private to this slot (pages are copied in), so
                # handing it to the donating chunk step is safe; the pages
                # themselves stay leased until the slot releases.
                hit, warm = self.pool.prefix_lookup(
                    req.prompt, slot, chunk_size=self.chunk_size)
                prefill = self.engine.begin_chunked_prefill(
                    prompt, cache_len=self.pool.slot_len(slot),
                    chunk_size=self.chunk_size,
                    cache_dtype=self.pool.dtype,
                    initial_cache=warm, start_offset=hit)
            except Exception:
                self.pool.release(slot)
                raise
            rt = request_track(req.uid)
            self._tr.end("queued", rt)
            self._tr.begin("admit", rt,
                           cache_len=self.pool.slot_len(slot),
                           prefix_hit=hit)
            self._admitting = {"req": req, "slot": slot, "prefill": prefill,
                               "budget": budget}
            return
        # Nothing queued could start: resume any parked request that fits,
        # ignoring the priority gate above — it only *defers* resumes behind
        # admissible higher-priority arrivals, and must never deadlock the
        # drain loop when those arrivals cannot be placed yet.
        for entry in self._resume_order():
            if self._try_resume(entry):
                return

    # -- host-spill preemption ---------------------------------------------

    def _resume_order(self) -> list[dict]:
        """Parked requests in resume order: priority desc, admission asc."""
        return sorted(self._preempted,
                      key=lambda e: (-e["req"].priority, e["seq"]))

    def _slot_nbytes(self, clen: int) -> int:
        """Bytes one lane of class ``clen`` holds (memoized abstract-shape
        walk via `engine.cache_nbytes`) — the spill's transfer cost and the
        device memory a preemption frees."""
        n = self._class_nbytes.get(clen)
        if n is None:
            n = self.engine.cache_nbytes(clen, dtype=self.pool.dtype)
            self._class_nbytes[clen] = n
        return n

    def _pick_victim(self, priority: int, need: int) -> int | None:
        """Byte-aware preemption: among resident lanes of strictly lower
        priority whose class could hold ``need`` positions, pick the lowest
        priority first, then the lane *freeing the most device bytes* (the
        largest cache class — one spill should buy the most placement
        headroom per transfer), then the oldest admission."""
        best = None
        for slot, st in self._active.items():
            if st["req"].priority >= priority:
                continue
            if self.pool.slot_len(slot) < need:
                continue
            rank = (st["req"].priority,
                    -self._slot_nbytes(self.pool.slot_len(slot)), st["seq"])
            if best is None or rank < best[0]:
                best = (rank, slot)
        return None if best is None else best[1]

    def _preempt(self, slot: int) -> None:
        """Spill a resident lane to the host tier and park it, resumable
        bit-exactly: cache pytree (via `CachePool.spill`), sampling key,
        pending token, and — on the speculative path — the lane's draft
        history all survive the round trip."""
        st = self._active.pop(slot)
        clen, lane = self.pool.locate(slot)
        entry = {"req": st["req"], "slot": slot, "seq": st["seq"],
                 "budget": st["budget"], "emitted": st["emitted"],
                 "verify_steps": st["verify_steps"],
                 "accepted_drafts": st["accepted_drafts"],
                 "t_submit": st.get("t_submit"), "t_last": st.get("t_last"),
                 "token": int(self._tokens[clen][lane, 0, 0]),
                 "key": self._keys[clen][lane]}
        if self._spec is not None:
            entry["hist"] = jax.device_get(self._hist[clen][lane])
            entry["hist_len"] = int(self._hist_len[clen][lane])
        self.pool.spill(slot)
        self._preempted.append(entry)
        self.stats["preempted"] += 1
        rt = request_track(st["req"].uid)
        self._tr.end("decode", rt)
        self._tr.instant("preempt", rt, cache_len=clen)
        self._tr.begin("preempted", rt)

    def _try_resume(self, entry: dict) -> bool:
        """Fetch a parked request's cache back into a free lane of its class
        and rejoin the vmapped decode — no re-prefill, no new compiles (the
        slot `write` is the same scatter admission uses)."""
        slot = entry["slot"]
        if not self.pool.has_free_lane(self.pool.slot_len(slot)):
            return False
        self.pool.fetch(slot)
        clen, lane = self.pool.locate(slot)
        self._tokens[clen] = self._tokens[clen].at[lane, 0, 0].set(
            entry["token"])
        self._keys[clen] = self._keys[clen].at[lane].set(entry["key"])
        if self._spec is not None:
            self._hist[clen] = self._hist[clen].at[lane].set(
                jnp.asarray(entry["hist"]))
            self._hist_len[clen] = self._hist_len[clen].at[lane].set(
                entry["hist_len"])
        self._active[slot] = {"req": entry["req"], "emitted": entry["emitted"],
                              "budget": entry["budget"], "seq": entry["seq"],
                              "verify_steps": entry["verify_steps"],
                              "accepted_drafts": entry["accepted_drafts"],
                              "t_submit": entry.get("t_submit"),
                              "t_last": entry.get("t_last")}
        self._preempted.remove(entry)
        self.stats["resumed"] += 1
        rt = request_track(entry["req"].uid)
        self._tr.end("preempted", rt)
        self._tr.instant("resume", rt, cache_len=clen)
        self._tr.begin("decode", rt)
        return True

    def _admit(self) -> None:
        """MMM phase: advance the in-flight admission by at most one chunk."""
        if self._admitting is None:
            self._start_admission()
        if self._admitting is None:
            return
        adm = self._admitting
        rt = request_track(adm["req"].uid)
        now = self._now()
        if "t_chunk" in adm:
            # Pacing: the gap between successive chunk dispatches is the
            # decode latency the admission is overlapping with.
            self.obs.metrics.histogram(
                "sched.prefill_chunk_interval_s").record(
                    now - adm["t_chunk"], t=now)
        adm["t_chunk"] = now
        with self._tr.span("prefill_chunk", rt):
            logits = adm["prefill"].advance()
        self.stats["prefill_chunks"] += 1
        if not adm["prefill"].done:
            return
        req, slot = adm["req"], adm["slot"]
        self.pool.write(slot, adm["prefill"].cache)
        # The finished prompt's warm cache covers [0, len(prompt)) — index
        # it so later admissions sharing this prefix skip their prefill.
        # (`write` does not donate, so the cache is still whole here.)
        self.pool.prefix_register(req.prompt, adm["prefill"].cache, slot)
        key = jax.random.fold_in(self.base_key, req.uid)
        key, sub = jax.random.split(key)
        tok = sample(logits[0], self.gen.sampling, sub)
        clen, local = self.pool.locate(slot)
        self._tokens[clen] = self._tokens[clen].at[local, 0, 0].set(tok)
        self._keys[clen] = self._keys[clen].at[local].set(key)
        if self._spec is not None:
            prompt = jnp.asarray(req.prompt, jnp.int32)
            row = jnp.zeros((self._hist[clen].shape[1],),
                            jnp.int32).at[:prompt.shape[0]].set(prompt)
            self._hist[clen] = self._hist[clen].at[local].set(row)
            self._hist_len[clen] = self._hist_len[clen].at[local].set(
                prompt.shape[0])
        self._active[slot] = {"req": req, "emitted": [],
                              "budget": adm["budget"], "seq": self._seq,
                              "verify_steps": 0, "accepted_drafts": 0,
                              "t_submit": self._t_submit.pop(req.uid, None),
                              "t_last": None}
        self._seq += 1
        self._admitting = None
        self.stats["admitted"] += 1
        self._tr.end("admit", rt)
        self._tr.begin("decode", rt)

    def _finish(self, fr: FinishedRequest) -> None:
        """The single completion sink: every `FinishedRequest` — retired,
        cancelled mid-admission, or cancelled while preempted — lands here,
        so `on_finish` observers (the async front end resolving a request's
        token stream) see every terminal state exactly once.  Scheduler
        bookkeeping is already consistent when the callback fires: the
        callback may re-enter `cancel`/`submit`/`pending` safely."""
        self._finished.append(fr)
        if self.on_finish is not None:
            self.on_finish(fr)

    def _retire(self, slot: int, cancelled: bool = False) -> None:
        st = self._active.pop(slot)
        clen = self.pool.slot_len(slot)
        self.pool.release(slot)
        t_sub = st.get("t_submit")
        if t_sub is not None:
            t_fin = self._now()
            self.obs.metrics.histogram("sched.request_latency_s").record(
                t_fin - t_sub, t=t_fin)
        rt = request_track(st["req"].uid)
        self._tr.end("decode", rt)
        self._tr.instant("finish", rt, tokens=len(st["emitted"]),
                         cancelled=cancelled)
        self._tr.end("request", rt)
        self._finish(FinishedRequest(
            uid=st["req"].uid, prompt_len=len(st["req"].prompt),
            tokens=st["emitted"], slot=slot,
            cache_len=clen, cancelled=cancelled,
            verify_steps=st["verify_steps"],
            accepted_drafts=st["accepted_drafts"]))

    def step(self) -> int:
        """One admit+decode cycle; returns the number of tokens emitted."""
        self._admit()
        self.pool.prefix_maintain()
        self.stats["steps"] += 1
        # Occupancy gauges + trace counter series, sampled once per cycle at
        # the step boundary (no device access: queue/active/preempted are
        # python containers, host_bytes sums host-resident leaves).
        m = self.obs.metrics
        m.gauge("sched.queue_depth").set(len(self._queue))
        m.gauge("sched.active").set(len(self._active))
        m.gauge("sched.preempted_depth").set(len(self._preempted))
        m.gauge("pool.host_bytes").set(self.pool.host_bytes)
        if self._tr.enabled:
            self._tr.counter("queue_depth", len(self._queue))
            self._tr.counter("active", len(self._active))
            self._tr.counter("host_bytes", self.pool.host_bytes)
        if not self._active:
            if self._admitting is not None:
                self.stats["decode_stall_steps"] += 1
            return 0

        # Snapshot this step's token block per active slot *before* decoding:
        # like the fused loop, the tokens emitted at step i were sampled from
        # the previous step's (or prefill's) logits.  One vmapped dispatch
        # per resident class.  The per-token path emits a 1-token block; the
        # speculative path a 1..k+1-token block per slot.
        emitted = 0
        active_classes = sorted({self.pool.locate(s)[0] for s in self._active})
        stepped: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        for clen in active_classes:
            toks = self._tokens[clen]
            if self._spec is not None:
                with self.obs.annotation("sched.spec_pool_step"):
                    (blocks, counts, nxt, new_store, self._keys[clen],
                     self._hist[clen], self._hist_len[clen]) = \
                        self._spec_pool_step(
                            self.engine.params, toks,
                            self.pool.get_store(clen),
                            self._keys[clen], self._hist[clen],
                            self._hist_len[clen])
                stepped[clen] = (np.asarray(jax.device_get(blocks)),
                                 np.asarray(jax.device_get(counts)))
                self._tokens[clen] = nxt
            else:
                snap = np.asarray(jax.device_get(toks[:, 0, 0]))
                stepped[clen] = (snap[:, None],
                                 np.ones(snap.shape[0], np.int64))
                with self.obs.annotation("sched.pool_step"):
                    nxt, new_store, self._keys[clen] = self._pool_step(
                        self.engine.params, toks, self.pool.get_store(clen),
                        self._keys[clen])
                self._tokens[clen] = nxt[:, None, None]
            self.pool.set_store(clen, new_store)

        now = self._now()
        for slot in list(self._active):
            st = self._active.get(slot)
            if st is None:           # retired by an on_token cancel mid-loop
                continue
            clen, local = self.pool.locate(slot)
            blocks, counts = stepped[clen]
            block = [int(t) for t in blocks[local][:int(counts[local])]]
            if self._spec is not None:
                st["verify_steps"] += 1
                st["accepted_drafts"] += len(block) - 1
                self.stats["verify_steps"] += 1
                self.stats["accepted_drafts"] += len(block) - 1
                m.histogram("sched.tokens_per_verify_step").record(len(block))
            if block:
                # SLO latencies, stamped at the drain boundary (the tokens
                # were already gathered above; no extra sync).  TTFT covers
                # submit → first drained token; inter-token spreads the gap
                # since the previous drain over this drain's block (the first
                # block's same-drain extras carry no previous gap to spread).
                if st.get("t_last") is None:
                    if st.get("t_submit") is not None:
                        m.histogram("sched.ttft_s").record(
                            now - st["t_submit"], t=now)
                    self._tr.instant("first_token",
                                     request_track(st["req"].uid))
                else:
                    dt = (now - st["t_last"]) / len(block)
                    for _ in block:
                        m.histogram("sched.inter_token_s").record(dt, t=now)
                st["t_last"] = now
            for tok in block:
                st["emitted"].append(tok)
                emitted += 1
                if self.on_token is not None:
                    # The callback may cancel() any request — including this
                    # one, which retires the slot before the stop/budget
                    # check below.
                    self.on_token(st["req"].uid, tok)
                if slot not in self._active:
                    break
                if (tok in self.gen.stop_tokens
                        or len(st["emitted"]) >= st["budget"]):
                    # Committed-but-over-budget/post-stop block tokens are
                    # dropped; the slot retires either way.
                    self._retire(slot)
                    break
        self.stats["emitted"] += emitted
        return emitted

    def run(self) -> dict[int, FinishedRequest]:
        """Drain queue + active slots; returns results keyed by request uid."""
        while self.pending:
            self.step()
        return {f.uid: f for f in self._finished}
