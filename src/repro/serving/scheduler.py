"""Continuous-batching request scheduler over a paged, slot-based cache pool.

Mirrors the HSA sequencer (paper Sec. IV): the engine's *prefill* path (MMM
dataflow) admits new requests into free cache slots while the resident slots
advance through the *decode* path (MVM dataflow) one token per step.  Two
refinements over the original slot pool make the admission path match the
paper's LISO scenario (750-token prompts entering a busy decode batch):

  * **Chunk-granular admission** — `_admit` advances at most ONE prefill
    chunk per `step()` (`InferenceEngine.begin_chunked_prefill`), so a long
    prompt overlaps ~n_chunks decode cycles instead of stalling every lane
    for one monolithic MMM pass, and the ladder-sized chunks keep the number
    of compiled prefill shapes logarithmic in prompt length.

  * **Paged pool** — `CachePool` holds *classes* of slots (small/medium/
    large cache lengths over the same stacked-pytree layout) instead of one
    global `cache_len`; admission picks the smallest class that fits
    ``prompt + budget``, so short requests stop paying the longest request's
    KV memory.

`CachePool` builds each class over `lm.make_decode_cache`: every per-model
cache kind (KV rings, MXINT4-decoded MoE experts, Mamba conv state, RetNet's
O(1) retention state, the online RoPE angle memory, the per-sequence
position) is just a pytree leaf with a leading ``[n_slots]`` axis.  The
decode step vmaps `lm.forward_decode` over that axis — one dispatch per
*class* with at least one resident request (free lanes still compute garbage
that is never read: one compiled shape per class, no re-trace as occupancy
fluctuates, the same trade the fixed-size PE array makes in silicon).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import speculative as spec_mod
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import GenerationConfig, sample

Params = dict[str, Any]


@dataclasses.dataclass
class Request:
    """One generation request; `max_new_tokens` overrides the scheduler's.

    ``priority``: higher admits first; FIFO among equal priorities (0 is the
    default class, negative deprioritizes).
    """

    uid: int
    prompt: Any                          # int sequence [S_in]
    max_new_tokens: int | None = None
    priority: int = 0


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt_len: int
    tokens: list[int]                    # emitted tokens incl. any stop token
    slot: int                            # pool slot it ran in (for tests/stats)
    cancelled: bool = False              # retired early via `cancel(uid)`
    # Speculative-decode stats (zero on the per-token path):
    verify_steps: int = 0                # verify dispatches while resident
    accepted_drafts: int = 0             # drafted tokens verification accepted

    @property
    def tokens_per_step(self) -> float:
        if not self.verify_steps:
            return 1.0
        return 1.0 + self.accepted_drafts / self.verify_steps


class CachePool:
    """Paged decode-cache pool: slot *classes* of increasing cache length.

    ``classes`` is a sequence of ``(n_slots, cache_len)`` pairs; the legacy
    single-class form ``CachePool(cfg, n_slots, cache_len)`` still works.
    Slots carry global ids (stable across classes); each class is one stacked
    pytree (``[n_slots_c, ...]`` per leaf) over `lm.make_decode_cache`
    (batch=1 per slot), so the slot layout is identical for every cache kind
    the model zoo produces.  Prefilled batch-1 caches are scattered into a
    slot with ``write``; the scheduler advances each class in one vmapped
    decode step.
    """

    def __init__(self, cfg, n_slots: int | None = None,
                 cache_len: int | None = None, *,
                 classes: Sequence[tuple[int, int]] | None = None,
                 dtype=jnp.float32):
        if classes is None:
            classes = [(n_slots if n_slots is not None else 4,
                        cache_len if cache_len is not None else 128)]
        classes = sorted(classes, key=lambda c: c[1])
        if not classes or any(n < 1 or length < 1 for n, length in classes):
            raise ValueError(f"bad cache classes: {classes}")
        if len({length for _, length in classes}) != len(classes):
            raise ValueError(f"duplicate class cache_len: {classes}")
        self.cfg = cfg
        self.classes = [(int(n), int(length)) for n, length in classes]
        self.n_slots = sum(n for n, _ in self.classes)
        self.cache_len = self.classes[-1][1]      # largest class (compat)
        self.dtype = dtype

        self._stores: dict[int, Params] = {}
        self._locate: dict[int, tuple[int, int]] = {}   # gid -> (clen, local)
        self._free: dict[int, list[int]] = {}           # clen -> free gids
        gid = 0
        for n, clen in self.classes:
            template = lm.make_decode_cache(cfg, 1, clen, dtype)
            self._stores[clen] = jax.tree.map(
                lambda x: jnp.zeros((n,) + x.shape, x.dtype), template)
            self._free[clen] = []
            for local in range(n):
                self._locate[gid] = (clen, local)
                self._free[clen].append(gid)
                gid += 1

    # -- slot accounting ----------------------------------------------------

    @property
    def free_slots(self) -> int:
        return sum(len(f) for f in self._free.values())

    def fits(self, min_len: int) -> bool:
        """Could a request needing `min_len` cache positions EVER be placed?"""
        return min_len <= self.cache_len

    def slot_len(self, slot: int) -> int:
        return self._locate[slot][0]

    def locate(self, slot: int) -> tuple[int, int]:
        return self._locate[slot]

    def acquire(self, min_len: int = 0) -> int | None:
        """Smallest-class-first placement: the cheapest slot that fits."""
        for _, clen in self.classes:
            if clen >= min_len and self._free[clen]:
                return self._free[clen].pop(0)
        return None

    def release(self, slot: int) -> None:
        clen, _ = self._locate[slot]
        assert slot not in self._free[clen], slot
        self._free[clen].append(slot)

    # -- stacked stores -----------------------------------------------------

    @property
    def store(self) -> Params:
        """Legacy single-class view of the stacked store."""
        if len(self.classes) != 1:
            raise ValueError("`store` is single-class; use get_store(clen)")
        return self._stores[self.classes[0][1]]

    def get_store(self, clen: int) -> Params:
        return self._stores[clen]

    def set_store(self, clen: int, store: Params) -> None:
        self._stores[clen] = store

    def write(self, slot: int, cache: Params) -> None:
        """Scatter one batch-1 cache (e.g. fresh from prefill) into a slot."""
        clen, local = self._locate[slot]
        self._stores[clen] = jax.tree.map(
            lambda pool, c: pool.at[local].set(c.astype(pool.dtype)),
            self._stores[clen], cache)


class RequestScheduler:
    """Admit-while-decoding serving loop around one `InferenceEngine`.

    ``step()`` performs one sequencer cycle: (1) advance the in-flight
    admission by at most one prefill chunk (starting the next queued request
    that fits a free slot class when idle), (2) advance every resident class
    one token through the vmapped MVM decode path, (3) retire slots that hit
    a stop token or their token budget.  ``run()`` drains the queue.

    ``on_token(uid, token)`` streams tokens as they are emitted;
    ``cancel(uid)`` drops a queued request, aborts an in-flight admission, or
    retires an active slot (its partial output is returned with
    ``cancelled=True``).

    Admission order is FIFO with skip: a request whose smallest fitting class
    is momentarily full does not block later requests that fit elsewhere.

    Stochastic sampling stays per-request reproducible: each request draws
    from ``fold_in(key, uid)`` regardless of which slot it lands in or what
    else shares the batch.
    """

    def __init__(self, engine: InferenceEngine, *, n_slots: int = 4,
                 cache_len: int = 128,
                 classes: Sequence[tuple[int, int]] | None = None,
                 gen: GenerationConfig = GenerationConfig(),
                 key: jax.Array | None = None,
                 chunk_size: int = 32,
                 on_token: Callable[[int, int], None] | None = None):
        self.engine = engine
        self.gen = gen
        self.pool = CachePool(engine.cfg, n_slots, cache_len, classes=classes)
        self.base_key = key if key is not None else jax.random.key(0)
        self.chunk_size = chunk_size
        self.on_token = on_token

        self._queue: list[Request] = []
        self._admitting: dict | None = None      # the one in-flight prefill
        self._active: dict[int, dict] = {}       # gid -> per-request state
        self._finished: list[FinishedRequest] = []
        # Per class: current token per slot [N_c, 1, 1] (lane-major so vmap
        # sees [1, 1], the [B=1, T=1] shape forward_decode expects) and the
        # per-slot sampling keys (set on admit).
        self._tokens = {clen: jnp.zeros((n, 1, 1), jnp.int32)
                        for n, clen in self.pool.classes}
        self._keys = {clen: jax.random.split(self.base_key, n)
                      for n, clen in self.pool.classes}
        self.stats = {"steps": 0, "emitted": 0, "prefill_chunks": 0,
                      "admitted": 0, "cancelled": 0, "decode_stall_steps": 0,
                      "verify_steps": 0, "accepted_drafts": 0}

        # Speculative decode: each slot is its own batch lane, so acceptance
        # depth is per-request (no lockstep min over the batch like the
        # engine's fused loop) and each lane carries its own token history
        # for the prompt-lookup drafter.
        self._spec = gen.speculative
        if self._spec is not None:
            if self._spec.drafter != "ngram":
                raise ValueError(
                    "RequestScheduler speculative decode supports the "
                    "model-free 'ngram' drafter (the MTP drafter needs "
                    "per-lane hidden state; use engine.generate)")
            w = engine.cfg.sliding_window
            if w and self._spec.k + 1 > w:
                raise ValueError(f"verify block k+1 ({self._spec.k + 1}) "
                                 f"must fit the sliding window ({w})")
            cap = self._spec.k + 1
            self._hist = {clen: jnp.zeros((n, clen + cap), jnp.int32)
                          for n, clen in self.pool.classes}
            self._hist_len = {clen: jnp.zeros((n,), jnp.int32)
                              for n, clen in self.pool.classes}

        # Same split-then-sample order as the engine's fused loop, so a
        # request's token stream is identical whether it runs here or through
        # engine.generate with key = fold_in(base_key, uid).
        def pool_step(params, tokens, store, keys):
            def one(tok, cache, key):
                logits, new_cache = lm.forward_decode(
                    params, tok, cache, engine.cfg, engine.hsa)
                key, sub = jax.random.split(key)
                nxt = sample(logits[0], gen.sampling, sub)
                return nxt, new_cache, key
            return jax.vmap(one)(tokens, store, keys)

        self._pool_step = jax.jit(pool_step)

        # Speculative sibling: per slot, draft k from the lane's history,
        # verify the k+1 block in ONE chunk-shaped dispatch against the
        # lane's resident cache, commit the accepted prefix (exact rollback)
        # and hand the Python side a variable-length token block.  Built on
        # the same `NgramDrafter`/`verify_block` core as the engine's fused
        # loop — each lane is a batch-1 instance, so the commit depth is the
        # lane's own acceptance (no lockstep min over the batch).
        def spec_pool_step(params, tokens, store, keys, hist, hlen):
            spec = self._spec
            k = spec.k
            drafter = spec_mod.NgramDrafter(k=k, m=spec.ngram)

            def one(tok, cache, key, h, hl):
                pend = tok[:, 0]                              # [1]
                dstate = {"hist": h[None, :], "len": hl}
                drafts = drafter.draft(params, dstate, pend)
                block = jnp.concatenate([pend[:, None], drafts], axis=1)
                key, sub = jax.random.split(key)
                cand, acc, hidden_all, ver = spec_mod.verify_block(
                    params, block, cache, sub, cfg=engine.cfg,
                    hsa=engine.hsa, gen=gen)
                a = acc[0]
                n_commit = a + 1
                new_cache = lm.commit_verified_cache(cache, ver, n_commit,
                                                     k + 1, engine.cfg)
                nxt = jax.lax.dynamic_index_in_dim(cand[0], a,
                                                   keepdims=False)
                dstate = drafter.observe(dstate, block, n_commit, hidden_all,
                                         nxt[None])
                return (block[0], n_commit, nxt[None, None], new_cache, key,
                        dstate["hist"][0], dstate["len"])
            return jax.vmap(one)(tokens, store, keys, hist, hlen)

        self._spec_pool_step = jax.jit(spec_pool_step)

    # -- queue management ---------------------------------------------------

    def submit(self, request: Request, priority: int | None = None) -> None:
        """Enqueue; ``priority`` (or ``request.priority``) orders admission:
        higher priorities admit first, FIFO within a level.  A ``priority``
        argument is submission-scoped: the caller's Request is not mutated
        (the queue holds a copy carrying the effective priority)."""
        if priority is not None:
            request = dataclasses.replace(request, priority=priority)
        i = len(self._queue)
        while i > 0 and self._queue[i - 1].priority < request.priority:
            i -= 1
        self._queue.insert(i, request)

    @property
    def pending(self) -> int:
        return (len(self._queue) + len(self._active)
                + (1 if self._admitting is not None else 0))

    def cancel(self, uid: int) -> bool:
        """Drop a queued request / abort its admission / retire its slot."""
        for i, req in enumerate(self._queue):
            if req.uid == uid:
                self._queue.pop(i)
                self.stats["cancelled"] += 1
                return True
        if self._admitting is not None and self._admitting["req"].uid == uid:
            self.pool.release(self._admitting["slot"])
            self._admitting = None
            self.stats["cancelled"] += 1
            return True
        for slot, st in self._active.items():
            if st["req"].uid == uid:
                self._retire(slot, cancelled=True)
                self.stats["cancelled"] += 1
                return True
        return False

    # -- the sequencer cycle ------------------------------------------------

    def _start_admission(self) -> None:
        """Pick the first queued request that fits a free slot class.

        The capacity check happens *before* `pool.acquire`, and any failure
        after acquisition releases the slot — admission can never leak slots.
        A request that can never fit raises ValueError (a sizing bug at the
        call site, not load); the offender is dropped first, so resident
        lanes and the rest of the queue survive — `run()` again resumes.
        """
        for i, req in enumerate(self._queue):
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            budget = req.max_new_tokens or self.gen.max_new_tokens
            # Decode writes cache positions s .. s+budget-1; past-capacity
            # positions would silently clamp onto the last linear-cache slot
            # (gqa_decode), so reject instead of corrupting attention.
            # Speculative verify blocks write up to k tokens past the last
            # budget position before rolling back — reserve them too.
            need = prompt.shape[1] + budget
            if self._spec is not None:
                need += self._spec.k
            if not self.pool.fits(need):
                self._queue.pop(i)
                raise ValueError(
                    f"request {req.uid}: prompt ({prompt.shape[1]}) + "
                    f"max_new_tokens ({budget}) exceeds every pool class "
                    f"(largest cache_len {self.pool.cache_len})")
            slot = self.pool.acquire(need)
            if slot is None:
                continue                 # fitting classes all busy: try next
            self._queue.pop(i)
            try:
                prefill = self.engine.begin_chunked_prefill(
                    prompt, cache_len=self.pool.slot_len(slot),
                    chunk_size=self.chunk_size,
                    cache_dtype=self.pool.dtype)
            except Exception:
                self.pool.release(slot)
                raise
            self._admitting = {"req": req, "slot": slot, "prefill": prefill,
                               "budget": budget}
            return

    def _admit(self) -> None:
        """MMM phase: advance the in-flight admission by at most one chunk."""
        if self._admitting is None:
            self._start_admission()
        if self._admitting is None:
            return
        adm = self._admitting
        logits = adm["prefill"].advance()
        self.stats["prefill_chunks"] += 1
        if not adm["prefill"].done:
            return
        req, slot = adm["req"], adm["slot"]
        self.pool.write(slot, adm["prefill"].cache)
        key = jax.random.fold_in(self.base_key, req.uid)
        key, sub = jax.random.split(key)
        tok = sample(logits[0], self.gen.sampling, sub)
        clen, local = self.pool.locate(slot)
        self._tokens[clen] = self._tokens[clen].at[local, 0, 0].set(tok)
        self._keys[clen] = self._keys[clen].at[local].set(key)
        if self._spec is not None:
            prompt = jnp.asarray(req.prompt, jnp.int32)
            row = jnp.zeros((self._hist[clen].shape[1],),
                            jnp.int32).at[:prompt.shape[0]].set(prompt)
            self._hist[clen] = self._hist[clen].at[local].set(row)
            self._hist_len[clen] = self._hist_len[clen].at[local].set(
                prompt.shape[0])
        self._active[slot] = {"req": req, "emitted": [],
                              "budget": adm["budget"],
                              "verify_steps": 0, "accepted_drafts": 0}
        self._admitting = None
        self.stats["admitted"] += 1

    def _retire(self, slot: int, cancelled: bool = False) -> None:
        st = self._active.pop(slot)
        self._finished.append(FinishedRequest(
            uid=st["req"].uid, prompt_len=len(st["req"].prompt),
            tokens=st["emitted"], slot=slot, cancelled=cancelled,
            verify_steps=st["verify_steps"],
            accepted_drafts=st["accepted_drafts"]))
        self.pool.release(slot)

    def step(self) -> int:
        """One admit+decode cycle; returns the number of tokens emitted."""
        self._admit()
        self.stats["steps"] += 1
        if not self._active:
            if self._admitting is not None:
                self.stats["decode_stall_steps"] += 1
            return 0

        # Snapshot this step's token block per active slot *before* decoding:
        # like the fused loop, the tokens emitted at step i were sampled from
        # the previous step's (or prefill's) logits.  One vmapped dispatch
        # per resident class.  The per-token path emits a 1-token block; the
        # speculative path a 1..k+1-token block per slot.
        emitted = 0
        active_classes = sorted({self.pool.locate(s)[0] for s in self._active})
        stepped: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        for clen in active_classes:
            toks = self._tokens[clen]
            if self._spec is not None:
                (blocks, counts, nxt, new_store, self._keys[clen],
                 self._hist[clen], self._hist_len[clen]) = \
                    self._spec_pool_step(
                        self.engine.params, toks, self.pool.get_store(clen),
                        self._keys[clen], self._hist[clen],
                        self._hist_len[clen])
                stepped[clen] = (np.asarray(jax.device_get(blocks)),
                                 np.asarray(jax.device_get(counts)))
                self._tokens[clen] = nxt
            else:
                snap = np.asarray(jax.device_get(toks[:, 0, 0]))
                stepped[clen] = (snap[:, None],
                                 np.ones(snap.shape[0], np.int64))
                nxt, new_store, self._keys[clen] = self._pool_step(
                    self.engine.params, toks, self.pool.get_store(clen),
                    self._keys[clen])
                self._tokens[clen] = nxt[:, None, None]
            self.pool.set_store(clen, new_store)

        for slot in list(self._active):
            st = self._active.get(slot)
            if st is None:           # retired by an on_token cancel mid-loop
                continue
            clen, local = self.pool.locate(slot)
            blocks, counts = stepped[clen]
            block = [int(t) for t in blocks[local][:int(counts[local])]]
            if self._spec is not None:
                st["verify_steps"] += 1
                st["accepted_drafts"] += len(block) - 1
                self.stats["verify_steps"] += 1
                self.stats["accepted_drafts"] += len(block) - 1
            for tok in block:
                st["emitted"].append(tok)
                emitted += 1
                if self.on_token is not None:
                    # The callback may cancel() any request — including this
                    # one, which retires the slot before the stop/budget
                    # check below.
                    self.on_token(st["req"].uid, tok)
                if slot not in self._active:
                    break
                if (tok in self.gen.stop_tokens
                        or len(st["emitted"]) >= st["budget"]):
                    # Committed-but-over-budget/post-stop block tokens are
                    # dropped; the slot retires either way.
                    self._retire(slot)
                    break
        self.stats["emitted"] += emitted
        return emitted

    def run(self) -> dict[int, FinishedRequest]:
        """Drain queue + active slots; returns results keyed by request uid."""
        while self.pending:
            self.step()
        return {f.uid: f for f in self._finished}
