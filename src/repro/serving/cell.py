"""Typed serving-cell description: shardings + shapes for one deployment.

`ServeCell` replaces the untyped dict `runtime/serve_step.build_serve` used
to return.  It is the *planning* artifact for a sharded deployment (dry-run
lowering, multi-chip serving); the in-process path is `InferenceEngine`.
`runtime/serve_step.py` re-exports everything here, so existing imports keep
working.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.hsa import HSAConfig, HSAEngine
from repro.models import deploy, lm
from repro.models.config import InputShape, ModelConfig

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ServeCell:
    """Everything needed to jit one serving cell (prefill or decode kind).

    ``cell["name"]`` access is kept as a deprecated alias for the dict this
    used to be; new code should use the attributes.
    """

    engine: HSAEngine
    prefill: Callable[[Params, Params], tuple[jax.Array, Params]]
    decode: Callable[[Params, jax.Array, Params], tuple[jax.Array, Params]]
    param_shapes: Params
    param_axes: Params
    param_shardings: Params
    cache_shapes: Params
    cache_shardings: Params
    policy: Any
    # Chunked-prefill step (params, batch, cache) -> (logits, cache): the
    # sharded twin of `InferenceEngine.begin_chunked_prefill`; cache
    # shardings are the decode ones (the chunk path is cache-resident).
    prefill_chunk: Callable[[Params, Params, Params],
                            tuple[jax.Array, Params]] | None = None
    # Speculative verify step (params, batch, cache) -> (all-position logits,
    # hidden, cache+state-snapshots): scores a k+1-token draft block in one
    # MMM dispatch (serving/speculative.py drives it in-process; this is the
    # sharded twin for multi-chip lowering).
    verify_chunk: Callable[[Params, Params, Params],
                           tuple[jax.Array, jax.Array, Params]] | None = None
    # The planning inputs, retained so the in-process `InferenceEngine` can
    # re-resolve shardings for shapes other than the planning shape (the
    # dataclass's `cache_shardings` is for `cache_shapes` exactly).
    cfg: ModelConfig | None = None
    mesh: Mesh | None = None

    def __getitem__(self, name: str):
        if name not in {f.name for f in dataclasses.fields(self)}:
            raise KeyError(name)
        return getattr(self, name)

    def cache_shardings_for(self, cache: Params) -> Params:
        """NamedSharding tree for ANY cache pytree (concrete or abstract) of
        this model under the cell's policy — same rules engine that produced
        `cache_shardings`, resolved against the given tree's shapes (the
        divisibility fallback is shape-dependent)."""
        from repro.runtime import sharding as shd   # deferred: import cycle
        if self.cfg is None or self.mesh is None:
            raise ValueError("cell was built without cfg/mesh retention; "
                             "rebuild via build_serve on a current checkout")
        return shd.tree_shardings(cache, lm.cache_axes(self.cfg), self.mesh,
                                  self.policy)

    def place_params(self, params: Params) -> Params:
        """`jax.device_put` a live param tree under `param_shardings`."""
        return jax.device_put(params, self.param_shardings)


def serving_engine(kernel_impl: str = "auto") -> HSAEngine:
    """The paper's deployment policy: W8A8 MMM prefill, MXINT4 MVM decode."""
    return HSAEngine(HSAConfig(prefill_format="w8a8", decode_format="mxint4",
                               kernel_impl=kernel_impl))


def deployed_shapes(cfg: ModelConfig,
                    quantize: bool = True) -> tuple[Params, Params]:
    """(serving param ShapeDtypeStructs, their axes) — no allocation.

    ``quantize=False`` plans for fp master weights (the ablation / identity-
    test deployment): same tree the engine serves when
    ``EngineSpec(quantize=False)``.
    """
    params_abs, axes, paths = lm.init(cfg, jax.random.key(0), abstract=True)
    if not quantize:
        return params_abs, axes
    served = jax.eval_shape(
        lambda p: deploy.deploy_quantize(p, paths), params_abs)
    served_axes = deploy.deployed_axes(axes, paths)
    return served, served_axes


def prefill_step_fn(cfg: ModelConfig, engine: HSAEngine, cache_len: int = 0):
    def prefill(params, batch):
        return lm.forward_prefill(params, batch, cfg, engine,
                                  cache_len=cache_len)
    return prefill


def decode_step_fn(cfg: ModelConfig, engine: HSAEngine):
    def decode(params, tokens, cache):
        logits, new_cache = lm.forward_decode(params, tokens, cache, cfg, engine)
        return logits, new_cache
    return decode


def prefill_chunk_step_fn(cfg: ModelConfig, engine: HSAEngine):
    """Chunk-granular prefill step: appends [B, C] tokens into a warm cache
    at ``cache['pos']`` (one compiled shape per chunk length)."""
    def prefill_chunk(params, batch, cache):
        return lm.forward_prefill_chunk(params, batch, cache, cfg, engine)
    return prefill_chunk


def verify_chunk_step_fn(cfg: ModelConfig, engine: HSAEngine):
    """Speculative verify step: score a [B, k+1] draft block in one MMM
    dispatch — per-position logits + hidden + rollback state snapshots."""
    def verify_chunk(params, batch, cache):
        return lm.forward_verify_chunk(params, batch, cache, cfg, engine)
    return verify_chunk


def build_serve(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                policy=None, kernel_impl: str = "auto",
                local_batch: int | None = None,
                cache_dtype=jnp.bfloat16, quantize: bool = True) -> ServeCell:
    """Shardings + shapes for one serving cell (prefill or decode kind)."""
    from repro.runtime import sharding as shd   # deferred: avoid import cycle

    policy = policy or shd.ShardingPolicy()
    engine = serving_engine(kernel_impl)
    batch = local_batch or shape.global_batch

    served_shapes, served_axes = deployed_shapes(cfg, quantize=quantize)
    param_shardings = shd.tree_shardings(served_shapes, served_axes, mesh,
                                         policy)

    cache_shapes = jax.eval_shape(
        lambda: lm.make_decode_cache(cfg, batch, shape.seq_len, cache_dtype))
    c_axes = lm.cache_axes(cfg)
    # Prepend 'batch' resolution: cache axes use the logical 'batch'/'cache'
    # names directly; tree_specs resolves per-tensor with fallback.
    cache_shardings = shd.tree_shardings(cache_shapes, c_axes, mesh, policy)

    return ServeCell(
        engine=engine,
        prefill=prefill_step_fn(cfg, engine, cache_len=shape.seq_len),
        decode=decode_step_fn(cfg, engine),
        prefill_chunk=(None if cfg.is_encdec
                       else prefill_chunk_step_fn(cfg, engine)),
        verify_chunk=(None if cfg.is_encdec or cfg.frontend
                      else verify_chunk_step_fn(cfg, engine)),
        param_shapes=served_shapes,
        param_axes=served_axes,
        param_shardings=param_shardings,
        cache_shapes=cache_shapes,
        cache_shardings=cache_shardings,
        policy=policy,
        cfg=cfg,
        mesh=mesh,
    )
