"""Pallas TPU kernel: MXINT4 dequant-fused matmul — the HSA MVM dataflow (C2).

This is the TPU realization of the paper's decode dataflow (Fig. 4c): packed
int4 mantissas and 4-bit group-shift exponents stream HBM -> VMEM (4.25
bits/weight instead of 8/16), dequantization (`m * 2^(S_g-2)`) happens in VMEM
immediately before the MXU dot — the analogue of "dequantize on idle PEs" —
and the Eq. (4) fused-RMSNorm epilogue (`* out_scale * row_scale + bias`) is
applied in-register on the final K step, so the normalized activation tensor
never makes an extra HBM round-trip.

Tiling: grid ``(M/bm, N/bn, K/bk)``, K innermost/sequential with an fp32 VMEM
accumulator (output-stationary — the same dataflow class as the paper's PE
array).  ``bn`` is a multiple of 128 (MXU lane) and of the quant group (16);
``bk`` a multiple of 128.  Weight VMEM footprint per step is
``bk * bn * 0.53`` bytes — e.g. (512, 256) blocks = 69 kB packed, well inside
VMEM, leaving room for double-buffered pipelining.

The ASIC splits the shift into 2 LSBs (pre-shift) + 2 MSBs (accumulation-row
gating) because full shifters per PE are expensive in silicon; a VPU is not, so
we apply the whole exponent as one exact `exp2` multiply (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.core.mxint4 import EXP_BIAS, GROUP_SIZE, MANT_SHIFT


def _kernel(x_ref, packed_ref, exps_ref, oscale_ref, rscale_ref, bias_ref,
            out_ref, acc_ref, *, n_k: int, out_dtype):
    """One (bm, bn) output tile; K iterated sequentially via the grid."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- dequantize the (bk, bn) weight tile in VMEM ----------------------
    packed = packed_ref[...]                                # int8 [bk, bn//2]
    lo = ((packed << 4) >> 4).astype(jnp.int8)              # sign-extend low nibble
    hi = (packed >> 4).astype(jnp.int8)                     # arithmetic shift
    # Interleave nibbles back to logical channel order: [bk, bn//2, 2] -> [bk, bn]
    mant = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    ep = exps_ref[...]                                      # uint8 [bk, bn//32]
    codes = jnp.stack([ep & jnp.uint8(0x0F), (ep >> 4) & jnp.uint8(0x0F)],
                      axis=-1).reshape(packed.shape[0], -1)  # [bk, bn//16]
    scale = jnp.exp2(codes.astype(jnp.float32) - (EXP_BIAS + MANT_SHIFT))
    w = (mant.astype(jnp.float32)
         .reshape(packed.shape[0], -1, GROUP_SIZE) * scale[..., None]
         ).reshape(packed.shape[0], -1)                     # f32 [bk, bn]

    # ---- MXU dot, fp32 accumulate (output-stationary) ---------------------
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # ---- Eq. (4) epilogue on the last K step ------------------------------
    @pl.when(kk == n_k - 1)
    def _epilogue():
        y = acc_ref[...] * oscale_ref[...] * rscale_ref[...] + bias_ref[...]
        out_ref[...] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def mxint4_matmul_pallas(
    x: jax.Array,            # [M, K] bf16/f32 (or int8 activations pre-scaled)
    packed: jax.Array,       # int8 [K, N//2]
    exps_packed: jax.Array,  # uint8 [K, N//(2*GROUP_SIZE)] — biased nibble codes
    out_scale: jax.Array,    # f32 [N]
    row_scale: jax.Array,    # f32 [M]
    bias: jax.Array,         # f32 [N]
    *,
    block_m: int = 8,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n = packed.shape[1] * 2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    if bn % (2 * GROUP_SIZE) != 0:
        raise ValueError(f"block_n {bn} must cover whole packed groups "
                         f"({2 * GROUP_SIZE})")
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),             # x
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),        # packed
            pl.BlockSpec((bk, bn // (2 * GROUP_SIZE)),
                         lambda i, j, kk: (kk, j)),                       # exps
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),               # out_scale
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),               # row_scale
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),               # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed, exps_packed, out_scale.reshape(1, n), row_scale.reshape(m, 1),
      bias.reshape(1, n))
