"""Pallas TPU kernel: fused sigma^{-1} reduction (the fused-RMSNorm producer).

The only RMSNorm arithmetic the fused pipeline (Eq. 4) still needs is the
square-accumulate + rsqrt per token — the paper keeps this unit and overlaps it
with the next layer's MAC.  This kernel computes it as a blocked reduction:
grid ``(M/bm, D/bd)`` with the D axis sequential, partial sums held in a VMEM
scratch, rsqrt applied on the last D step.  It never materializes y^2 in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(y_ref, out_ref, acc_ref, *, n_d: int, d_total: int, eps: float):
    dd = pl.program_id(1)

    @pl.when(dd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = y_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(y * y, axis=1, keepdims=True)

    @pl.when(dd == n_d - 1)
    def _final():
        out_ref[...] = jax.lax.rsqrt(acc_ref[...] / d_total + eps)


@functools.partial(jax.jit, static_argnames=("block_m", "block_d", "eps", "interpret"))
def rmsnorm_stats_pallas(
    y: jax.Array,                 # [M, D]
    *,
    block_m: int = 256,
    block_d: int = 512,
    eps: float = 1e-6,
    interpret: bool = False,
) -> jax.Array:                   # f32 [M, 1]
    m, d = y.shape
    bm, bd = min(block_m, m), min(block_d, d)
    if m % bm or d % bd:
        raise ValueError(f"shape ({m},{d}) not divisible by blocks ({bm},{bd})")
    n_d = d // bd

    return pl.pallas_call(
        functools.partial(_kernel, n_d=n_d, d_total=d, eps=eps),
        grid=(m // bm, n_d),
        in_specs=[pl.BlockSpec((bm, bd), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(y)
