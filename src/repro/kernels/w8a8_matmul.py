"""Pallas TPU kernel: W8A8 int8 matmul — the HSA MMM (prefill) dataflow (C1).

The paper's prefill runs the PE array output-stationary on INT8 activations x
INT8 weights with int32 accumulation (Fig. 4b).  On TPU the MXU natively
consumes int8 pairs with int32 accumulate; this kernel expresses the paper's
dataflow explicitly: grid ``(M/bm, N/bn, K/bk)`` with K sequential, an int32
VMEM accumulator per output tile (output-stationary), and the dequantization
epilogue (`acc * act_scale * w_scale * S_{n+1} * sigma^{-1} + B`) applied once
at drain time — the Eq. (4) fusion on the MMM path.

XLA lowers jnp int8 dots to the MXU already (ops.w8a8_matmul's default path);
this kernel exists so the prefill dataflow has the same explicit BlockSpec
treatment as the decode kernel, and is validated against ref.w8a8_matmul_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(x_ref, w_ref, scale_ref, rscale_ref, bias_ref, out_ref, acc_ref,
            *, n_k: int, out_dtype):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Output-stationary int8 x int8 -> int32 accumulate (the PE array).
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kk == n_k - 1)
    def _drain():
        y = acc_ref[...].astype(jnp.float32) * scale_ref[...] \
            * rscale_ref[...] + bias_ref[...]
        out_ref[...] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def w8a8_matmul_pallas(
    x_q: jax.Array,          # int8 [M, K]
    w_q: jax.Array,          # int8 [K, N]
    out_scale: jax.Array,    # f32 [N] — act_scale * w_scale * S_{n+1}
    row_scale: jax.Array,    # f32 [M] — sigma^{-1} (Eq. 4)
    bias: jax.Array,         # f32 [N]
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_q.shape
    n = w_q.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),     # x int8
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),     # w int8
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # out_scale
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),       # row_scale
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q, out_scale.reshape(1, n), row_scale.reshape(m, 1),
      bias.reshape(1, n))
