"""Pallas TPU kernel: chunkwise multi-scale retention (RetNet prefill, C5).

Maps the chunkwise retention recurrence onto the TPU the way flash-attention
maps softmax attention: the grid walks ``(batch*heads, num_chunks)`` with the
chunk axis sequential; the running state ``S [dk, dv]`` lives in a VMEM
scratch accumulator across chunk steps (never spilled to HBM), and each step
does three MXU matmuls (scores, inner, cross) plus the decay-weighted state
update.  Intra-chunk decay matrices are built from `broadcasted_iota` on the
VPU — nothing is gathered from HBM.

Why it matters for the paper: chunkwise retention is the MMM-shaped prefill
workload the HSA runs in systolic mode; O(S) memory with no softmax pass is
RetNet's advantage the paper leans on (Sec. II).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(logg_ref, q_ref, k_ref, v_ref, y_ref, state_out_ref, state_ref,
            *, chunk: int, n_chunks: int, out_dtype):
    cc = pl.program_id(1)

    @pl.when(cc == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    log_g = logg_ref[0, 0]                                   # this head's log(gamma)
    q = q_ref[0].astype(jnp.float32)                         # [c, dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    # Decay structures (built on-chip; positions m = 1..c within the chunk).
    rows = jax.lax.broadcasted_iota(jnp.float32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.float32, (chunk, chunk), 1)
    diff = rows - cols
    d = jnp.where(diff >= 0, jnp.exp(diff * log_g), 0.0)     # [c, c]
    m = jax.lax.broadcasted_iota(jnp.float32, (chunk, 1), 0) + 1.0
    in_decay = jnp.exp(m * log_g)                            # gamma^m      [c, 1]
    out_decay = jnp.exp((chunk - m) * log_g)                 # gamma^(c-m)  [c, 1]
    chunk_decay = jnp.exp(chunk * log_g)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * d
    inner = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    cross = jax.lax.dot_general(q * in_decay, state_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = (inner + cross).astype(out_dtype)

    kv = jax.lax.dot_general(k * out_decay, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [dk, dv]
    state_ref[...] = chunk_decay * state_ref[...] + kv

    @pl.when(cc == n_chunks - 1)
    def _emit_state():
        state_out_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "out_dtype", "interpret"))
def retention_chunkwise_pallas(
    q: jax.Array,        # [BH, S, dk]
    k: jax.Array,        # [BH, S, dk]
    v: jax.Array,        # [BH, S, dv]
    log_gamma: jax.Array,  # f32 [BH, 1] — per-(batch,head) decay, log space
    *,
    chunk: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [BH, S, dv], final state [BH, dk, dv])."""
    bh, s, dk = q.shape
    dv = v.shape[-1]
    if s % chunk != 0:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    n_chunks = s // chunk

    grid = (bh, n_chunks)
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),            # log_gamma
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),  # q
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),  # k
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),  # v
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),  # y
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),     # final state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), out_dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(log_gamma, q, k, v)
    return y, state
