"""Pallas TPU kernel: split-KV flash-decode attention — the MVM-phase
attention hot loop.

The single-token decode step is memory-bound: per step, per layer, the whole
resident KV cache streams HBM -> VMEM once while the MXU does O(C*d) flops —
arithmetic intensity ~1, two orders below the ridge.  So the kernel's job is
to (a) touch each cache byte exactly once, (b) touch as *few* bytes as the
cache format allows, and (c) never materialize the [G, C] score matrix in HBM.

Dataflow (the neuronx ``flashdecode_attention`` split-KV idiom, on the
paper's HSA decode rung): grid ``(B, KV, C/block_c)`` with the KV-length
axis innermost/sequential ("arbitrary"); each step loads one
``(block_c, d)`` cache tile into VMEM, *dequantizes in-register* (int8
per-token scales or MXINT4 per-block shared exponents — core/kvq.py; packed
bytes are all HBM ever streams), computes partial scores, and folds them
into VMEM-resident online-softmax state ``(m, l, acc)`` — the same combine
as layers._flash_fwd_impl, one token wide.  The normalized output is written
once on the final KV block.

GQA batches G = n_heads/n_kv_heads query heads per kv head in one tile;
MLA maps to KV=1 with a second score stream (the shared rope key) riding
alongside the latent stream: ``s = (q·k + q2·k2) * scale``.

CPU runs use ``interpret=True`` (ops.flash_decode sets it automatically off
TPU); correctness oracle: kernels/ref.py `flash_decode_ref`.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core import kvq
from repro.core.mxint4 import GROUP_SIZE, MANT_SHIFT


def _part_fmt(leaf) -> str:
    """Static dequant tag for one cache operand."""
    fmt = kvq.leaf_format(leaf)
    if fmt is not None:
        return fmt
    if leaf.dtype == jnp.int8:
        return "legacy_int8"
    return "fp"


def _parts(leaf) -> list[jax.Array]:
    """Flatten one cache operand into its HBM-resident arrays (key order is
    fixed per format so kernel ref order is deterministic)."""
    fmt = kvq.leaf_format(leaf)
    if fmt == "int8_tok":
        return [leaf["q"], leaf["s"]]
    if fmt == "mxint4_blk":
        return [leaf["m"], leaf["e"]]
    return [leaf]


def _dequant(parts: list, fmt: str) -> jax.Array:
    """(1, block_c, 1, *) part refs -> f32 [block_c, d] tile, in VMEM."""
    if fmt == "int8_tok":
        return parts[0][0, :, 0, :].astype(jnp.float32) * parts[1][0, :, 0, :]
    if fmt == "mxint4_blk":
        m8 = parts[0][0, :, 0, :]
        lo = ((m8 << 4) >> 4).astype(jnp.int8)          # sign-extended low
        hi = (m8 >> 4).astype(jnp.int8)                 # arithmetic shift
        mant = jnp.stack([lo, hi], axis=-1).reshape(m8.shape[0], -1)
        e = parts[1][0, :, 0, :]
        scale = jnp.exp2(e.astype(jnp.float32) - MANT_SHIFT)
        return (mant.astype(jnp.float32)
                .reshape(m8.shape[0], -1, GROUP_SIZE) * scale[..., None]
                ).reshape(m8.shape[0], -1)
    if fmt == "legacy_int8":
        return parts[0][0, :, 0, :].astype(jnp.float32) / kvq.KV8_SCALE
    return parts[0][0, :, 0, :].astype(jnp.float32)


def _kernel(len_ref, q_ref, *refs, scale: float, block_c: int,
            n_k: int, n_v: int, n_k2: int, kfmt: str, vfmt: str, k2fmt: str,
            two_stream: bool, out_dtype):
    """One (batch lane, kv head) output row; KV blocks iterated sequentially."""
    i = 0
    q2_ref = None
    if two_stream:
        q2_ref, i = refs[0], 1
    k_parts = refs[i:i + n_k]
    v_parts = refs[i + n_k:i + n_k + n_v]
    k2_parts = refs[i + n_k + n_v:i + n_k + n_v + n_k2]
    out_ref, m_ref, l_ref, acc_ref = refs[i + n_k + n_v + n_k2:]

    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kblk = _dequant(list(k_parts), kfmt)                # f32 [block_c, d]
    vblk = _dequant(list(v_parts), vfmt)                # f32 [block_c, dv]
    qv = q_ref[0, 0].astype(jnp.float32)                # [G, d]
    s = jax.lax.dot_general(qv, kblk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if two_stream:
        k2blk = _dequant(list(k2_parts), k2fmt)
        q2v = q2_ref[0, 0].astype(jnp.float32)
        s = s + jax.lax.dot_general(q2v, k2blk, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    s = s * scale                                       # [G, block_c]

    # Rows at absolute index >= kv_len are masked out; this also covers the
    # padded tail of a non-dividing final block (kv_len <= C always).  The
    # V rows are zeroed too so boundary-pad garbage can't ride into acc.
    idx = kk * block_c + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < len_ref[0, 0], s, -jnp.inf)
    ridx = kk * block_c + jax.lax.broadcasted_iota(jnp.int32, vblk.shape, 0)
    vblk = jnp.where(ridx < len_ref[0, 0], vblk, 0.0)

    m_prev = m_ref[...]                                 # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)                             # masked rows -> 0
    corr = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, vblk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kk == pl.num_programs(2) - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_c", "interpret"))
def flash_decode_pallas(q, k, v, kv_len, *, q2=None, k2=None,
                        scale: float | None = None, block_c: int = 128,
                        interpret: bool = False) -> jax.Array:
    """Split-KV decode attention.  q ``[B, KV, G, d]``; k/v cache leaves
    ``[B, C, KV, *]`` (fp/legacy-int8 arrays or kvq-encoded dicts); ``kv_len``
    a traced i32 scalar.  Optional second stream ``q2 [B, KV, G, d2]`` /
    ``k2 [B, C, KV, d2]`` (MLA rope term).  ``scale=None`` -> ``1/sqrt(d)``.

    Returns f32 ``[B, KV, G, dv]``.
    """
    b, kv_h, g, d = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    two_stream = q2 is not None
    kfmt, vfmt = _part_fmt(k), _part_fmt(v)
    k2fmt = _part_fmt(k2) if two_stream else "fp"
    k_parts, v_parts = _parts(k), _parts(v)
    k2_parts = _parts(k2) if two_stream else []
    c = k_parts[0].shape[1]
    dv = kvq.decoded_dim(v)
    bc = min(block_c, c)
    n_blocks = pl.cdiv(c, bc)

    def part_spec(p):
        return pl.BlockSpec((1, bc, 1, p.shape[-1]),
                            lambda bi, hi, kk: (bi, kk, hi, 0))

    in_specs = [pl.BlockSpec((1, 1), lambda bi, hi, kk: (0, 0)),       # kv_len
                pl.BlockSpec((1, 1, g, d), lambda bi, hi, kk: (bi, hi, 0, 0))]
    operands = [jnp.asarray(kv_len, jnp.int32).reshape(1, 1), q]
    if two_stream:
        d2 = q2.shape[-1]
        in_specs.append(pl.BlockSpec((1, 1, g, d2),
                                     lambda bi, hi, kk: (bi, hi, 0, 0)))
        operands.append(q2)
    for p in k_parts + v_parts + k2_parts:
        in_specs.append(part_spec(p))
        operands.append(p)

    kernel = functools.partial(
        _kernel, scale=scale, block_c=bc, n_k=len(k_parts),
        n_v=len(v_parts), n_k2=len(k2_parts), kfmt=kfmt, vfmt=vfmt,
        k2fmt=k2fmt, two_stream=two_stream, out_dtype=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(b, kv_h, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda bi, hi, kk: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv_h, g, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),     # m
                        pltpu.VMEM((g, 1), jnp.float32),     # l
                        pltpu.VMEM((g, dv), jnp.float32)],   # acc
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
