"""Public jit'd wrappers around the Pallas kernels.

Implementation selection (`impl`):
  'pallas'  — the TPU kernel (pass ``interpret=True`` on CPU; used by tests)
  'ref'     — the pure-jnp oracle from ref.py
  'auto'    — 'pallas' on a real TPU backend, 'ref' otherwise.  The ref path
              streams the identical packed-int4 + exponent buffers, so dry-run
              roofline byte counts match what the TPU kernel would move.

These wrappers own shape plumbing: M-padding to the block size, optional
epilogue operands defaulted to identities, and flattening of leading batch
dims.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mxint4 import MXINT4Weight
from repro.kernels import ref as _ref
from repro.kernels.mxint4_matmul import mxint4_matmul_pallas
from repro.kernels.retention_kernel import retention_chunkwise_pallas
from repro.kernels.rmsnorm_stats import rmsnorm_stats_pallas


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _pad_rows(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    m = x.shape[0]
    pad = (-m) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def mxint4_matmul(
    x: jax.Array,
    q: MXINT4Weight,
    out_scale: jax.Array | None = None,
    row_scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    *,
    out_dtype=jnp.float32,
    impl: str = "auto",
    interpret: bool = False,
    block_m: int = 8,
    block_n: int = 256,
    block_k: int = 512,
) -> jax.Array:
    """Decode-path quantized matmul with the Eq. (4) fused epilogue.

    ``x`` may have leading batch dims; they are flattened into M.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = q.shape[1]
    x2 = x.reshape(-1, k)
    rs = None if row_scale is None else row_scale.reshape(-1)

    impl = _resolve(impl)
    if impl == "ref":
        y = _ref.mxint4_matmul_ref(x2, q, out_scale, rs, bias, out_dtype)
        return y.reshape(*lead, n)

    x2p, m = _pad_rows(x2, block_m)
    os = jnp.ones((n,), jnp.float32) if out_scale is None else jnp.broadcast_to(
        jnp.asarray(out_scale, jnp.float32), (n,))
    bs = jnp.zeros((n,), jnp.float32) if bias is None else jnp.asarray(bias, jnp.float32)
    if rs is None:
        rsp = jnp.ones((x2p.shape[0],), jnp.float32)
    else:
        rsp = jnp.pad(rs.astype(jnp.float32), (0, x2p.shape[0] - m),
                      constant_values=1.0)
    y = mxint4_matmul_pallas(
        x2p, q.packed, q.exps_packed, os, rsp, bs,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret,
    )
    return y[:m].reshape(*lead, n)


def w8a8_matmul(
    x_q: jax.Array,
    w_q: jax.Array,
    combined_scale: jax.Array,
    row_scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    *,
    out_dtype=jnp.float32,
    impl: str = "auto",
    interpret: bool = False,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
) -> jax.Array:
    """Prefill MMM path (int8 MXU dot with the Eq. (4) drain epilogue).

    'auto' uses the jnp path (XLA maps int8 dots to the MXU natively);
    'pallas' runs the explicit output-stationary kernel
    (kernels/w8a8_matmul.py) — same dataflow, same results."""
    lead = x_q.shape[:-1]
    k = x_q.shape[-1]
    n = w_q.shape[1]
    x2 = x_q.reshape(-1, k)
    rs = None if row_scale is None else row_scale.reshape(-1)

    if _resolve(impl) == "ref":
        y = _ref.w8a8_matmul_ref(x2, w_q, combined_scale, rs, bias, out_dtype)
        return y.reshape(*lead, n)

    from repro.kernels.w8a8_matmul import w8a8_matmul_pallas
    x2p, m = _pad_rows(x2, block_m)
    os = jnp.broadcast_to(jnp.asarray(combined_scale, jnp.float32), (n,))
    bs = jnp.zeros((n,), jnp.float32) if bias is None \
        else jnp.asarray(bias, jnp.float32)
    if rs is None:
        rsp = jnp.ones((x2p.shape[0],), jnp.float32)
    else:
        rsp = jnp.pad(rs.astype(jnp.float32), (0, x2p.shape[0] - m),
                      constant_values=1.0)
    y = w8a8_matmul_pallas(x2p, w_q, os, rsp, bs, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           out_dtype=out_dtype, interpret=interpret)
    return y[:m].reshape(*lead, n)


def retention_chunkwise(
    q: jax.Array,          # [B, H, S, dk]
    k: jax.Array,
    v: jax.Array,          # [B, H, S, dv]
    gamma: jax.Array,      # [H]
    *,
    chunk: int = 128,
    state: jax.Array | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "ref" or state is not None:
        # The kernel owns zero-initialized state; warm-state callers (decode
        # chunk continuation) use the oracle path.
        return _ref.retention_chunkwise_ref(q, k, v, gamma, chunk=chunk, state=state)

    b, h, s, dk = q.shape
    dv = v.shape[-1]
    log_g = jnp.broadcast_to(jnp.log(gamma.astype(jnp.float32))[None, :, None],
                             (b, h, 1)).reshape(b * h, 1)
    y, st = retention_chunkwise_pallas(
        q.reshape(b * h, s, dk), k.reshape(b * h, s, dk), v.reshape(b * h, s, dv),
        log_g, chunk=chunk, out_dtype=jnp.float32, interpret=interpret,
    )
    return (y.reshape(b, h, s, dv).astype(v.dtype),
            st.reshape(b, h, dk, dv))


def flash_decode(
    q: jax.Array,          # GQA: [B, KV, G, d] | MLA: [B, H, r]
    k,                     # cache leaf: fp/int8 array or kvq-encoded dict
    v,
    kv_len: jax.Array,     # traced i32 scalar — valid cache prefix length
    *,
    q2: jax.Array | None = None,   # MLA rope query [B, H, dr]
    k2=None,                       # MLA rope key cache leaf [B, C, dr]
    scale=None,                    # None -> s/sqrt(d); MLA passes 1/sqrt(dn+dr)
    impl: str = "auto",
    interpret: bool = False,
    block_c: int = 128,
) -> jax.Array:
    """Single-token decode attention over the first ``kv_len`` cache rows —
    the MVM-phase hot loop (kernels/flash_decode.py).

    Two layouts (see ref.flash_decode_ref): GQA with ``[B, C, KV, d]`` cache
    leaves, and MLA (``q.ndim == 3``) attending in the compressed latent
    space with the shared rope key as a second score stream.  Cache leaves
    may be kvq-quantized dicts — the Pallas path dequantizes inside its KV
    block loads; the ref path is the bit-exact jnp oracle the engine decode
    loops are token-identical against.

    'pallas' off-TPU automatically runs in interpret mode, so the kernel
    path stays testable (and auditable) on CPU.
    """
    if _resolve(impl) == "ref":
        return _ref.flash_decode_ref(q, k, v, kv_len, q2=q2, k2=k2,
                                     scale=scale)
    from repro.kernels.flash_decode import flash_decode_pallas
    interpret = interpret or jax.default_backend() != "tpu"
    sc = None if scale is None else float(scale)
    if q.ndim == 4:
        return flash_decode_pallas(q, k, v, kv_len, scale=sc,
                                   block_c=block_c, interpret=interpret)
    # MLA: insert a singleton kv-head axis around the kernel call.
    add_kv = lambda leaf: jax.tree.map(lambda x: x[:, :, None], leaf)
    out = flash_decode_pallas(
        q[:, None], add_kv(k), add_kv(v), kv_len, q2=q2[:, None],
        k2=add_kv(k2), scale=sc, block_c=block_c, interpret=interpret)
    return out[:, 0]


def rmsnorm_stats(
    y: jax.Array, *, eps: float = 1e-6, impl: str = "auto", interpret: bool = False
) -> jax.Array:
    """sigma^{-1} over the last axis; leading dims preserved."""
    lead = y.shape[:-1]
    y2 = y.reshape(-1, y.shape[-1])
    if _resolve(impl) == "ref":
        return _ref.rmsnorm_stats_ref(y2, eps).reshape(lead)
    y2p, m = _pad_rows(y2, 8)
    out = rmsnorm_stats_pallas(y2p, block_m=min(256, y2p.shape[0]),
                               block_d=min(512, y2.shape[1]), eps=eps,
                               interpret=interpret)
    return out[:m, 0].reshape(lead)
