"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *definition of correctness* for the corresponding kernel:
kernel tests sweep shapes/dtypes and `assert_allclose` against these.  They are
also the CPU execution path (`impl='ref'` in ops.py) used by smoke tests and by
the dry-run lowering (Pallas TPU kernels cannot lower on the CPU backend; the
ref path streams the same 4.25-bit weight buffers, so roofline byte counts are
representative).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kvq
from repro.core import mxint4 as mx
from repro.core import retention as ret


def mxint4_matmul_ref(
    x: jax.Array,
    q: mx.MXINT4Weight,
    out_scale: jax.Array | None = None,   # [N] or scalar — S_{n+1} (Eq. 4)
    row_scale: jax.Array | None = None,   # [M] — sigma^{-1} from fused RMSNorm
    bias: jax.Array | None = None,        # [N] — B_{n+1} (Eq. 4)
    out_dtype=jnp.float32,
) -> jax.Array:
    """y = (x @ dequant(q)) * out_scale * row_scale + bias  — the MVM dataflow."""
    w = mx.dequantize_mxint4(q, dtype=jnp.float32)
    y = x.astype(jnp.float32) @ w
    if out_scale is not None:
        y = y * out_scale
    if row_scale is not None:
        y = y * row_scale[:, None]
    if bias is not None:
        y = y + bias
    return y.astype(out_dtype)


def w8a8_matmul_ref(
    x_q: jax.Array,        # int8 [M, K]
    w_q: jax.Array,        # int8 [K, N]
    combined_scale: jax.Array,   # f32 scalar or [N] — act_scale * w_scale * S
    row_scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Prefill MMM dataflow: int8 x int8 -> int32 accumulate, scale epilogue."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32)
    y = acc * combined_scale
    if row_scale is not None:
        y = y * row_scale[:, None]
    if bias is not None:
        y = y + bias
    return y.astype(out_dtype)


def retention_chunkwise_ref(q, k, v, gamma, chunk=128, state=None):
    """Oracle for the chunkwise retention kernel (identical math)."""
    return ret.retention_chunkwise(q, k, v, gamma, chunk=chunk, state=state)


def rmsnorm_stats_ref(y: jax.Array, eps: float = 1e-6) -> jax.Array:
    """sigma^{-1} per row of ``[M, D]`` (the fused-RMSNorm producer)."""
    y32 = y.astype(jnp.float32)
    return jax.lax.rsqrt(jnp.mean(y32 * y32, axis=-1) + eps)


def flash_decode_ref(q, k, v, kv_len, *, q2=None, k2=None, scale=None):
    """Single-token decode attention over the first ``kv_len`` cache rows.

    Two layouts, matching the two attention decode entry points in
    models/layers.py *operation for operation* (same einsum strings, same
    mask/softmax order), so greedy decode through this path is bit-identical
    to the pre-kernel `attend_one_step` / `mla_decode` math:

    GQA  (``q.ndim == 4``): q ``[B, KV, G, d]``; k/v ``[B, C, KV, d]`` cache
        leaves (fp / legacy-int8 array or kvq-encoded dict).  ``scale=None``
        applies the ``s / sqrt(d)`` convention.
    MLA  (``q.ndim == 3``): q = absorbed latent queries ``[B, H, r]`` with a
        second rope score stream ``q2 [B, H, dr]`` against ``k2 [B, C, dr]``;
        v is the shared latent cache.  ``scale`` is required
        (``1/sqrt(dn+dr)``) and multiplies the summed scores.

    ``kv_len`` is a traced i32 scalar: rows at index >= kv_len are masked to
    -inf before the softmax (ring caches pass C once wrapped — softmax over
    a full ring is order-independent, so a prefix-length mask suffices).
    """
    kf = kvq.decode(k)
    vf = kvq.decode(v)
    b, c = kf.shape[0], kf.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, (b, c), 1) < kv_len
    if q.ndim == 4:
        d = q.shape[-1]
        s = jnp.einsum("bhgd,bchd->bhgc", q.astype(jnp.float32), kf)
        s = s * scale if scale is not None else s / jnp.sqrt(jnp.float32(d))
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgc,bchd->bhgd", p, vf)
    if q2 is None or k2 is None or scale is None:
        raise ValueError("MLA layout (q.ndim == 3) needs q2, k2 and scale")
    s_lat = jnp.einsum("bhr,bcr->bhc", q.astype(jnp.float32), kf)
    s_rope = jnp.einsum("bhr,bcr->bhc", q2.astype(jnp.float32),
                        kvq.decode(k2))
    s = (s_lat + s_rope) * scale
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhc,bcr->bhr", p, vf)
