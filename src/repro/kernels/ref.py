"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *definition of correctness* for the corresponding kernel:
kernel tests sweep shapes/dtypes and `assert_allclose` against these.  They are
also the CPU execution path (`impl='ref'` in ops.py) used by smoke tests and by
the dry-run lowering (Pallas TPU kernels cannot lower on the CPU backend; the
ref path streams the same 4.25-bit weight buffers, so roofline byte counts are
representative).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mxint4 as mx
from repro.core import retention as ret


def mxint4_matmul_ref(
    x: jax.Array,
    q: mx.MXINT4Weight,
    out_scale: jax.Array | None = None,   # [N] or scalar — S_{n+1} (Eq. 4)
    row_scale: jax.Array | None = None,   # [M] — sigma^{-1} from fused RMSNorm
    bias: jax.Array | None = None,        # [N] — B_{n+1} (Eq. 4)
    out_dtype=jnp.float32,
) -> jax.Array:
    """y = (x @ dequant(q)) * out_scale * row_scale + bias  — the MVM dataflow."""
    w = mx.dequantize_mxint4(q, dtype=jnp.float32)
    y = x.astype(jnp.float32) @ w
    if out_scale is not None:
        y = y * out_scale
    if row_scale is not None:
        y = y * row_scale[:, None]
    if bias is not None:
        y = y + bias
    return y.astype(out_dtype)


def w8a8_matmul_ref(
    x_q: jax.Array,        # int8 [M, K]
    w_q: jax.Array,        # int8 [K, N]
    combined_scale: jax.Array,   # f32 scalar or [N] — act_scale * w_scale * S
    row_scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Prefill MMM dataflow: int8 x int8 -> int32 accumulate, scale epilogue."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32)
    y = acc * combined_scale
    if row_scale is not None:
        y = y * row_scale[:, None]
    if bias is not None:
        y = y + bias
    return y.astype(out_dtype)


def retention_chunkwise_ref(q, k, v, gamma, chunk=128, state=None):
    """Oracle for the chunkwise retention kernel (identical math)."""
    return ret.retention_chunkwise(q, k, v, gamma, chunk=chunk, state=state)


def rmsnorm_stats_ref(y: jax.Array, eps: float = 1e-6) -> jax.Array:
    """sigma^{-1} per row of ``[M, D]`` (the fused-RMSNorm producer)."""
    y32 = y.astype(jnp.float32)
    return jax.lax.rsqrt(jnp.mean(y32 * y32, axis=-1) + eps)
