"""Pallas TPU kernels for the paper's compute hot-spots.

mxint4_matmul.py   — C2: dequant-fused W4A8 matmul (the HSA MVM dataflow)
flash_decode.py    — split-KV single-token decode attention: online-softmax
                     combine across KV grid blocks, GQA/MLA-aware, with
                     core/kvq dequant (int8_tok / mxint4_blk / legacy int8)
                     fused into the cache block loads — packed bytes are all
                     HBM ever streams on the decode rung
retention_kernel.py — C5: chunkwise retention (the HSA MMM prefill workload)
rmsnorm_stats.py   — C3: fused sigma^{-1} reduction
ops.py             — jit'd public wrappers (impl='auto'|'pallas'|'ref')
ref.py             — pure-jnp oracles (the definition of correctness)

All kernels are written for TPU (BlockSpec VMEM tiling, MXU-aligned shapes)
and validated on CPU with ``interpret=True`` against ref.py.
"""
