"""Deterministic synthetic token pipeline: host-sharded, packed, checkpointable.

No external datasets ship in this container, so the training substrate is a
synthetic stream with real-pipeline semantics:

  * **Determinism/resume** — batch content is a pure function of
    (seed, host, step): restoring a checkpoint at step k replays the exact
    stream without persisting buffers (the pipeline state IS the step).
  * **Host sharding** — each host draws only its slice of the global batch
    (disjoint per-host substreams), matching multi-host input pipelines.
  * **Packing** — documents with Zipf-ish lengths are packed back-to-back
    into fixed seq_len rows, separated by EOS, with -1 label padding after
    the final EOS (loss-masked), like production LM packing.
  * **Markov structure** — tokens follow a seeded bigram chain so the loss
    has learnable signal (integration tests assert loss decreases).
"""

from __future__ import annotations

import dataclasses

import numpy as np

EOS = 0
PAD_LABEL = -1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 17
    n_hosts: int = 1
    host_id: int = 0
    mean_doc_len: int = 96
    branching: int = 4          # markov branching factor (lower = easier)


class SyntheticPipeline:
    """Stateless-by-construction synthetic LM data."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts != 0:
            raise ValueError(f"global_batch {cfg.global_batch} not divisible "
                             f"by n_hosts {cfg.n_hosts}")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        # Seeded bigram table: token t -> `branching` plausible successors.
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(
            1, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching))

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        n = max(2, int(rng.exponential(self.cfg.mean_doc_len)))
        toks = np.empty(n, np.int64)
        toks[0] = rng.integers(1, self.cfg.vocab_size)
        for i in range(1, n):
            toks[i] = self._succ[toks[i - 1], rng.integers(self.cfg.branching)]
        return toks

    def _row(self, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
        s = self.cfg.seq_len
        buf = []
        while sum(len(d) + 1 for d in buf) < s + 1:
            buf.append(self._doc(rng))
        flat = np.concatenate([np.append(d, EOS) for d in buf])[: s + 1]
        tokens = flat[:s]
        labels = flat[1: s + 1].copy()
        return tokens, labels

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """The (host-local) batch for `step` — pure function of (cfg, step)."""
        c = self.cfg
        toks = np.empty((self.local_batch, c.seq_len), np.int32)
        labs = np.empty((self.local_batch, c.seq_len), np.int32)
        for i in range(self.local_batch):
            rng = np.random.default_rng(
                (c.seed, c.host_id * 131071 + i, step))
            t, l = self._row(rng)
            toks[i], labs[i] = t, l
        return {"tokens": toks, "labels": labs}

    def state(self, step: int) -> dict:
        """Checkpointable pipeline state (the step counter is sufficient)."""
        return {"step": step, "seed": self.cfg.seed}
