"""Data pipeline substrate."""
