"""Feed-forward layers: gated dense MLP and top-k MoE with capacity dispatch.

The MoE dispatch is the sort-based, O(tokens * top_k) scheme used by
production JAX MoE stacks: route -> flatten (token, expert) assignments ->
sort by expert -> positions within expert via counts/offsets -> scatter into
``[E, capacity, D]`` buffers (mode='drop' handles overflow) -> per-expert
batched matmuls (expert dim sharded over the 'model' mesh axis = expert
parallelism; GSPMD inserts the all-to-alls at the dispatch/combine
resharding points) -> gather back with gate weighting.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.hsa import HSAEngine
from repro.compat import shard_map
from repro.models.config import ModelConfig
from repro.models.modules import ParamBuilder
from repro.runtime.sharding import constrain

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Dense gated MLP (silu(x W_gate) * (x W_up)) W_down — llama-family standard
# ---------------------------------------------------------------------------


def mlp_init(b: ParamBuilder, cfg: ModelConfig, d_ff: int | None = None,
             gated: bool = True) -> None:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if gated:
        b.linear("wg", d, f, "embed", "mlp")
    b.linear("wi", d, f, "embed", "mlp")
    b.linear("wo", f, d, "mlp", "embed")


def mlp_apply(p: Params, x_star: jax.Array, sig_inv, engine: HSAEngine,
              phase: str) -> jax.Array:
    up = engine.linear(p["wi"], x_star, phase, row_scale=sig_inv)
    if "wg" in p:
        gate = engine.linear(p["wg"], x_star, phase, row_scale=sig_inv)
        up = jax.nn.silu(gate) * up
    else:
        up = jax.nn.gelu(up)
    return engine.linear(p["wo"], up, phase)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_init(b: ParamBuilder, cfg: ModelConfig) -> None:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    b.linear("router", d, e, "embed", None)
    sub = b.child("experts")
    sub.param("wg", (e, d, f), ("experts", "embed", "mlp"))
    sub.param("wi", (e, d, f), ("experts", "embed", "mlp"))
    sub.param("wo", (e, f, d), ("experts", "mlp", "embed"))
    if cfg.n_shared_experts:
        shared = b.child("shared")
        mlp_init(shared, cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)


def _expert_weight(pe: Params, name: str) -> jax.Array:
    """Expert weight in f32 — dequantized from MXINT4 if deployed (C2 for MoE).

    When models/deploy.py has quantized the stacked expert tensors, the HLO
    streams the 4.25-bit packed buffers and dequantizes on-chip — the paper's
    decode dataflow generalized to expert weights.
    """
    if name in pe:
        return pe[name].astype(jnp.float32)
    from repro.models.deploy import dequantize_stacked  # local import, no cycle
    return dequantize_stacked(pe, name)


def _round_cap(cap: int) -> int:
    return ((cap + 255) // 256) * 256 if cap > 256 else cap


def _dispatch(x: jax.Array, idx: jax.Array, gates: jax.Array, e: int,
              cap: int):
    """Capacity dispatch of [T, D] rows into [E, cap, D], slot by slot.

    Processing the top-k slots one at a time keeps every intermediate at
    [T, D] (one gather/scatter per slot) instead of [T*k, D] — at ds-v3 scale
    the flattened form materialized multi-GB gather temporaries per device.

    Returns (buf, slots) where slots is a list of (expert_id [T], pos [T],
    gate [T]) per top-k slot; pos == cap marks dropped assignments.
    """
    t, d = x.shape
    k = idx.shape[-1]
    buf = jnp.zeros((e, cap, d), x.dtype)
    fill = jnp.zeros((e,), jnp.int32)
    slots = []
    for j in range(k):
        ej = idx[:, j]
        counts = jnp.bincount(ej, length=e)
        offsets = jnp.cumsum(counts) - counts
        order = jnp.argsort(ej)
        rank_sorted = jnp.arange(t, dtype=jnp.int32) - offsets[ej[order]]
        rank = jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)
        pos = fill[ej] + rank
        pos = jnp.where(pos < cap, pos, cap)               # cap -> dropped
        buf = buf.at[ej, pos].set(x, mode="drop")
        slots.append((ej, pos, gates[:, j]))
        fill = fill + counts
    return buf, slots


def _combine(out_buf: jax.Array, slots, t: int, dtype) -> jax.Array:
    """Inverse of `_dispatch`: gather expert outputs, gate-weight, sum/token."""
    e, cap, d = out_buf.shape
    ob = out_buf.astype(dtype)
    y = jnp.zeros((t, d), jnp.float32)
    for ej, pos, gate in slots:
        picked = ob[ej, jnp.minimum(pos, cap - 1)]
        picked = jnp.where((pos < cap)[:, None], picked, jnp.zeros((), dtype))
        y = y + picked.astype(jnp.float32) * gate[:, None].astype(jnp.float32)
    return y


def _expert_ffn(buf: jax.Array, wg, wi, wo) -> jax.Array:
    """buf [E, C, D] -> [E, C, D] through each expert's gated FFN."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32), wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32), wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_core_local(x: jax.Array, idx, gates, wg, wi, wo, e: int, cap: int):
    """Single-device MoE core (smoke tests / no sharding context)."""
    buf, slots = _dispatch(x, idx, gates, e, cap)
    out_buf = _expert_ffn(buf, wg, wi, wo)
    return _combine(out_buf, slots, x.shape[0], x.dtype)


def _moe_core_sharded(x, idx, gates, p_experts: Params, cfg: ModelConfig,
                      mesh, policy, no_drop: bool = False) -> jax.Array:
    """Expert-parallel MoE via shard_map (the production path).

    Tokens are row-sharded over the DP axes and *replicated* over the TP
    ('model') axis; experts are sharded over 'model'.  Because those are
    different mesh axes, dispatch needs NO collective: each device locally
    packs its token rows destined for its own E/n_tp expert slice.  Expert
    weights arrive FSDP-sharded on the d_model dim and are all-gathered over
    the DP axes just-in-time (ZeRO-3 style).  The only per-layer collective
    on the critical path is one psum of the [T_local, D] partial outputs over
    'model' — the same class as a dense TP FFN.  All [T*k, D]-scale tensors
    stay shard-local (GSPMD's gather handling materialized them replicated —
    TBs at ds-v3 scale; see EXPERIMENTS.md §Dry-run).
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    tp = "model" if "model" in mesh.shape and e % mesh.shape["model"] == 0 \
        else None
    n_tp = mesh.shape[tp] if tp else 1
    t_loc = t // n_dp
    # no_drop (speculative verify): a token appears at most once per expert,
    # so cap = t_loc guarantees zero capacity drops.
    cap_loc = (t_loc if no_drop
               else _round_cap(int(t_loc * k / e * cfg.capacity_factor) + 1))

    from jax.sharding import PartitionSpec as P

    from repro.runtime.sharding import spec_for_tensor

    def wspec(w, logical):
        # match the rules engine so no resharding is inserted at the boundary
        return spec_for_tensor(w.shape, logical, mesh, policy)

    wg, wi, wo = (p_experts.get(n) for n in ("wg", "wi", "wo"))
    quantized = wg is None
    in_axes = ("experts", "embed", "mlp")     # wg/wi (+ their packed forms)
    out_axes = ("experts", "mlp", "embed")    # wo
    if quantized:
        wg_p, wi_p, wo_p = (p_experts[f"{n}_mx"] for n in ("wg", "wi", "wo"))
        w_args = (wg_p["packed"], wg_p["exps"], wi_p["packed"], wi_p["exps"],
                  wo_p["packed"], wo_p["exps"])
        w_specs = tuple(wspec(w, ax) for w, ax in zip(
            w_args, (in_axes, in_axes, in_axes, in_axes, out_axes, out_axes)))
        gather_axes = (1, 1, 1, 1, 2, 2)      # the FSDP ('embed') dim of each
    else:
        w_args = (wg, wi, wo)
        w_specs = (wspec(wg, in_axes), wspec(wi, in_axes), wspec(wo, out_axes))
        gather_axes = (1, 1, 2)

    def _gather_fsdp(w, ax, spec):
        names = spec[ax] if ax < len(spec) else None
        if names is None:
            return w
        names = (names,) if isinstance(names, str) else tuple(names)
        return jax.lax.all_gather(w, names, axis=ax, tiled=True)

    def local_moe(x_loc, idx_loc, gates_loc, *w_loc):
        # ZeRO-3: gather each weight's FSDP-sharded dim just-in-time.
        w_loc = tuple(_gather_fsdp(w, ax, spec)
                      for w, ax, spec in zip(w_loc, gather_axes, w_specs))
        if quantized:
            from repro.models.deploy import dequantize_stacked
            pg = {"wg_mx": {"packed": w_loc[0], "exps": w_loc[1]},
                  "wi_mx": {"packed": w_loc[2], "exps": w_loc[3]},
                  "wo_mx": {"packed": w_loc[4], "exps": w_loc[5]}}
            wg_l = dequantize_stacked(pg, "wg")
            wi_l = dequantize_stacked(pg, "wi")
            wo_l = dequantize_stacked(pg, "wo")
        else:
            wg_l, wi_l, wo_l = w_loc
        e_loc = wg_l.shape[0]
        first = (jax.lax.axis_index(tp) * e_loc) if tp else 0

        # Keep only assignments routed to this device's expert slice.
        in_slice = (idx_loc >= first) & (idx_loc < first + e_loc)
        idx_here = jnp.where(in_slice, idx_loc - first, e_loc)  # e_loc = drop
        gates_here = jnp.where(in_slice, gates_loc, 0.0)

        buf, slots = _dispatch(x_loc, idx_here, gates_here, e_loc + 1, cap_loc)
        out_buf = _expert_ffn(buf[:e_loc], wg_l, wi_l, wo_l)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((1,) + out_buf.shape[1:], out_buf.dtype)], 0)
        y_part = _combine(out_buf, slots, x_loc.shape[0], x_loc.dtype)
        if tp:
            # Reduce the TP partials on the wire in bf16: each partial is a
            # short (<= top_k) sum of expert outputs, so a 16-way bf16 tree
            # reduction is numerically safe and halves the psum bytes
            # (§Perf cell A iteration 2).
            y_part = jax.lax.psum(y_part.astype(x_loc.dtype), tp)
        return y_part.astype(x_loc.dtype)

    manual = set(dp_axes) | ({tp} if tp else set())
    y = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(P(dp_axes or None, None), P(dp_axes or None, None),
                  P(dp_axes or None, None)) + w_specs,
        out_specs=P(dp_axes or None, None),
        axis_names=manual,
        check_vma=False,
    )(x, idx, gates, *w_args)
    return y.astype(jnp.float32)


def moe_apply(p: Params, x_star: jax.Array, sig_inv, engine: HSAEngine,
              phase: str, cfg: ModelConfig, no_drop: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], router aux loss scalar).

    ``no_drop`` disables capacity dropping (cap = tokens): required on the
    speculative *verify* path, where rejected draft tokens share the dispatch
    with real tokens and must not evict them from expert slots — per-token
    decode (cap >= top_k at t=1) never drops, so a verify pass that drops
    would break greedy token-identity with the baseline loop."""
    from repro.runtime.sharding import current_ctx

    bsz, s, d = x_star.shape
    e, k = cfg.n_experts, cfg.top_k
    t = bsz * s

    # Router consumes the fused (x*, sigma^{-1}) pair like any linear (C3).
    logits = engine.linear(p["router"], x_star, phase, row_scale=sig_inv)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).reshape(t, e)
    probs = constrain(probs, ("batch", None))

    # Expert FFN inputs must be actually normalized: apply sigma^{-1} once
    # here (cheap vs riding row scales through the dispatch permutation).
    x = x_star if sig_inv is None else (
        x_star * sig_inv[..., None]).astype(x_star.dtype)
    x = constrain(x.reshape(t, d), ("batch", None))

    gates, idx = jax.lax.top_k(probs, k)                   # [T, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Load-balance auxiliary loss (standard switch-style).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * e * cfg.router_aux_coef

    ctx = current_ctx()
    if ctx is not None and phase == "train":
        # Expert-parallel dispatch (shard_map over the DP axes) assumes
        # data-sharded activation rows: T divisible, per-shard capacity.
        # Serving traces (prefill/decode under the sharded engine) run the
        # local dispatch instead — batches are tiny/replicated, per-shard
        # capacity would break greedy identity with the single-device
        # engine, and GSPMD still tensor-shards the expert FFN einsums over
        # the 'experts'/'mlp' axes of the weights.
        y = _moe_core_sharded(x, idx, gates, p["experts"], cfg, *ctx,
                              no_drop=no_drop)
    else:
        cap = (t if no_drop
               else _round_cap(int(t * k / e * cfg.capacity_factor) + 1))
        wg = _expert_weight(p["experts"], "wg")
        wi = _expert_weight(p["experts"], "wi")
        wo = _expert_weight(p["experts"], "wo")
        y = _moe_core_local(x, idx, gates, wg, wi, wo, e, cap)

    if cfg.n_shared_experts:
        y = y.astype(jnp.float32) + mlp_apply(
            p["shared"], x_star, sig_inv, engine, phase
        ).reshape(t, d).astype(jnp.float32)
    return y.reshape(bsz, s, d).astype(x_star.dtype), aux
