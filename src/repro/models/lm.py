"""Decoder LM / encoder-decoder assembly for every assigned architecture.

One generic stack with per-family blocks, scanned over layers (compact HLO,
fast multi-pod compiles), with three entry points matching the workload cells:

    forward_train         — full-sequence teacher forcing, loss (train_4k)
    forward_prefill       — full-sequence, returns last-token logits + warm
                            caches (prefill_32k; also the LISO prompt phase);
                            ``batch['prompt_len']`` switches on bucketed
                            pad-and-mask mode (serving admission ladder)
    forward_prefill_chunk — [B, C] tokens appended into a warm cache at a
                            traced offset (the sequencer's chunk-granular
                            LISO admissions; serving/engine.py paces it)
    forward_decode        — one token with warm caches (decode_32k /
                            long_500k; the SILO generation phase)

The HSA engine (C1) routes every matmul; norms use fused emission (C3); the
decode path drives a single model-level online-RoPE unit (C4) shared by all
layers, exactly like the paper's PPU owns one RoPE unit.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import kvq
from repro.core import online_rope as orp
from repro.core.hsa import HSAEngine
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import mlp as M
from repro.models import retnet as R
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.modules import ParamBuilder, stack_layers
from repro.runtime.sharding import constrain, constrain_tree, current_ctx

Params = dict[str, Any]


def _constrain_cache(cache: Params, cfg: ModelConfig) -> Params:
    """Pin a decode-cache pytree onto the active mesh policy (`cache_axes`).

    A no-op outside a `sharding_ctx` — the single-device serving path and
    the scheduler's vmapped per-lane steps (which trace without a context)
    pay nothing.  Inside the sharded engine's traces this is what keeps the
    cache on-mesh across prefill chunks, the fused decode while_loop carry,
    and speculative verify/rollback, instead of silently replicating.
    """
    if current_ctx() is None:
        return cache
    return constrain_tree(cache, cache_axes(cfg))


# ---------------------------------------------------------------------------
# Layer groups: homogeneous runs of blocks that can share one lax.scan.
# ---------------------------------------------------------------------------


def layer_groups(cfg: ModelConfig) -> list[tuple[str, int, str]]:
    if cfg.family == "moe" and cfg.first_dense_layers:
        return [("dense_head", cfg.first_dense_layers, "dense"),
                ("blocks", cfg.n_layers - cfg.first_dense_layers, "moe")]
    kind = {"dense": "dense", "moe": "moe", "ssm": "ssm", "hybrid": "hybrid",
            "retnet": "retnet", "vlm": "dense", "audio": "dense"}.get(cfg.family)
    if cfg.is_encdec:
        return [("enc_blocks", cfg.encoder_layers, "enc"),
                ("blocks", cfg.n_layers, "dec")]
    return [("blocks", cfg.n_layers, kind)]


def hybrid_full_attn_flags(cfg: ModelConfig, count: int) -> jax.Array:
    """Hymba: full attention on first/middle/last layer, SWA elsewhere."""
    idx = jnp.arange(count)
    full = (idx == 0) | (idx == count // 2) | (idx == count - 1)
    return full


# ---------------------------------------------------------------------------
# Block init/apply per kind
# ---------------------------------------------------------------------------


def _block_init(b: ParamBuilder, cfg: ModelConfig, kind: str) -> None:
    L.norm_init(b, "ln1", cfg.d_model, cfg)
    if kind == "ssm":
        S.mamba_init(b.child("mamba"), cfg)
        return
    if kind == "retnet":
        R.retention_init(b.child("ret"), cfg)
        L.norm_init(b, "ln2", cfg.d_model, cfg)
        M.mlp_init(b.child("mlp"), cfg, gated=False)
        return
    if kind == "hybrid":
        L.gqa_init(b.child("attn"), cfg)
        S.mamba_init(b.child("mamba"), cfg)
        L.norm_init(b, "attn_norm", cfg.d_model, cfg)
        L.norm_init(b, "mamba_norm", cfg.d_model, cfg)
        L.norm_init(b, "ln2", cfg.d_model, cfg)
        M.mlp_init(b.child("mlp"), cfg)
        return
    # attention families
    if cfg.attn_type == "mla":
        L.mla_init(b.child("attn"), cfg)
    else:
        L.gqa_init(b.child("attn"), cfg)
    if kind == "dec":
        L.norm_init(b, "ln_cross", cfg.d_model, cfg)
        L.gqa_init(b.child("cross"), cfg)
    L.norm_init(b, "ln2", cfg.d_model, cfg)
    if kind == "moe":
        M.moe_init(b.child("moe"), cfg)
    else:
        M.mlp_init(b.child("mlp"), cfg, gated=cfg.norm_type == "rmsnorm")


def _block_apply(p: Params, x: jax.Array, cfg: ModelConfig, engine: HSAEngine,
                 phase: str, kind: str, *, rope=None, full_attn=None,
                 enc_kv=None, cache_len: int = 0, valid_len=None
                 ) -> tuple[jax.Array, Params | None, jax.Array]:
    """Full-sequence block.  Returns (x_out, cache_seed, aux_loss).

    ``valid_len`` (traced i32 scalar) marks a bucketed prefill: tokens at
    positions >= valid_len are padding.  Causality already keeps them out of
    every real token's *output*; the recurrent/conv/ring cache seeds
    additionally mask them so decode continues from the real prompt end.
    """
    sin, cos = rope if rope is not None else (None, None)
    aux = jnp.float32(0.0)
    xs, sig = L.norm_emit(p["ln1"], x, engine, cfg)

    if kind == "ssm":
        y, cache = S.mamba_apply(p["mamba"], xs, sig, engine, phase, cfg,
                                 valid_len=valid_len)
        return x + y, cache, aux

    if kind == "retnet":
        y, cache = R.retention_apply(p["ret"], xs, sig, engine, phase, cfg,
                                     rope_sin=sin, rope_cos=cos,
                                     valid_len=valid_len)
        x = x + y
        xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
        x = x + M.mlp_apply(p["mlp"], xs2, sig2, engine, phase)
        return x, cache, aux

    if kind == "hybrid":
        s = x.shape[1]
        window = jnp.where(full_attn, jnp.int32(s), jnp.int32(cfg.sliding_window))
        a_out, (k, v) = L.gqa_apply(p["attn"], xs, sig, engine, phase, cfg,
                                    causal=True, window=window,
                                    rope_sin=sin, rope_cos=cos)
        m_out, m_cache = S.mamba_apply(p["mamba"], xs, sig, engine, phase, cfg,
                                       valid_len=valid_len)
        y = 0.5 * (L.norm_full(p["attn_norm"], a_out, cfg)
                   + L.norm_full(p["mamba_norm"], m_out, cfg))
        x = x + y
        xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
        x = x + M.mlp_apply(p["mlp"], xs2, sig2, engine, phase)
        cache = {"attn": _seed_attn_cache(cfg, k, v, cache_len,
                                          valid_len=valid_len),
                 "mamba": m_cache}
        return x, cache, aux

    # attention families (dense / moe / enc / dec)
    causal = kind != "enc"
    if cfg.attn_type == "mla":
        a_out, (c_kv, k_rope) = L.mla_apply(p["attn"], xs, sig, engine, phase,
                                            cfg, rope_sin=sin, rope_cos=cos)
        if cache_len > c_kv.shape[1]:
            pad = ((0, 0), (0, cache_len - c_kv.shape[1]), (0, 0))
            c_kv, k_rope = jnp.pad(c_kv, pad), jnp.pad(k_rope, pad)
        cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        a_out, (k, v) = L.gqa_apply(p["attn"], xs, sig, engine, phase, cfg,
                                    causal=causal,
                                    window=cfg.sliding_window,
                                    rope_sin=sin, rope_cos=cos)
        cache = (_seed_attn_cache(cfg, k, v, cache_len, valid_len=valid_len)
                 if causal else None)
    x = x + a_out

    if kind == "dec":
        if enc_kv is None:
            raise TypeError("decoder blocks need encoder output")
        xc, sigc = L.norm_emit(p["ln_cross"], x, engine, cfg)
        c_out, (ck, cv) = _cross_from_enc(p["cross"], xc, sigc, engine, phase,
                                          cfg, enc_kv)
        x = x + c_out
        cache = {"self": cache, "cross_k": ck, "cross_v": cv}

    xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
    if kind == "moe":
        y, aux = M.moe_apply(p["moe"], xs2, sig2, engine, phase, cfg)
    else:
        y = M.mlp_apply(p["mlp"], xs2, sig2, engine, phase)
    return x + y, cache, aux


def _cross_from_enc(p, xc, sigc, engine, phase, cfg, enc_out):
    """Cross-attention: q from decoder, k/v projected from encoder output."""
    b, s_src, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    k = engine.linear(p["wk"], enc_out, phase).reshape(b, s_src, kv, hd)
    v = engine.linear(p["wv"], enc_out, phase).reshape(b, s_src, kv, hd)
    out, _ = L.gqa_apply(p, xc, sigc, engine, phase, cfg, causal=False,
                         kv_override=(k, v))
    return out, (k, v)


def _seed_attn_cache(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                     cache_len: int = 0, valid_len=None) -> Params:
    """Convert prefill K/V into the decode cache layout.

    Sliding-window caches are ring buffers keyed by ``pos % window``: the last
    `window` entries are rolled so each position p lands in slot p %% window.
    Linear caches are right-padded to `cache_len` so generation can continue.

    ``valid_len`` (traced, bucketed prefill) builds the ring from the *real*
    prompt only: slot i gets the key at the largest real position ≡ i mod w.
    Padded keys must never enter the ring — they would alias (overwrite)
    still-windowed real positions once the ring wraps.  Linear caches keep
    their padded tail: decode starts writing at ``pos = valid_len`` and its
    validity mask hides every not-yet-overwritten junk slot.
    """
    s = k.shape[1]
    if cfg.sliding_window:
        w = cfg.sliding_window
        if valid_len is not None:
            i = jnp.arange(w)
            # Largest real position p <= valid_len-1 with p % w == i.
            p = i + w * ((valid_len - 1 - i) // w)
            keep = (p >= 0)[None, :, None, None]
            pc = jnp.clip(p, 0, s - 1)
            k = jnp.where(keep, k[:, pc], 0)
            v = jnp.where(keep, v[:, pc], 0)
        elif s <= w:
            pad = [(0, 0), (0, w - s)] + [(0, 0)] * (k.ndim - 2)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)   # slot i = position i
        else:
            k, v = k[:, -w:], v[:, -w:]               # positions s-w .. s-1
            shift = s % w                             # slot of position p = p % w
            k, v = jnp.roll(k, shift, axis=1), jnp.roll(v, shift, axis=1)
    elif cache_len > s:
        pad = [(0, 0), (0, cache_len - s)] + [(0, 0)] * (k.ndim - 2)
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Decode-step block
# ---------------------------------------------------------------------------


def _block_decode(p: Params, x: jax.Array, cfg: ModelConfig, engine: HSAEngine,
                  kind: str, cache: Params, pos: jax.Array, *,
                  rope=None) -> tuple[jax.Array, Params]:
    sin, cos = rope if rope is not None else (None, None)
    xs, sig = L.norm_emit(p["ln1"], x, engine, cfg)

    if kind == "ssm":
        y, cache = S.mamba_decode(p["mamba"], xs, sig, engine, cfg, cache)
        return x + y, cache

    if kind == "retnet":
        y, cache = R.retention_decode(p["ret"], xs, sig, engine, cfg, cache,
                                      rope_sin=sin, rope_cos=cos)
        x = x + y
        xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
        return x + M.mlp_apply(p["mlp"], xs2, sig2, engine, "decode"), cache

    if kind == "hybrid":
        a_out, a_cache = L.gqa_decode(p["attn"], xs, sig, engine, cfg,
                                      cache["attn"], pos,
                                      window=cfg.sliding_window,
                                      rope_sin=sin, rope_cos=cos)
        m_out, m_cache = S.mamba_decode(p["mamba"], xs, sig, engine, cfg,
                                        cache["mamba"])
        y = 0.5 * (L.norm_full(p["attn_norm"], a_out, cfg)
                   + L.norm_full(p["mamba_norm"], m_out, cfg))
        x = x + y
        xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
        x = x + M.mlp_apply(p["mlp"], xs2, sig2, engine, "decode")
        return x, {"attn": a_cache, "mamba": m_cache}

    if cfg.attn_type == "mla":
        a_out, new_cache = L.mla_decode(p["attn"], xs, sig, engine, cfg,
                                        cache if kind != "dec" else cache["self"],
                                        pos, rope_sin=sin, rope_cos=cos)
    else:
        self_cache = cache if kind != "dec" else cache["self"]
        a_out, new_cache = L.gqa_decode(p["attn"], xs, sig, engine, cfg,
                                        self_cache, pos,
                                        window=cfg.sliding_window,
                                        rope_sin=sin, rope_cos=cos)
    x = x + a_out

    if kind == "dec":
        xc, sigc = L.norm_emit(p["ln_cross"], x, engine, cfg)
        b = x.shape[0]
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        q = engine.linear(p["cross"]["wq"], xc, "decode", row_scale=sigc)
        q = q.reshape(b, h, hd).reshape(b, kv, h // kv, hd)
        # Cross K/V is a fixed-length full-valid cache: kv_len = capacity.
        src = L.cache_capacity(cache["cross_k"])
        c_out = kops.flash_decode(q, cache["cross_k"], cache["cross_v"],
                                  jnp.int32(src),
                                  impl=engine.config.kernel_impl)
        c_out = engine.linear(p["cross"]["wo"], c_out.reshape(b, 1, h * hd),
                              "decode")
        x = x + c_out
        new_cache = {"self": new_cache, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}

    xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
    if kind == "moe":
        y, _ = M.moe_apply(p["moe"], xs2, sig2, engine, "decode", cfg)
    else:
        y = M.mlp_apply(p["mlp"], xs2, sig2, engine, "decode")
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key: jax.Array, abstract: bool = False):
    """Returns (params, axes, linear_paths).

    ``abstract=True`` records ShapeDtypeStructs instead of sampling — the
    dry-run path: full-model structure with zero allocation.
    """
    dtype = jnp.dtype(cfg.param_dtype)
    b = ParamBuilder(key=key, dtype=dtype, abstract=abstract)
    b.param("embed", (cfg.padded_vocab, cfg.d_model), (None, "embed_tp"),
            scale=0.02)
    all_paths: list[tuple[str, ...]] = []

    for gname, count, kind in layer_groups(cfg):
        stacked, axes_g, paths = stack_layers(
            b._next_key(), count,
            functools.partial(_block_init, cfg=cfg, kind=kind), dtype=dtype,
            abstract=abstract)
        b.params[gname] = stacked
        b.axes[gname] = axes_g
        all_paths += [(gname,) + p for p in paths]

    L.norm_init(b, "final_norm", cfg.d_model, cfg)
    if cfg.is_encdec:
        L.norm_init(b, "enc_final_norm", cfg.d_model, cfg)
    b.linear("lm_head", cfg.d_model, cfg.padded_vocab, "embed", "vocab",
             scale=0.02)
    if cfg.mtp:
        mtp = b.child("mtp")
        mtp.linear("proj", 2 * cfg.d_model, cfg.d_model, "embed", "embed")
        _block_init(mtp.child("block"), cfg,
                    "moe" if cfg.family == "moe" else "dense")
        all_paths += [p for p in b.linear_paths if p[0] == "mtp"]

    all_paths += [p for p in b.linear_paths if p[0] == "lm_head"]
    return b.params, b.axes, all_paths


# ---------------------------------------------------------------------------
# Shared forward plumbing
# ---------------------------------------------------------------------------


def _rope_dim(cfg: ModelConfig) -> int:
    if cfg.attn_type == "mla":
        return cfg.qk_rope_head_dim
    if cfg.family == "retnet":
        return cfg.d_model // cfg.n_heads
    return cfg.head_dim_


def _rope_tables(cfg: ModelConfig, s: int):
    if not cfg.rope:
        return None
    th = orp.rope_thetas(_rope_dim(cfg), cfg.rope_base)
    sin, cos = orp.rope_table(jnp.arange(s), th)
    return sin, cos


def _embed(params: Params, batch: Params, cfg: ModelConfig) -> jax.Array:
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        p = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([p, x[:, p.shape[1]:]], axis=1)
    if cfg.abs_pos_embed:
        x = x + _sinusoidal(jnp.arange(x.shape[1]), cfg.d_model)[None].astype(x.dtype)
    return x


def _sinusoidal(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal absolute position embeddings; `pos` may be traced (decode)."""
    pos = pos.astype(jnp.float32)[..., None]
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _run_group(params, gname, count, kind, x, cfg, engine, phase, rope,
               enc_kv=None, remat: bool = True, cache_len: int = 0,
               valid_len=None):
    """Scan one homogeneous layer group over the sequence-major activations."""
    flags = (hybrid_full_attn_flags(cfg, count) if kind == "hybrid"
             else jnp.zeros(count, bool))

    def body(carry, per_layer):
        xc, aux_acc = carry
        pl, flag = per_layer
        # Sequence-parallel residual stream: the scan carry (= the per-layer
        # activation saved for remat) shards over the TP axis.  No-op without
        # an active sharding context.
        xc = constrain(xc, ("batch", "seq", None))
        y, cache, aux = _block_apply(pl, xc, cfg, engine, phase, kind,
                                     rope=rope, full_attn=flag, enc_kv=enc_kv,
                                     cache_len=cache_len, valid_len=valid_len)
        y = y.astype(xc.dtype)     # keep the residual stream in param dtype
        if phase == "train":
            cache = None       # don't materialize per-layer K/V during training
        return (y, aux_acc + aux), cache

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if (remat and phase == "train") else body
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                                    (params[gname], flags))
    return x, aux, caches


def _encode(params, batch, cfg, engine, phase):
    src = batch["src_embeds"].astype(jnp.dtype(cfg.param_dtype))
    src = src + _sinusoidal(jnp.arange(src.shape[1]),
                            cfg.d_model)[None].astype(src.dtype)
    x, _, _ = _run_group(params, "enc_blocks", cfg.encoder_layers, "enc",
                         src, cfg, engine, phase, rope=None, remat=False)
    return L.norm_full(params["enc_final_norm"], x, cfg)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def forward_train(params: Params, batch: Params, cfg: ModelConfig,
                  engine: HSAEngine) -> tuple[jax.Array, Params]:
    """Teacher-forced loss.  batch: tokens/labels [B,S] (+frontend tensors)."""
    x = _embed(params, batch, cfg)
    s = x.shape[1]
    rope = _rope_tables(cfg, s)
    enc_kv = _encode(params, batch, cfg, engine, "train") if cfg.is_encdec else None

    aux_total = jnp.float32(0.0)
    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        x, aux, _ = _run_group(params, gname, count, kind, x, cfg, engine,
                               "train", rope, enc_kv=enc_kv)
        aux_total += aux

    h = L.norm_full(params["final_norm"], x, cfg)
    logits = engine.linear(params["lm_head"], h, "train")
    loss, n_tok = _xent(logits, batch["labels"], cfg)

    if cfg.mtp and "labels" in batch:
        loss = loss + 0.3 * _mtp_loss(params, x, batch, cfg, engine)

    metrics = {"loss": loss, "aux_loss": aux_total, "tokens": n_tok}
    return loss + aux_total, metrics


def _xent(logits: jax.Array, labels: jax.Array, cfg: ModelConfig):
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, lse - gold, 0.0)
    n = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(nll) / n, n


def _mtp_loss(params, x, batch, cfg, engine):
    """DeepSeek-V3 depth-1 MTP: predict token t+2 from [h_t ; emb(tok_{t+1})]."""
    emb_next = params["embed"][batch["tokens"]][:, 1:]
    h_in = jnp.concatenate([x[:, :-1], emb_next], axis=-1)
    h = engine.linear(params["mtp"]["proj"], h_in, "train")
    h, _, _ = _block_apply(params["mtp"]["block"], h, cfg, engine, "train",
                           "moe" if cfg.family == "moe" else "dense",
                           rope=_rope_tables(cfg, h.shape[1]))
    h = L.norm_full(params["final_norm"], h, cfg)
    logits = engine.linear(params["lm_head"], h, "train")
    labels2 = jnp.pad(batch["labels"][:, 2:], ((0, 0), (0, 1)),
                      constant_values=-1)[:, :h.shape[1]]
    loss, _ = _xent(logits, labels2, cfg)
    return loss


def mtp_decode_step(params: Params, h: jax.Array, tok: jax.Array,
                    cfg: ModelConfig, engine: HSAEngine
                    ) -> tuple[jax.Array, jax.Array]:
    """One depth step of the deepseek-v3 MTP head at *decode* time.

    The training loss `_mtp_loss` predicts token t+2 from ``[x_t ;
    emb(tok_{t+1})]``; speculative decode chains the same head as a draft
    model: ``h`` [B, D] is the pre-final-norm hidden at the last committed
    position, ``tok`` [B] the (pending or previously drafted) next token.
    Returns (draft logits [B, V], the head's hidden [B, D] to chain deeper).
    Single-position causal attention needs no cache or RoPE state — a
    position attends only to itself, and same-position rotations cancel in
    q·k.  Draft quality is the only thing at stake: verification against the
    target model makes any drafter sound.
    """
    emb = params["embed"][tok].astype(h.dtype)
    h_in = jnp.concatenate([h, emb], axis=-1)[:, None, :]
    hx = engine.linear(params["mtp"]["proj"], h_in, "decode")
    hx, _, _ = _block_apply(params["mtp"]["block"], hx, cfg, engine, "decode",
                            "moe" if cfg.family == "moe" else "dense")
    hn = L.norm_full(params["final_norm"], hx, cfg)
    logits = engine.linear(params["lm_head"], hn, "decode")[:, 0]
    return logits, hx[:, 0]


def forward_prefill(params: Params, batch: Params, cfg: ModelConfig,
                    engine: HSAEngine, cache_len: int = 0,
                    return_hidden: bool = False):
    """Prompt processing (MMM phase).  Returns (last logits [B,V], cache).

    `cache_len` > prompt length reserves KV slots for subsequent decoding.
    ``return_hidden`` appends the pre-final-norm hidden state of the last
    real token ([B, D]) to the return — the MTP self-speculation drafter
    chains its depth-1 head from it.

    Bucketed mode: if ``batch['prompt_len']`` (traced i32 scalar) is present,
    the token array is treated as a prompt of that length right-padded to the
    compiled bucket size.  Causality hides the pad from every real token;
    recurrent/conv/ring cache seeds mask it explicitly (see `_block_apply`);
    logits are taken at the last *real* token and the cache's ``pos``/RoPE
    state start there — so K distinct prompt lengths share one compile per
    bucket instead of one per length.
    """
    x = _embed(params, batch, cfg)
    b, s, _ = x.shape
    valid_len = batch.get("prompt_len")
    if valid_len is not None and cfg.is_encdec:
        raise NotImplementedError("bucketed prefill: encoder-decoder models "
                                  "prefill at exact length")
    rope = _rope_tables(cfg, s)
    enc_kv = _encode(params, batch, cfg, engine, "prefill") if cfg.is_encdec else None

    caches = {}
    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        x, _, cache = _run_group(params, gname, count, kind, x, cfg, engine,
                                 "prefill", rope, enc_kv=enc_kv, remat=False,
                                 cache_len=cache_len, valid_len=valid_len)
        caches[gname] = cache

    if valid_len is None:
        last = x[:, -1:]
        pos = jnp.int32(s)
    else:
        last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
        pos = jnp.asarray(valid_len, jnp.int32)
    h = L.norm_full(params["final_norm"], last, cfg)
    logits = engine.linear(params["lm_head"], h, "prefill")[:, 0]

    caches["pos"] = pos
    if cfg.rope:
        caches["rope"] = orp.init_state(_rope_dim(cfg), cfg.rope_base, pos=pos)
    caches = _constrain_cache(caches, cfg)
    if return_hidden:
        return logits, caches, last[:, 0]
    return logits, caches


def _block_chunk(p: Params, x: jax.Array, cfg: ModelConfig, engine: HSAEngine,
                 kind: str, cache: Params, pos: jax.Array, *, rope=None,
                 full_attn=None, collect: bool = False
                 ) -> tuple[jax.Array, Params]:
    """One chunked-prefill block: [B, C] tokens continuing a warm cache.

    The MMM-shaped sibling of `_block_decode`: same per-layer cache-in /
    cache-out contract, but C tokens at once through the prefill dataflow.
    ``collect`` (speculative verify) makes the recurrent sub-blocks snapshot
    their state after every position (see `commit_verified_cache`).
    """
    sin, cos = rope if rope is not None else (None, None)
    xs, sig = L.norm_emit(p["ln1"], x, engine, cfg)

    if kind == "ssm":
        y, cache = S.mamba_apply(p["mamba"], xs, sig, engine, "prefill", cfg,
                                 cache=cache, collect_states=collect)
        return x + y, cache

    if kind == "retnet":
        y, cache = R.retention_apply(p["ret"], xs, sig, engine, "prefill",
                                     cfg, rope_sin=sin, rope_cos=cos,
                                     cache=cache, collect_states=collect)
        x = x + y
        xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
        return x + M.mlp_apply(p["mlp"], xs2, sig2, engine, "prefill"), cache

    if kind == "hybrid":
        c = x.shape[1]
        # Full-attention layers see the whole resident prefix (the ring bounds
        # it to the last `window` positions — the same degradation decode
        # applies; exact whenever the prompt fits the window).
        window = jnp.where(full_attn, pos + jnp.int32(c),
                           jnp.int32(cfg.sliding_window))
        a_out, a_cache = L.gqa_chunk(p["attn"], xs, sig, engine, cfg,
                                     cache["attn"], pos, window=window,
                                     rope_sin=sin, rope_cos=cos)
        m_out, m_cache = S.mamba_apply(p["mamba"], xs, sig, engine, "prefill",
                                       cfg, cache=cache["mamba"],
                                       collect_states=collect)
        y = 0.5 * (L.norm_full(p["attn_norm"], a_out, cfg)
                   + L.norm_full(p["mamba_norm"], m_out, cfg))
        x = x + y
        xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
        x = x + M.mlp_apply(p["mlp"], xs2, sig2, engine, "prefill")
        return x, {"attn": a_cache, "mamba": m_cache}

    if cfg.attn_type == "mla":
        a_out, new_cache = L.mla_chunk(p["attn"], xs, sig, engine, cfg, cache,
                                       pos, rope_sin=sin, rope_cos=cos)
    else:
        a_out, new_cache = L.gqa_chunk(p["attn"], xs, sig, engine, cfg, cache,
                                       pos, window=cfg.sliding_window,
                                       rope_sin=sin, rope_cos=cos)
    x = x + a_out

    xs2, sig2 = L.norm_emit(p["ln2"], x, engine, cfg)
    if kind == "moe":
        # collect = speculative verify: rejected draft tokens share this
        # dispatch with real ones and must not evict them from expert slots.
        y, _ = M.moe_apply(p["moe"], xs2, sig2, engine, "prefill", cfg,
                           no_drop=collect)
    else:
        y = M.mlp_apply(p["mlp"], xs2, sig2, engine, "prefill")
    return x + y, new_cache


def forward_prefill_chunk(params: Params, batch: Params, cache: Params,
                          cfg: ModelConfig, engine: HSAEngine
                          ) -> tuple[jax.Array, Params]:
    """Chunked prefill (MMM phase over a warm cache).

    Processes ``batch['tokens']`` [B, C] as a continuation of ``cache`` —
    absolute positions ``cache['pos'] .. cache['pos']+C-1`` — and returns
    (last-token logits [B, V], advanced cache).  Because the offset rides in
    the cache as a traced scalar, every chunk of the same length C shares one
    compile: the sequencer admits a 750-token LISO prompt as a handful of
    cached chunk shapes instead of one monolithic per-length trace.

    Chunks are exact (never padded): the engine decomposes a prompt into
    ladder-sized chunks, so recurrent (RetNet/SSM) state needs no pad
    correction here.
    """
    x, new_cache = _chunk_stack(params, batch, cache, cfg, engine)
    h = L.norm_full(params["final_norm"], x[:, -1:], cfg)
    logits = engine.linear(params["lm_head"], h, "prefill")[:, 0]
    return logits, _constrain_cache(new_cache, cfg)


def _chunk_stack(params: Params, batch: Params, cache: Params,
                 cfg: ModelConfig, engine: HSAEngine, collect: bool = False
                 ) -> tuple[jax.Array, Params]:
    """Shared chunk-continuation body: run [B, C] tokens against a warm cache
    and return (pre-final-norm activations [B, C, D], advanced cache)."""
    if cfg.is_encdec:
        raise NotImplementedError("chunked prefill: encoder-decoder models "
                                  "prefill monolithically")
    if cfg.frontend:
        raise NotImplementedError("chunked prefill: frontend (vision/audio) "
                                  "prompts splice patch embeddings — prefill "
                                  "monolithically")
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    c = x.shape[1]
    pos0 = cache["pos"]
    positions = pos0 + jnp.arange(c)
    if cfg.abs_pos_embed:
        x = x + _sinusoidal(positions, cfg.d_model)[None].astype(x.dtype)

    rope = None
    if cfg.rope:
        th = orp.rope_thetas(_rope_dim(cfg), cfg.rope_base)
        rope = orp.rope_table(positions, th)

    new_cache: Params = {"pos": pos0 + jnp.int32(c)}
    if cfg.rope:
        new_cache["rope"] = orp.init_state(_rope_dim(cfg), cfg.rope_base,
                                           pos=pos0 + jnp.int32(c))

    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        flags = (hybrid_full_attn_flags(cfg, count) if kind == "hybrid"
                 else jnp.zeros(count, bool))

        def body(xc, per_layer, kind=kind):
            pl, cl, flag = per_layer
            y, c2 = _block_chunk(pl, xc, cfg, engine, kind, cl, pos0,
                                 rope=rope, full_attn=flag, collect=collect)
            return y.astype(xc.dtype), c2

        x, new_g = jax.lax.scan(body, x, (params[gname], cache[gname], flags))
        new_cache[gname] = new_g
    return x, new_cache


def forward_verify_chunk(params: Params, batch: Params, cache: Params,
                         cfg: ModelConfig, engine: HSAEngine
                         ) -> tuple[jax.Array, jax.Array, Params]:
    """Speculative verify: score a [B, C] draft block in one MMM dispatch.

    The chunked-prefill machinery already appends C tokens into a warm cache
    at a traced offset; verify reuses it with two differences: (1) the LM
    head runs at *every* chunk position — logits[:, i] is the target
    distribution for the token after draft position i, which is what
    accept/reject compares against — and (2) recurrent sub-blocks snapshot
    their state per position (``s_all`` / ``h_all`` / ``conv_ext``) so
    `commit_verified_cache` can roll the cache back to exactly the accepted
    prefix.  Also returns the pre-final-norm hidden states [B, C, D] (the
    MTP drafter chains from the hidden at the acceptance boundary).
    """
    x, new_cache = _chunk_stack(params, batch, cache, cfg, engine,
                                collect=True)
    h = L.norm_full(params["final_norm"], x, cfg)
    logits = engine.linear(params["lm_head"], h, "prefill")
    # State snapshots (`s_all`/`h_all`/`conv_ext`) have no cache_axes entry
    # and pass through unconstrained; `commit_verified_cache` pins the
    # committed cache it derives from them.
    return logits, x, _constrain_cache(new_cache, cfg)


def commit_verified_cache(prev: Params, ver: Params, n_accept: jax.Array,
                          c: int, cfg: ModelConfig) -> Params:
    """Roll a verified cache back to its accepted prefix (speculative decode).

    ``ver`` is `forward_verify_chunk`'s cache after appending a ``c``-token
    draft block at ``prev['pos']``; ``n_accept`` (traced, 1..c) is how many
    of those tokens the target model accepted.  Per cache kind:

      * linear KV / MLA latents — keep the verified buffers and *rewind the
        position pointer*: decode's validity mask hides the rejected rows and
        the next block overwrites them.
      * sliding-window rings — rejected writes aliased live history; restore
        those slots from the pre-verify ring (`layers.ring_rollback`).
      * RetNet retention state / Mamba h+conv — recurrent state can't be
        un-stepped cheaply, so select the per-position snapshot the verify
        pass collected at the acceptance boundary.

    Leaves carry a leading stacked-layer axis (position axis = 2), matching
    `forward_prefill_chunk`'s cache layout.
    """
    pos0 = prev["pos"]
    new_pos = pos0 + jnp.asarray(n_accept, jnp.int32)
    out: Params = {"pos": new_pos}
    if cfg.rope:
        out["rope"] = orp.init_state(_rope_dim(cfg), cfg.rope_base,
                                     pos=new_pos)

    def at_boundary(x):                       # [L, B, C, ...] -> [L, B, ...]
        return jax.lax.dynamic_index_in_dim(x, n_accept - 1, axis=2,
                                            keepdims=False)

    def mamba_commit(g):
        cw = cfg.conv_width
        conv = jax.lax.dynamic_slice_in_dim(g["conv_ext"], n_accept,
                                            cw - 1, axis=2)
        return {"h": at_boundary(g["h_all"]), "conv": conv}

    def attn_commit(prev_g, ver_g):
        if cfg.sliding_window:
            return L.ring_rollback(prev_g, ver_g, pos0, c, n_accept,
                                   cfg.sliding_window)
        return ver_g                          # linear: pointer rewind only

    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        if kind == "retnet":
            out[gname] = {"s": at_boundary(ver[gname]["s_all"])}
        elif kind == "ssm":
            out[gname] = mamba_commit(ver[gname])
        elif kind == "hybrid":
            out[gname] = {
                "attn": attn_commit(prev[gname]["attn"], ver[gname]["attn"]),
                "mamba": mamba_commit(ver[gname]["mamba"]),
            }
        elif cfg.attn_type == "mla":
            out[gname] = {"c_kv": ver[gname]["c_kv"],
                          "k_rope": ver[gname]["k_rope"]}
        else:
            out[gname] = attn_commit(prev[gname], ver[gname])
    return _constrain_cache(out, cfg)


def forward_decode(params: Params, tokens: jax.Array, cache: Params,
                   cfg: ModelConfig, engine: HSAEngine
                   ) -> tuple[jax.Array, Params]:
    """One generation step (MVM phase).  tokens [B, 1]."""
    x = params["embed"][tokens]
    pos = cache["pos"]
    if cfg.abs_pos_embed:
        x = x + _sinusoidal(pos[None], cfg.d_model)[None].astype(x.dtype)

    rope = None
    new_cache: Params = {"pos": pos + 1}
    if cfg.rope:
        st: orp.OnlineRopeState = cache["rope"]
        rope = (st.sin, st.cos)                      # C4 Embed mode
        th = orp.rope_thetas(_rope_dim(cfg), cfg.rope_base)
        new_cache["rope"] = orp.advance(st, th)      # C4 Update mode

    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        dkind = "dec" if kind == "dec" else kind

        def body(xc, per_layer):
            pl, cl = per_layer
            y, c2 = _block_decode(pl, xc, cfg, engine, dkind, cl, pos, rope=rope)
            return y.astype(xc.dtype), c2

        x, new_g = jax.lax.scan(body, x, (params[gname], cache[gname]))
        new_cache[gname] = new_g

    h = L.norm_full(params["final_norm"], x, cfg)
    logits = engine.linear(params["lm_head"], h, "decode")[:, 0]
    return logits, _constrain_cache(new_cache, cfg)


def make_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, start_pos: int | None = None
                      ) -> Params:
    """Cold caches.  Default ``start_pos`` keeps the decode-only dry-run
    convention (pos = cache_len - 1); ``start_pos=0`` yields the empty cache
    a chunked prefill appends into (zeros are the exact initial state for
    every cache kind: KV rings, retention S, mamba h/conv).

    ``dtype`` may also be a quantized-cache format name (`core.kvq.FORMATS`):
    attention KV leaves become encoded dicts (`kvq.zeros`, bit-identical to
    encoding a zero cache) while recurrent state stays fp32."""
    pos = cache_len - 1 if start_pos is None else start_pos
    caches: Params = {"pos": jnp.int32(pos)}
    if cfg.rope:
        caches["rope"] = orp.init_state(_rope_dim(cfg), cfg.rope_base,
                                        pos=pos)

    def one_layer(kind):
        if kind == "ssm":
            return S.mamba_make_cache(cfg, batch)
        if kind == "retnet":
            return R.retention_make_cache(cfg, batch)
        if kind == "hybrid":
            return {"attn": L.gqa_make_cache(cfg, batch, cache_len, dtype),
                    "mamba": S.mamba_make_cache(cfg, batch)}
        if cfg.attn_type == "mla":
            c = L.mla_make_cache(cfg, batch, cache_len, dtype)
        else:
            c = L.gqa_make_cache(cfg, batch, cache_len, dtype)
        if kind == "dec":
            kv, hd = cfg.n_kv_heads, cfg.head_dim_
            src = cfg.frontend_tokens or cache_len
            return {"self": c,
                    "cross_k": L.make_cache_leaf((batch, src, kv, hd), dtype),
                    "cross_v": L.make_cache_leaf((batch, src, kv, hd), dtype)}
        return c

    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        caches[gname] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (count,) + x.shape), one_layer(kind))
    return caches


def quantize_cache(cache: Params, cfg: ModelConfig, fmt: str) -> Params:
    """Encode the attention KV leaves of a warm decode cache into ``fmt``.

    The bridge between monolithic prefill (always fp — one MMM dispatch has
    no bandwidth problem) and a quantized decode residency: the engine calls
    this once, right after `forward_prefill`, when the request's
    `GenerationConfig.cache_format` is set.  Chunked prefill instead appends
    into an already-encoded cache (`make_decode_cache(dtype=fmt)`), and
    row-local encoding makes the two paths bit-identical.

    Recurrent state (retention S, mamba h/conv), `pos` and rope angles pass
    through untouched — only the KV streams that decode re-reads every step
    are worth compressing.  Idempotent: already-encoded leaves pass through.
    """
    kvq.check_format(fmt)
    enc = lambda x: kvq.encode(x, fmt)

    def enc_self(g):
        if cfg.attn_type == "mla":
            return {"c_kv": enc(g["c_kv"]), "k_rope": enc(g["k_rope"])}
        return {"k": enc(g["k"]), "v": enc(g["v"])}

    out = dict(cache)
    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        g = cache[gname]
        if kind in ("ssm", "retnet"):
            continue
        if kind == "hybrid":
            out[gname] = {"attn": enc_self(g["attn"]), "mamba": g["mamba"]}
        elif kind == "dec":
            out[gname] = {"self": enc_self(g["self"]),
                          "cross_k": enc(g["cross_k"]),
                          "cross_v": enc(g["cross_v"])}
        else:
            out[gname] = enc_self(g)
    return out


def cache_axes(cfg: ModelConfig) -> Params:
    """Logical sharding axes mirroring `make_decode_cache` (runtime/sharding).

    'batch' shards over DP axes when divisible; 'cache' (the KV length axis)
    picks up the 'data' axis when batch fell through (long_500k, batch=1);
    'inner'/'kv'/'heads'/'mlp' ride the TP axis where divisible.

    Quantized caches need no extra entries: a KV leaf's tuple broadcasts over
    the encoded sub-dict (``{"q","s"}`` / ``{"m","e"}``) — every sub-leaf
    keeps the leaf's rank, with only the last (replicated) axis resized.
    """
    gqa_axes = {"k": ("layers", "batch", "cache", "kv", None),
                "v": ("layers", "batch", "cache", "kv", None)}
    mamba_axes = {"h": ("layers", "batch", "inner", None),
                  "conv": ("layers", "batch", None, "inner")}

    def one(kind):
        if kind == "ssm":
            return mamba_axes
        if kind == "retnet":
            return {"s": ("layers", "batch", "heads", None, "mlp")}
        if kind == "hybrid":
            return {"attn": gqa_axes, "mamba": mamba_axes}
        if cfg.attn_type == "mla":
            c = {"c_kv": ("layers", "batch", "cache", None),
                 "k_rope": ("layers", "batch", "cache", None)}
        else:
            c = gqa_axes
        if kind == "dec":
            return {"self": c,
                    "cross_k": ("layers", "batch", None, "kv", None),
                    "cross_v": ("layers", "batch", None, "kv", None)}
        return c

    axes: Params = {"pos": ()}
    if cfg.rope:
        axes["rope"] = None          # tiny angle memory: replicated
    for gname, count, kind in layer_groups(cfg):
        if kind == "enc":
            continue
        axes[gname] = one(kind)
    return axes


# ---------------------------------------------------------------------------
# Shared-prefix cache reuse (serving/paging.py builds on these).
# ---------------------------------------------------------------------------

# Cache kinds whose per-position rows are position-addressable: every leaf
# carries the cache axis at position 2 and row j is a pure function of
# (token_j, position j, params) — the property that makes token-prefix pages
# reusable across requests.  Ring buffers (sliding_window) alias positions
# mod window and recurrent state (retnet S, mamba h/conv) folds the whole
# history into O(1) slots, so neither can share *pages*; they snapshot whole
# cache states instead (`prefix_sharing_mode` returns 'snapshot').
_PAGEABLE_KINDS = frozenset({"dense", "moe"})


def prefix_sharing_mode(cfg: ModelConfig) -> str | None:
    """How this architecture can reuse a cached token prefix.

    'paged'    — every decode-cache group is position-addressable linear
                 attention (dense/moe GQA or MLA, no sliding window): token
                 prefixes map to immutable page runs sliceable on the cache
                 axis, adoptable at any boundary.
    'snapshot' — at least one group is a ring or recurrent state: pages
                 cannot represent it, but the *whole cache pytree* at a
                 finished prompt is a valid prefix state, adoptable at that
                 exact token boundary (the PR 3 verify-snapshot insight).
    None       — chunked prefill itself is unsupported (encoder-decoder /
                 frontend prompts prefill monolithically), so there is no
                 admission seam to adopt a prefix through.
    """
    if cfg.is_encdec or cfg.frontend:
        return None
    kinds = {kind for _, _, kind in layer_groups(cfg)}
    if kinds <= _PAGEABLE_KINDS and not cfg.sliding_window:
        return "paged"
    return "snapshot"


def prefix_page_groups(cfg: ModelConfig) -> list[str]:
    """Cache groups a page row covers (every group except pos/rope) — only
    meaningful when `prefix_sharing_mode(cfg) == 'paged'`."""
    if prefix_sharing_mode(cfg) != "paged":
        raise ValueError(f"{cfg.name}: cache is not pageable "
                         f"(mode={prefix_sharing_mode(cfg)!r})")
    return [gname for gname, _, kind in layer_groups(cfg) if kind != "enc"]


def slice_cache_rows(cache: Params, cfg: ModelConfig, start: int,
                     stop: int) -> Params:
    """Extract cache rows [start, stop) of every pageable group.

    Returns ``{gname: subtree}`` with each leaf sliced on the cache axis
    (axis 2 under the stacked-layer layout).  Quantized residency slices the
    encoded dict leaves identically — `core.kvq` formats encode along the
    *last* axis only, so a cache-axis slice of the ``q``/``s`` (or
    ``m``/``e``) planes is exactly the encoding of the sliced rows.
    """
    return {g: jax.tree.map(lambda x: x[:, :, start:stop], cache[g])
            for g in prefix_page_groups(cfg)}


def assemble_prefix_cache(cfg: ModelConfig, rows: Params, n_tokens: int,
                          cache_len: int, dtype) -> Params:
    """Build the warm batch-1 decode cache an adopted prefix resumes from.

    ``rows`` is `slice_cache_rows` output (possibly concatenated across
    pages) covering positions [0, n_tokens).  The scaffold comes from
    `make_decode_cache(start_pos=n_tokens)` — which sets ``pos`` and the
    online-RoPE angle state to exactly what a chunked prefill of those
    n_tokens leaves behind (`_chunk_stack` rebuilds rope functionally from
    ``pos`` each chunk) — and the page rows are scattered under it.  The
    result has the same pytree structure, shapes, and dtypes as a cold
    chunked-prefill cache, so the suffix chunks and the decode loop reuse
    the already-compiled executables (audit A8 pins this).
    """
    cache = make_decode_cache(cfg, 1, cache_len, dtype, start_pos=n_tokens)
    for g in prefix_page_groups(cfg):
        cache[g] = jax.tree.map(
            lambda full, r: full.at[:, :, :n_tokens].set(
                r.astype(full.dtype)), cache[g], rows[g])
    return cache
