"""Model zoo substrate: every assigned architecture family, built on the core
library (HSA engine for all linears, fused RMSNorm, online RoPE, retention).
"""
