"""Modality frontend stubs ([audio] / [vlm] assignment rule).

Per the assignment, the audio/vision entries specify the transformer BACKBONE
only; the frontend is a stub whose `input_specs()` provides *precomputed*
frame/patch embeddings.  These helpers generate deterministic synthetic
embeddings for smoke tests/examples and the matching ShapeDtypeStructs for the
dry-run.

llava-next "anyres tiling": a (2x2 tiles + 1 base) 336px/14 grid would give
5 * 576 = 2880 patch tokens; we expose `vision_tokens(cfg)` so configs pick
their token budget explicitly (llava-next-34b uses 2880).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def vision_tokens(cfg: ModelConfig) -> int:
    return cfg.frontend_tokens


def synth_patch_embeds(cfg: ModelConfig, batch: int, seed: int = 0) -> jax.Array:
    """Deterministic stand-in for the vision tower output [B, P, D]."""
    rng = np.random.default_rng(seed)
    p = rng.standard_normal((batch, cfg.frontend_tokens, cfg.d_model)) * 0.02
    return jnp.asarray(p, jnp.dtype(cfg.param_dtype))


def synth_frame_embeds(cfg: ModelConfig, batch: int, n_frames: int,
                       seed: int = 0) -> jax.Array:
    """Deterministic stand-in for the speech encoder frontend [B, T, D]."""
    rng = np.random.default_rng(seed)
    f = rng.standard_normal((batch, n_frames, cfg.d_model)) * 0.02
    return jnp.asarray(f, jnp.dtype(cfg.param_dtype))
