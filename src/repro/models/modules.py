"""Minimal functional param-tree module system (no flax dependency).

Params are plain nested dicts of jnp arrays — trivially checkpointable and
shardable.  A `ParamBuilder` records, while initializing:

  * the param tree itself,
  * a parallel tree of *logical sharding axes* per tensor dimension
    (mapped to mesh axes by runtime/sharding.py), and
  * the tree-paths of every linear layer (so PTQ deployment can find and
    quantize exactly the matmul weights — the paper's Section III pipeline).

Logical axis vocabulary (see runtime/sharding.py for the mesh mapping):
  'embed'   — d_model-sized dims          (FSDP candidate)
  'vocab'   — vocabulary dims             (TP candidate)
  'heads'   — attention-head dims         (TP candidate)
  'mlp'     — FFN hidden dims             (TP candidate)
  'experts' — MoE expert dims             (EP candidate)
  'kv'      — KV-head dims
  'inner'   — SSM inner-channel dims      (TP candidate)
  None      — replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]
Axes = dict[str, Any]


@dataclasses.dataclass
class ParamBuilder:
    key: jax.Array
    dtype: Any = jnp.float32
    abstract: bool = False           # True: record ShapeDtypeStructs only
    params: Params = dataclasses.field(default_factory=dict)
    axes: Axes = dataclasses.field(default_factory=dict)
    linear_paths: list[tuple[str, ...]] = dataclasses.field(default_factory=list)
    _path: tuple[str, ...] = ()

    def _next_key(self) -> jax.Array:
        if self.abstract:
            return self.key
        self.key, sub = jax.random.split(self.key)
        return sub

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(key=self._next_key(), dtype=self.dtype,
                           abstract=self.abstract,
                           linear_paths=self.linear_paths,
                           _path=self._path + (name,))
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def param(self, name: str, shape: tuple[int, ...], axes: tuple,
              init: str = "normal", scale: float | None = None,
              dtype=None) -> jax.Array:
        if len(axes) != len(shape):
            raise ValueError(f"param {name!r}: axes {axes} do not match "
                             f"shape {shape}")
        dtype = dtype or self.dtype
        if self.abstract:
            v = jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
            self.params[name] = v
            self.axes[name] = axes
            return v
        if init == "normal":
            std = scale if scale is not None else 1.0 / math.sqrt(shape[0])
            v = jax.random.normal(self._next_key(), shape, jnp.float32) * std
        elif init == "zeros":
            v = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            v = jnp.ones(shape, jnp.float32)
        else:
            raise ValueError(init)
        v = v.astype(dtype)
        self.params[name] = v
        self.axes[name] = axes
        return v

    def linear(self, name: str, k: int, n: int, k_axis: str | None,
               n_axis: str | None, bias: bool = False,
               scale: float | None = None) -> None:
        """A matmul weight ``W[K, N]`` executed through the HSA engine."""
        sub = self.child(name)
        sub.param("w", (k, n), (k_axis, n_axis), scale=scale)
        if bias:
            sub.param("b", (n,), (n_axis,), init="zeros")
        self.linear_paths.append(self._path + (name,))


def tree_get(tree: Params, path: tuple[str, ...]) -> Any:
    for p in path:
        tree = tree[p]
    return tree


def stack_layers(key: jax.Array, n_layers: int, build_one, dtype=jnp.float32,
                 abstract: bool = False):
    """Initialize a scanned layer stack: every leaf gains a leading [L] dim.

    `build_one(builder)` populates one layer's params.  Returns
    (stacked params, per-layer axes with 'layers' prepended, linear paths).
    """
    proto = ParamBuilder(key=jax.random.key(0), dtype=dtype, abstract=True)
    build_one(proto)
    axes = jax.tree.map(lambda a: ("layers",) + a, proto.axes,
                        is_leaf=lambda x: isinstance(x, tuple))

    if abstract:
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype),
            proto.params)
        return stacked, axes, proto.linear_paths

    def init_one(k):
        b = ParamBuilder(key=k, dtype=dtype)
        build_one(b)
        return b.params

    keys = jax.random.split(key, n_layers)
    stacked = jax.vmap(init_one)(keys)
    return stacked, axes, proto.linear_paths


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
