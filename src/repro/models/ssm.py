"""Mamba-1 selective-SSM block (falcon-mamba-7b; the SSM half of hymba).

Diagonal-A selective scan.  Per channel d and state n:

    h_t = exp(dt_t * A[d,n]) * h_{t-1} + dt_t * B_t[n] * x_t[d]
    y_t = sum_n C_t[n] * h_t[d,n]  +  D[d] * x_t[d]

Training/prefill runs a **chunked** scan (DESIGN.md §3): an outer
`jax.lax.scan` over chunks of `cfg.ssm_chunk` tokens carries the O(1) state,
and an inner `associative_scan` materializes ``[B, chunk, d_inner, state]``
only transiently — never the full-sequence state tensor (which at
falcon-mamba scale would be ~TB).  Decode is the plain one-step recurrence —
an MVM-shaped, memory-bound workload, exactly where the paper's MXINT4
weight path pays off.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.hsa import HSAEngine
from repro.models.config import ModelConfig
from repro.models.modules import ParamBuilder

Params = dict[str, Any]


def mamba_init(b: ParamBuilder, cfg: ModelConfig) -> None:
    d, di, n, r = cfg.d_model, cfg.d_inner_, cfg.ssm_state, cfg.dt_rank_
    b.linear("in_proj", d, 2 * di, "embed", "inner")       # x and z branches
    b.param("conv_w", (cfg.conv_width, di), (None, "inner"),
            scale=1.0 / cfg.conv_width)
    b.param("conv_b", (di,), ("inner",), init="zeros")
    b.linear("x_proj", di, r + 2 * n, "inner", None)       # dt, B, C
    b.linear("dt_proj", r, di, None, "inner", bias=True)
    b.param("a_log", (di, n), ("inner", None), init="ones")
    b.param("d_skip", (di,), ("inner",), init="ones")
    b.linear("out_proj", di, d, "inner", "embed")


def _ssm_inputs(p: Params, xz: jax.Array, engine: HSAEngine, phase: str,
                cfg: ModelConfig):
    """Split in_proj output, return (x_conv_input, z, dt, Bc, Cc)."""
    di, n, r = cfg.d_inner_, cfg.ssm_state, cfg.dt_rank_
    x, z = xz[..., :di], xz[..., di:]
    dbc = engine.linear(p["x_proj"], x, phase)
    dt = jax.nn.softplus(engine.linear(p["dt_proj"], dbc[..., :r], phase))
    bc, cc = dbc[..., r:r + n], dbc[..., r + n:]
    return x, z, dt, bc, cc


def _conv_causal(x: jax.Array, w: jax.Array, bias: jax.Array,
                 state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over seq.  x [B,S,di], w [cw,di]."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state                                       # [B, cw-1, di]
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return jax.nn.silu(out + bias)


def mamba_apply(p: Params, x_star: jax.Array, sig_inv, engine: HSAEngine,
                phase: str, cfg: ModelConfig,
                cache: Params | None = None,
                valid_len: jax.Array | None = None,
                collect_states: bool = False
                ) -> tuple[jax.Array, Params]:
    """Full-sequence chunked selective scan.  Returns (y, final ssm cache).

    ``cache`` (chunked prefill) seeds the scan with the previous chunk's
    ``h``/``conv`` state so chunk N continues chunk N-1 exactly.
    ``valid_len`` (bucketed prefill) freezes the state through padded tail
    tokens: their ``dt`` is zeroed (``exp(0·A) = 1`` keeps h, ``dt·B·x = 0``
    adds nothing) and the outgoing conv window is gathered at the last *real*
    token instead of the padded end.

    ``collect_states`` (speculative verify; small s) adds per-position state
    snapshots to the cache: ``'h_all'`` ``[B, S, di, n]`` (the recurrent h
    after every token — the associative scan materializes it anyway) and
    ``'conv_ext'`` ``[B, cw-1+S, di]`` (the carried-in conv window followed
    by this chunk's pre-conv inputs, so the window as of any accepted length
    ``a`` is the slice ``[:, a:a+cw-1]``).  Rollback then *restores* the
    snapshot at the acceptance boundary instead of trying to invert the
    selective scan.
    """
    bsz, s, _ = x_star.shape
    di, n = cfg.d_inner_, cfg.ssm_state
    cw = cfg.conv_width
    chunk = min(cfg.ssm_chunk, s)

    xz = engine.linear(p["in_proj"], x_star, phase, row_scale=sig_inv)
    xc, z, dt, bc, cc = _ssm_inputs(p, xz, engine, phase, cfg)
    conv_in = cache["conv"].astype(xc.dtype) if cache is not None else None
    xc = _conv_causal(xc, p["conv_w"], p["conv_b"], state=conv_in)
    if valid_len is not None:
        dt = dt * (jnp.arange(s) < valid_len)[None, :, None]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # [di, n], negative

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b2 + a2 * b1

    def scan_block(h0, dt_c, xc_c, bc_c, cc_c):
        """One chunk.  Inputs seq-major [c, B, ...]; the [c, B, di, n] decay
        and state tensors exist only inside this block (transient VMEM-scale
        working set — never the full sequence; DESIGN.md §3 'chunked')."""
        da_c = jnp.exp(dt_c[..., None] * a)                # [c, B, di, n]
        db_c = (dt_c * xc_c)[..., None] * bc_c[..., None, :]
        a_sc, b_sc = jax.lax.associative_scan(combine, (da_c, db_c), axis=0)
        h = a_sc * h0[None] + b_sc
        y = jnp.einsum("sbdn,sbn->sbd", h, cc_c)
        return h[-1], y

    # seq-major [S, B, ...] f32 views of the small per-step inputs
    dt_s = jnp.moveaxis(dt.astype(jnp.float32), 1, 0)
    xc_s = jnp.moveaxis(xc.astype(jnp.float32), 1, 0)
    bc_s = jnp.moveaxis(bc.astype(jnp.float32), 1, 0)
    cc_s = jnp.moveaxis(cc.astype(jnp.float32), 1, 0)
    main, rem = (s // chunk) * chunk, s % chunk
    h0 = (cache["h"] if cache is not None
          else jnp.zeros((bsz, di, n), jnp.float32))

    h_all = None
    if collect_states:
        # One un-chunked pass: s is a speculative verify block (<= k+1
        # tokens), so the full [S, B, di, n] state tensor is tiny and *is*
        # the product we're after.
        da_s = jnp.exp(dt_s[..., None] * a)
        db_s = (dt_s * xc_s)[..., None] * bc_s[..., None, :]
        a_sc, b_sc = jax.lax.associative_scan(combine, (da_s, db_s), axis=0)
        h_all = a_sc * h0[None] + b_sc                     # [S, B, di, n]
        y_seq = jnp.einsum("sbdn,sbn->sbd", h_all, cc_s)
        h_last = h_all[-1]
    else:
        def chunk_step(h, blk):
            return scan_block(h, *blk)

        def to_chunks(t):
            return t[:main].reshape(main // chunk, chunk, *t.shape[1:])

        h_last, ys = jax.lax.scan(
            chunk_step, h0,
            (to_chunks(dt_s), to_chunks(xc_s), to_chunks(bc_s), to_chunks(cc_s)))
        y_main = ys.reshape(main, bsz, di)
        if rem:
            h_last, y_rem = scan_block(h_last, dt_s[main:], xc_s[main:],
                                       bc_s[main:], cc_s[main:])
            y_seq = jnp.concatenate([y_main, y_rem], axis=0)
        else:
            y_seq = y_main
    y = jnp.moveaxis(y_seq, 0, 1)

    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = engine.linear(p["out_proj"], y.astype(x_star.dtype), phase)
    pre = xz[..., :di].astype(jnp.float32)                 # pre-conv inputs
    # Outgoing conv window: the cw-1 pre-conv inputs ending at the last real
    # token, continuing any incoming window across the chunk boundary.
    prev = (cache["conv"].astype(jnp.float32) if cache is not None
            else jnp.zeros((bsz, cw - 1, di), jnp.float32))
    pre_ext = jnp.concatenate([prev, pre], axis=1)         # [B, cw-1+s, di]
    if valid_len is None:
        conv_state = pre_ext[:, s:]
    else:
        conv_state = jax.lax.dynamic_slice(
            pre_ext, (0, valid_len, 0), (bsz, cw - 1, di))
    new_cache = {"h": h_last, "conv": conv_state}
    if collect_states:
        new_cache["h_all"] = jnp.moveaxis(h_all, 0, 1)     # [B, S, di, n]
        new_cache["conv_ext"] = pre_ext
    return out, new_cache


def mamba_decode(p: Params, x_star: jax.Array, sig_inv, engine: HSAEngine,
                 cfg: ModelConfig, cache: Params
                 ) -> tuple[jax.Array, Params]:
    """One-step recurrence (O(1) state) — the edge decode workload."""
    bsz = x_star.shape[0]
    di, n = cfg.d_inner_, cfg.ssm_state

    xz = engine.linear(p["in_proj"], x_star, "decode", row_scale=sig_inv)
    x_raw = xz[..., :di]                                   # pre-conv input
    # Ring conv state: shift in the newest input.
    conv_state = jnp.concatenate(
        [cache["conv"][:, 1:], x_raw.astype(jnp.float32)], axis=1)
    xc = _conv_causal(x_raw, p["conv_w"], p["conv_b"],
                      state=cache["conv"].astype(x_raw.dtype))
    _, z, dt, bc, cc = _ssm_inputs(p, xz, engine, "decode", cfg)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                     # [B, di]
    da = jnp.exp(dtf[..., None] * a)                       # [B, di, n]
    db = (dtf * xc[:, 0].astype(jnp.float32))[..., None] * \
        bc[:, 0].astype(jnp.float32)[:, None, :]
    h = da * cache["h"] + db
    y = jnp.einsum("bdn,bn->bd", h, cc[:, 0].astype(jnp.float32))
    y = y + xc[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = engine.linear(p["out_proj"], y[:, None].astype(x_star.dtype), "decode")
    return out, {"h": h, "conv": conv_state}


def mamba_make_cache(cfg: ModelConfig, batch: int) -> Params:
    di, n = cfg.d_inner_, cfg.ssm_state
    return {
        "h": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32),
    }
