"""RetNet block — the paper's target model (C5), built on core/retention.py.

Multi-scale retention with RoPE-rotated q/k (the paper's RoPE unit serves
exactly this block), v/gate at 2*d_model (RetNet's d_v = 2d), per-head
GroupNorm, swish gate, then a GeLU FFN.  Prefill/training uses the chunkwise
form (the Pallas kernel when on TPU); decode uses the O(1) recurrent form —
the reason the paper chose RetNet for bandwidth-starved edge decode.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import online_rope as orp
from repro.core import retention as ret
from repro.core.hsa import HSAEngine
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.modules import ParamBuilder

Params = dict[str, Any]


def retention_init(b: ParamBuilder, cfg: ModelConfig) -> None:
    d = cfg.d_model
    b.linear("wq", d, d, "embed", "heads")
    b.linear("wk", d, d, "embed", "heads")
    b.linear("wv", d, 2 * d, "embed", "heads")
    b.linear("wg", d, 2 * d, "embed", "heads")
    b.linear("wo", 2 * d, d, "heads", "embed")


def _project(p: Params, x_star, sig_inv, engine: HSAEngine, phase: str,
             cfg: ModelConfig):
    b, s, d = x_star.shape
    h = cfg.n_heads
    dk, dv = d // h, 2 * d // h
    q = engine.linear(p["wq"], x_star, phase, row_scale=sig_inv)
    k = engine.linear(p["wk"], x_star, phase, row_scale=sig_inv)
    v = engine.linear(p["wv"], x_star, phase, row_scale=sig_inv)
    g = engine.linear(p["wg"], x_star, phase, row_scale=sig_inv)
    q = q.reshape(b, s, h, dk) * (dk ** -0.5)
    k = k.reshape(b, s, h, dk) * (dk ** -0.5)   # RetNet scales k too
    v = v.reshape(b, s, h, dv)
    return q, k, v, g


def retention_apply(p: Params, x_star, sig_inv, engine: HSAEngine, phase: str,
                    cfg: ModelConfig, *, rope_sin=None, rope_cos=None,
                    cache: Params | None = None,
                    valid_len: jax.Array | None = None,
                    collect_states: bool = False
                    ) -> tuple[jax.Array, Params]:
    """Full-sequence (chunkwise) retention.  Returns (out, final-state cache).

    ``cache`` (chunked prefill) carries the O(1) retention state across
    chunks — outputs and the new state then include the decayed contribution
    of everything before this chunk.  ``valid_len`` (bucketed prefill) masks
    padded tail tokens out of the state: their k/v are zeroed and the final
    state is re-scaled by ``gamma^(valid_len - s)`` to undo the extra decay
    the padded steps applied (exact — see decay recurrence).

    ``collect_states`` (speculative verify; small s) runs the *recurrent*
    form instead and adds ``'s_all'`` — the state snapshot after every
    position, ``[B, S, H, dk, dv]`` — to the cache so a rejected draft rolls
    back to the exact state the accepted prefix produced (re-decaying the
    final state would amplify fp error by ``gamma^-(s-a)``).
    """
    b, s, d = x_star.shape
    h = cfg.n_heads
    q, k, v, g = _project(p, x_star, sig_inv, engine, phase, cfg)
    if rope_sin is not None:
        q = orp.apply_rope(q, rope_sin[None, :, None, :], rope_cos[None, :, None, :])
        k = orp.apply_rope(k, rope_sin[None, :, None, :], rope_cos[None, :, None, :])
    gamma = ret.head_decays(h)
    if valid_len is not None:
        keep = (jnp.arange(s) < valid_len)[None, :, None, None]
        k = k * keep
        v = v * keep
    qt, kt, vt = (jnp.moveaxis(t, 2, 1) for t in (q, k, v))   # [B,H,S,d*]
    state0 = cache["s"] if cache is not None else None
    s_all = None
    chunk = min(128, s)
    if collect_states:
        y, state, s_all = ret.retention_recurrent(qt, kt, vt, gamma,
                                                  state=state0,
                                                  return_states=True)
    elif s % chunk == 0:
        y, state = ops.retention_chunkwise(qt, kt, vt, gamma, chunk=chunk,
                                           state=state0)
    elif state0 is None:
        y = ret.retention_parallel(qt, kt, vt, gamma)
        _, state = ret.retention_recurrent(qt, kt, vt, gamma)
    else:
        y, state = ret.retention_chunkwise(qt, kt, vt, gamma, chunk=s,
                                           state=state0)
    if valid_len is not None:
        undo = jnp.exp((valid_len - s).astype(jnp.float32) * jnp.log(gamma))
        state = state * undo[None, :, None, None]
    y = ret.group_norm_heads(y)
    y = jnp.moveaxis(y, 1, 2).reshape(b, s, 2 * d)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = engine.linear(p["wo"], y, phase)
    new_cache = {"s": state}
    if s_all is not None:
        new_cache["s_all"] = s_all
    return out, new_cache


def retention_decode(p: Params, x_star, sig_inv, engine: HSAEngine,
                     cfg: ModelConfig, cache: Params, *,
                     rope_sin=None, rope_cos=None
                     ) -> tuple[jax.Array, Params]:
    """O(1)-state recurrent step — the paper's decode workload."""
    b, _, d = x_star.shape
    h = cfg.n_heads
    q, k, v, g = _project(p, x_star, sig_inv, engine, "decode", cfg)
    if rope_sin is not None:
        q = orp.apply_rope(q, rope_sin, rope_cos)
        k = orp.apply_rope(k, rope_sin, rope_cos)
    gamma = ret.head_decays(h)
    y, state = ret.retention_recurrent_step(
        q[:, 0], k[:, 0], v[:, 0], cache["s"], gamma)
    y = ret.group_norm_heads(y)
    y = y.reshape(b, 1, 2 * d)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = engine.linear(p["wo"], y, "decode")
    return out, {"s": state}


def retention_make_cache(cfg: ModelConfig, batch: int) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    return {"s": jnp.zeros((batch, h, d // h, 2 * d // h), jnp.float32)}
