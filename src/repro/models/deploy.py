"""Deployment PTQ pass — Section III applied to a whole model.

`deploy_quantize` walks the linear paths recorded at init time and attaches
the serving formats to each weight:

    {'w': W[, 'b': b]}  ->  {'w8_vals', 'w8_scale',          # prefill W8A8
                             'mx_packed', 'mx_exps'[, 'b']}  # decode  W4A8
                            [+ 'w' kept where structurally needed]

plus MXINT4 for 3-D stacked expert tensors (MoE decode EMA).  The pass is pure
jnp, so `jax.eval_shape(deploy_quantize, ...)` yields the serving param
*structure* for dry-run lowering without ever allocating the full model.

SmoothQuant (core/smoothquant.py) runs *before* this pass in the PTQ pipeline
(examples/quantize_model.py): calibration absmax -> fold 1/s into producer
gammas, s into weights -> then quantize here.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import mxint4 as mx

Params = dict[str, Any]

# Linears whose master weight must survive deployment because the math uses
# the matrix itself (MLA absorbed-decode einsums), not an x @ W matmul.
KEEP_MASTER = re.compile(r"(wk_b|wv_b)$")


def _mx_ok(w: jax.Array) -> bool:
    """MXINT4 packing needs N % 32 == 0 (2 nibbles x group 16).  The few
    non-conforming linears (e.g. hymba's x_proj, N = dt_rank + 2*state = 132)
    stay INT8 — the HSA engine falls back per-layer; EMA impact is <0.1 % of
    weight bytes for every assigned arch (DESIGN.md §8)."""
    return w.shape[-1] % (2 * mx.GROUP_SIZE) == 0


def _quantize_linear(sub: Params, keep_master: bool) -> Params:
    w = sub["w"]
    q8 = mx.quantize_int8_tensor(w)
    out = {"w8_vals": q8.values, "w8_scale": q8.scale}
    if _mx_ok(w):
        q4 = mx.quantize_mxint4(w)
        out["mx_packed"] = q4.packed
        out["mx_exps"] = q4.exps_packed
    if keep_master:
        out["w"] = w
    if "b" in sub:
        out["b"] = sub["b"]
    return out


def quantize_stacked(stacked: jax.Array) -> Params:
    """MXINT4 for [E, K, N] expert stacks (vmapped Eq. 1)."""
    q = jax.vmap(mx.quantize_mxint4)(stacked)
    return {"packed": q.packed, "exps": q.exps_packed}


def dequantize_stacked(pe: Params, name: str) -> jax.Array:
    """Inverse used by mlp._expert_weight during deployed MoE decode."""
    packed, exps = pe[f"{name}_mx"]["packed"], pe[f"{name}_mx"]["exps"]
    k, n_half = packed.shape[-2], packed.shape[-1]

    def one(pk, ex):
        return mx.dequantize_mxint4(
            mx.MXINT4Weight(packed=pk, exps_packed=ex, shape=(k, n_half * 2)),
            dtype=jnp.float32)

    return jax.vmap(one)(packed, exps)


def deploy_quantize(params: Params, linear_paths: list[tuple[str, ...]],
                    keep_all_masters: bool = False) -> Params:
    """Return the serving param tree (pure; eval_shape-compatible)."""
    out = jax.tree.map(lambda x: x, params)  # shallow-ish copy via rebuild

    def set_path(tree: Params, path: tuple[str, ...], value: Any) -> None:
        for pp in path[:-1]:
            tree = tree[pp]
        tree[path[-1]] = value

    def get_path(tree: Params, path: tuple[str, ...]) -> Any:
        for pp in path:
            tree = tree[pp]
        return tree

    for path in linear_paths:
        sub = get_path(out, path)
        if "w" not in sub:        # already transformed (shared subtree)
            continue
        keep = keep_all_masters or bool(KEEP_MASTER.search(path[-1]))
        # Stacked (scanned) layers carry a leading [L] dim: vmap the PTQ.
        w = sub["w"]
        if w.ndim == 2:
            set_path(out, path, _quantize_linear(sub, keep))
        else:
            q8 = jax.vmap(mx.quantize_int8_tensor)(w)
            new = {"w8_vals": q8.values, "w8_scale": q8.scale}
            if _mx_ok(w):
                q4 = jax.vmap(mx.quantize_mxint4)(w)
                new["mx_packed"] = q4.packed
                new["mx_exps"] = q4.exps_packed
            if keep:
                new["w"] = w
            if "b" in sub:
                new["b"] = sub["b"]
            set_path(out, path, new)

    # MoE expert stacks ([L, E, K, N] or [E, K, N]): quantize in place.
    def quantize_experts(tree: Params) -> None:
        for key, val in list(tree.items()):
            if isinstance(val, dict):
                if key == "experts":
                    for wname in ("wg", "wi", "wo"):
                        if wname in val:
                            w = val.pop(wname)
                            flat = w.reshape((-1,) + w.shape[-2:])
                            q = jax.vmap(mx.quantize_mxint4)(flat)
                            val[f"{wname}_mx"] = {
                                "packed": q.packed.reshape(
                                    w.shape[:-2] + q.packed.shape[-2:]),
                                "exps": q.exps_packed.reshape(
                                    w.shape[:-2] + q.exps_packed.shape[-2:]),
                            }
                else:
                    quantize_experts(val)

    quantize_experts(out)
    return out


def deployed_axes(axes: Params, linear_paths: list[tuple[str, ...]]) -> Params:
    """Mirror the axes tree through the deployment transform."""
    out = jax.tree.map(lambda a: a, axes,
                       is_leaf=lambda x: isinstance(x, tuple))

    def get_path(tree, path):
        for pp in path:
            tree = tree[pp]
        return tree

    for path in linear_paths:
        parent = get_path(out, path[:-1]) if len(path) > 1 else out
        sub = parent[path[-1]]
        if "w" not in sub:
            continue
        wa = sub["w"]
        new = {"w8_vals": wa, "w8_scale": wa[:-2] if len(wa) > 2 else (),
               "mx_packed": wa, "mx_exps": wa, "w": wa}
        if "b" in sub:
            new["b"] = sub["b"]
        parent[path[-1]] = new

    def fix_experts(tree):
        for key, val in list(tree.items()):
            if isinstance(val, dict):
                if key == "experts":
                    for wname in ("wg", "wi", "wo"):
                        if wname in val:
                            wa = val.pop(wname)
                            val[f"{wname}_mx"] = {"packed": wa, "exps": wa}
                else:
                    fix_experts(val)

    fix_experts(out)
    return out
