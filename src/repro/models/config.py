"""ModelConfig — one config dataclass covering every assigned architecture.

Each `src/repro/configs/<arch>.py` instantiates this with the exact published
numbers; `reduced()` derives the family-preserving tiny config used by the
per-arch CPU smoke tests (the full configs are only ever lowered via the
dry-run's ShapeDtypeStructs, never allocated).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|retnet|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- attention ---------------------------------------------------------
    attn_type: str = "gqa"          # 'gqa' | 'mla' | 'none' | 'retention'
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    rope: bool = True
    rope_base: float = 10000.0
    abs_pos_embed: bool = False     # sinusoidal absolute positions (seamless)
    sliding_window: int = 0         # 0 = full attention
    full_attn_every: int = 0        # hybrid: layer i is full-attn if i % this == 0
    norm_type: str = "rmsnorm"      # 'rmsnorm' | 'layernorm'

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0     # deepseek-v3: first 3 layers dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- MLA (deepseek-v3) ---------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False               # multi-token-prediction extra block

    # --- SSM (mamba-1) -------------------------------------------------------
    ssm_state: int = 0
    d_inner: int = 0                # 0 -> 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)
    ssm_chunk: int = 128

    # --- retention (retnet) --------------------------------------------------
    # v/gate use 2*d_model (RetNet's d_v = 2 d); heads are retention heads.

    # --- enc-dec / frontends --------------------------------------------------
    encoder_layers: int = 0         # >0 -> encoder-decoder
    frontend: str | None = None     # 'audio' | 'vision' (stub embeddings)
    frontend_tokens: int = 0        # patches/frames occupying the prompt head

    # --- numerics / structure -------------------------------------------------
    param_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False

    # ------------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner_(self) -> int:
        return self.d_inner or (2 * self.d_model)

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return self.family in ("ssm", "retnet") or (
            self.family == "hybrid" and self.sliding_window > 0)

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 + self.first_dense_layers),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=32,
            d_ff=192 if self.d_ff else 0,
            vocab_size=512,
            vocab_pad_multiple=64,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_head_dim=32 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=16 if self.qk_rope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            d_inner=256 if self.family in ("ssm", "hybrid") else 0,
            dt_rank=8 if self.family in ("ssm", "hybrid") else 0,
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_tokens=min(self.frontend_tokens, 8),
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (workload) input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
