"""Shared layers: fused norms, RoPE plumbing, flash attention, GQA and MLA.

Every matmul goes through the HSA engine (core/hsa.py) so the phase decides
the dataflow/format (C1/C2), and every pre-matmul norm uses the Eq. (4)
fused emission (C3): the norm returns ``(x*, sigma^{-1})`` and sigma^{-1}
rides into the consuming linears' epilogues as `row_scale`.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fused_rmsnorm as fr
from repro.core import kvq
from repro.core import online_rope as orp
from repro.core.hsa import HSAEngine
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.modules import ParamBuilder
from repro.runtime.sharding import constrain

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Norms (fused emission — C3)
# ---------------------------------------------------------------------------


def norm_init(b: ParamBuilder, name: str, dim: int, cfg: ModelConfig) -> None:
    sub = b.child(name)
    sub.param("g", (dim,), (None,), init="ones")
    if cfg.norm_type == "layernorm":
        sub.param("b", (dim,), (None,), init="zeros")


def norm_emit(p: Params, x: jax.Array, engine: HSAEngine, cfg: ModelConfig
              ) -> tuple[jax.Array, jax.Array | None]:
    """Return (x*, sigma_inv) fused, or (normalized x, None) unfused."""
    if engine.config.fuse_rmsnorm:
        if cfg.norm_type == "layernorm":
            return fr.fused_layernorm_emit(x, p["g"])
        return fr.fused_rmsnorm_emit(x, p["g"])
    if cfg.norm_type == "layernorm":
        return fr.layernorm(x, p["g"], p.get("b")), None
    return fr.rmsnorm(x, p["g"]), None


def norm_full(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Always-normalized variant (final norm before the LM head)."""
    if cfg.norm_type == "layernorm":
        return fr.layernorm(x, p["g"], p.get("b"))
    return fr.rmsnorm(x, p["g"])


# ---------------------------------------------------------------------------
# Flash attention (pure-JAX online-softmax; memory-efficient for 32k prefill)
# ---------------------------------------------------------------------------


def _flash_mask(q_pos, k_pos, lims, causal, windowed):
    """Positional mask.  ``lims`` is the f32 [4] array
    ``[window, q_offset, k_offset, kv_len]`` (entries may be traced); q/k
    positions arrive already offset, in f32 (exact for any real seq len)."""
    mask = (k_pos[None, :] >= 0) & (k_pos[None, :] < lims[3])
    mask = jnp.broadcast_to(mask, (q_pos.shape[0], k_pos.shape[0]))
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if windowed:
        mask &= (q_pos[:, None] - k_pos[None, :]) < lims[0]
    return mask


def _flash_fwd_impl(cfg, q, k, v, lims):
    """Forward online-softmax scan.  q is pre-scaled f32 [B,Sq,KV,G,hd].

    Returns (out [B,Sq,KV,G,dv] f32, lse [B,KV,G,Sq] f32).
    """
    (causal, windowed, q_chunk, kv_chunk) = cfg
    b, sq, kv_h, g, hd = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]

    kf = k.reshape(b, sk // kv_chunk, kv_chunk, kv_h, hd)
    vf = v.reshape(b, sk // kv_chunk, kv_chunk, kv_h, dv)
    qf = q.reshape(b, sq // q_chunk, q_chunk, kv_h, g, hd)

    def one_q_chunk(args):
        qi, q_blk = args
        q_pos = lims[1] + (qi * q_chunk + jnp.arange(q_chunk)).astype(jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            k_pos = lims[2] + (ki * kv_chunk + jnp.arange(kv_chunk)).astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk)
            mask = _flash_mask(q_pos, k_pos, lims, causal, windowed)
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # All-masked rows: keep m finite so exp() stays well-defined.
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kv_h, g, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((b, kv_h, g, q_chunk), jnp.float32),
            jnp.zeros((b, kv_h, g, q_chunk, dv), jnp.float32),
        )
        ks = jnp.arange(sk // kv_chunk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (ks, jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        lse = m_safe + jnp.log(jnp.maximum(l, 1e-30))
        return jnp.moveaxis(out, 3, 1), lse     # [B,qc,KV,G,dv], [B,KV,G,qc]

    outs, lses = jax.lax.map(one_q_chunk,
                             (jnp.arange(sq // q_chunk),
                              jnp.moveaxis(qf, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kv_h, g, dv)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kv_h, g, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg, q, k, v, lims):
    out, _ = _flash_fwd_impl(cfg, q, k, v, lims)
    return out


def _flash_fwd(cfg, q, k, v, lims):
    out, lse = _flash_fwd_impl(cfg, q, k, v, lims)
    return out, (q, k, v, lims, out, lse)


def _flash_bwd(cfg, res, dout):
    """Real flash-attention backward: recompute P per (q,kv) tile from
    (q,k,v,lse) instead of letting autodiff save per-step score/mask tensors
    (which made large train cells exceed HBM — see EXPERIMENTS.md §Dry-run).
    """
    (causal, windowed, q_chunk, kv_chunk) = cfg
    q, k, v, lims, out, lse = res
    b, sq, kv_h, g, hd = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]

    dout = dout.astype(jnp.float32)
    # D_i = rowsum(dout * out), per query position
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dout, out)

    kf = k.reshape(b, sk // kv_chunk, kv_chunk, kv_h, hd)
    vf = v.reshape(b, sk // kv_chunk, kv_chunk, kv_h, dv)
    qf = q.reshape(b, sq // q_chunk, q_chunk, kv_h, g, hd)
    do_f = dout.reshape(b, sq // q_chunk, q_chunk, kv_h, g, dv)
    lse_f = lse.reshape(b, kv_h, g, sq // q_chunk, q_chunk)
    dl_f = delta.reshape(b, kv_h, g, sq // q_chunk, q_chunk)

    def one_q_chunk(carry, args):
        dk_acc, dv_acc = carry                   # [B, Sk, KV, hd/dv] f32
        qi, q_blk, do_blk, lse_blk, dl_blk = args
        q_pos = lims[1] + (qi * q_chunk + jnp.arange(q_chunk)).astype(jnp.float32)

        def kv_step(carry2, inp):
            dq_blk, dk_a, dv_a = carry2
            ki, k_blk, v_blk = inp
            k_pos = lims[2] + (ki * kv_chunk + jnp.arange(kv_chunk)).astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk)
            mask = _flash_mask(q_pos, k_pos, lims, causal, windowed)
            p = jnp.where(mask, jnp.exp(s - lse_blk[..., None]), 0.0)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk, v_blk)
            ds = p * (dp - dl_blk[..., None])
            dq_blk = dq_blk + jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk)
            dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk)
            dv_c = jnp.einsum("bhgqk,bqhgd->bkhd", p, do_blk)
            start = ki * kv_chunk
            dk_a = jax.lax.dynamic_update_slice(
                dk_a, jax.lax.dynamic_slice(
                    dk_a, (0, start, 0, 0), (b, kv_chunk, kv_h, hd)) + dk_c,
                (0, start, 0, 0))
            dv_a = jax.lax.dynamic_update_slice(
                dv_a, jax.lax.dynamic_slice(
                    dv_a, (0, start, 0, 0), (b, kv_chunk, kv_h, dv)) + dv_c,
                (0, start, 0, 0))
            return (dq_blk, dk_a, dv_a), None

        dq0 = jnp.zeros((b, q_chunk, kv_h, g, hd), jnp.float32)
        ks = jnp.arange(sk // kv_chunk)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            (ks, jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0)))
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((b, sk, kv_h, hd), jnp.float32)
    dv0 = jnp.zeros((b, sk, kv_h, dv), jnp.float32)
    (dk, dv_), dqs = jax.lax.scan(
        one_q_chunk, (dk0, dv0),
        (jnp.arange(sq // q_chunk), jnp.moveaxis(qf, 1, 0),
         jnp.moveaxis(do_f, 1, 0), jnp.moveaxis(lse_f, 3, 0),
         jnp.moveaxis(dl_f, 3, 0)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, kv_h, g, hd)
    return dq, dk, dv_, jnp.zeros_like(lims)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,              # [B, Sq, KV, G, hd]  (G = q-heads per kv head)
    k: jax.Array,              # [B, Sk, KV, hd]
    v: jax.Array,              # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: int | jax.Array = 0,   # >0: sliding window; may be traced (hybrid)
    q_offset: int | jax.Array = 0,  # absolute position of q[0]; may be traced
    k_offset: int | jax.Array = 0,  # absolute position of k[0] (ring gathers)
    kv_len: int | jax.Array | None = None,  # valid keys end at this absolute
                                            # position (default: Sk + k_offset)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash attention (pure JAX, custom VJP): never materializes [Sq, Sk].

    Forward: online-softmax over KV chunks.  Backward: the flash backward
    (recompute P from q,k,v,lse per tile) — O(chunk^2) transients only.
    Handles causal, sliding-window (possibly traced, for hybrid layer flags)
    and bidirectional (cross/encoder) masking via position arithmetic.

    Chunked prefill threads *traced* ``q_offset``/``k_offset``/``kv_len``
    through the mask (all positional limits ride in one f32 side array with a
    zero cotangent), so one compiled shape serves every chunk offset: queries
    sit at absolute positions ``q_offset + i``, keys at ``k_offset + j``, and
    keys at positions outside ``[0, kv_len)`` are masked out.
    """
    b, sq, kv_h, g, hd = q.shape
    sk = k.shape[1]
    windowed = not (isinstance(window, int) and window == 0)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # Pad to chunk multiples; padded K positions are masked out (they fall at
    # or beyond kv_len), padded Q rows sliced off on return.
    sq_orig, sk_orig = sq, sk
    pq, pk = (-sq) % q_chunk, (-sk) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq)) + ((0, 0),) * 3)
        sq += pq
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk)) + ((0, 0),) * 2)
        v = jnp.pad(v, ((0, 0), (0, pk)) + ((0, 0),) * 2)
        sk += pk

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qs = q.astype(jnp.float32) * scale
    # All positional limits ride as one f32 [4] array arg (entries may be
    # traced); custom_vjp returns a zero cotangent for it.  f32 position
    # arithmetic is exact below 2^24 — far beyond any context length here.
    if kv_len is None:
        kv_len = sk_orig + k_offset
    lims = jnp.stack([
        jnp.asarray(window, jnp.float32) if windowed else jnp.float32(0),
        jnp.asarray(q_offset, jnp.float32),
        jnp.asarray(k_offset, jnp.float32),
        jnp.asarray(kv_len, jnp.float32),
    ])
    cfg = (causal, windowed, q_chunk, kv_chunk)
    out = _flash(cfg, qs, k.astype(jnp.float32), v.astype(jnp.float32), lims)
    return out[:, :sq_orig].astype(v.dtype)


# int8 KV-cache (beyond-paper, consistent with the paper's A8 activations):
# symmetric fixed-point with a static scale; halves decode cache HBM reads.
# The per-row quantized formats ('int8_tok', 'mxint4_blk') live in
# core/kvq.py; their encoded leaves are dicts and thread through every cache
# helper below structure-generically.
KV8_SCALE = kvq.KV8_SCALE


def to_cache_dtype(x: jax.Array, dtype) -> jax.Array:
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * KV8_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def from_cache_dtype(c) -> jax.Array:
    """Cache leaf (fp/int8 array or kvq-encoded dict) -> f32 array."""
    return kvq.decode(c)


def to_cache_like(x: jax.Array, leaf):
    """Encode fresh K/V rows to match the resident cache leaf's format."""
    if isinstance(leaf, dict):
        return kvq.encode_like(x, leaf)
    return to_cache_dtype(x, leaf.dtype)


def cache_update(leaf, x: jax.Array, pos) -> Any:
    """Append rows at the cache axis (axis 1) via dynamic_update_slice —
    structure-generic over plain and kvq-encoded leaves."""
    enc = to_cache_like(x, leaf)

    def upd(buf, rows):
        return jax.lax.dynamic_update_slice(
            buf, rows, (0, pos) + (0,) * (buf.ndim - 2))

    if isinstance(leaf, dict):
        return {kk: upd(leaf[kk], enc[kk]) for kk in leaf}
    return upd(leaf, enc)


def cache_scatter(leaf, x: jax.Array, idx: jax.Array) -> Any:
    """Ring-buffer scatter at precomputed slot indices (axis 1)."""
    enc = to_cache_like(x, leaf)
    if isinstance(leaf, dict):
        return {kk: leaf[kk].at[:, idx].set(enc[kk]) for kk in leaf}
    return leaf.at[:, idx].set(enc)


def cache_gather(leaf, idx: jax.Array) -> Any:
    """Ring-buffer gather at slot indices (axis 1), format-preserving."""
    if isinstance(leaf, dict):
        return {kk: leaf[kk][:, idx] for kk in leaf}
    return leaf[:, idx]


def cache_capacity(leaf) -> int:
    """Slot count of a cache leaf (axis 1), dict- or array-formed."""
    if isinstance(leaf, dict):
        return next(iter(leaf.values())).shape[1]
    return leaf.shape[1]


def attend_one_step(
    q: jax.Array,              # [B, KV, G, hd] — one new token
    k_cache,                   # [B, C, KV, hd] array or kvq-encoded dict
    v_cache,
    valid_mask: jax.Array,     # bool [B, C]
) -> jax.Array:
    """Decode-phase attention over the cache (the MVM-shaped workload).

    This is the *oracle* for kernels/flash_decode.py: the kernel's ref path
    reproduces these exact einsum/mask/softmax steps, so greedy decode is
    bit-identical across `impl` settings on the ref path."""
    hd = q.shape[-1]
    s = jnp.einsum("bhgd,bchd->bhgc", q.astype(jnp.float32),
                   from_cache_dtype(k_cache)) / jnp.sqrt(jnp.float32(hd))
    s = jnp.where(valid_mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgc,bchd->bhgd", p, from_cache_dtype(v_cache))


# ---------------------------------------------------------------------------
# GQA attention block (dense / moe / hybrid / vlm / encdec self-attn)
# ---------------------------------------------------------------------------


def gqa_init(b: ParamBuilder, cfg: ModelConfig, d_in: int | None = None) -> None:
    d = d_in or cfg.d_model
    hd, h, kv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    b.linear("wq", d, h * hd, "embed", "heads", bias=cfg.qkv_bias)
    b.linear("wk", d, kv * hd, "embed", "kv", bias=cfg.qkv_bias)
    b.linear("wv", d, kv * hd, "embed", "kv", bias=cfg.qkv_bias)
    b.linear("wo", h * hd, d, "heads", "embed")
    if cfg.qk_norm:
        norm_init(b, "qnorm", hd, cfg)
        norm_init(b, "knorm", hd, cfg)


def _qk_head_norm(p: Params, q: jax.Array, k: jax.Array, cfg: ModelConfig):
    if not cfg.qk_norm:
        return q, k
    return (fr.rmsnorm(q, p["qnorm"]["g"]), fr.rmsnorm(k, p["knorm"]["g"]))


def _project_qkv(p: Params, x_star: jax.Array, sig_inv, engine: HSAEngine,
                 phase: str, cfg: ModelConfig):
    b, s, _ = x_star.shape
    hd, h, kv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    q = engine.linear(p["wq"], x_star, phase, row_scale=sig_inv)
    k = engine.linear(p["wk"], x_star, phase, row_scale=sig_inv)
    v = engine.linear(p["wv"], x_star, phase, row_scale=sig_inv)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    return _qk_head_norm(p, q, k, cfg) + (v,)


def gqa_apply(
    p: Params,
    x_star: jax.Array,          # [B, S, D] — gamma-scaled (fused) or normalized
    sig_inv: jax.Array | None,  # [B, S] — sigma^{-1} (fused mode)
    engine: HSAEngine,
    phase: str,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int | jax.Array = 0,
    rope_sin: jax.Array | None = None,   # [S, hd/2] precomputed (prefill/train)
    rope_cos: jax.Array | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attention
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence attention.  Returns (out [B,S,D], (k, v) for caching)."""
    b, s, _ = x_star.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q, k, v = _project_qkv(p, x_star, sig_inv, engine, phase, cfg)
    if kv_override is not None:
        k, v = kv_override
    elif rope_sin is not None:
        q = orp.apply_rope(q, rope_sin[None, :, None, :], rope_cos[None, :, None, :])
        k = orp.apply_rope(k, rope_sin[None, :, None, :], rope_cos[None, :, None, :])
    # Head-parallel region: the sequence-parallel residual sharding must not
    # leak into flash's seq-splitting reshapes (GSPMD would replicate the
    # whole [B,S,H,hd] tensor) — reshard to batch+heads here.
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv", None))
    v = constrain(v, ("batch", None, "kv", None))
    g = h // kv
    out = flash_attention(q.reshape(b, s, kv, g, hd), k, v,
                          causal=causal, window=window)
    out = engine.linear(p["wo"], out.reshape(b, s, h * hd), phase)
    return out, (k, v)


def gqa_decode(
    p: Params,
    x_star: jax.Array,          # [B, 1, D]
    sig_inv: jax.Array | None,
    engine: HSAEngine,
    cfg: ModelConfig,
    cache: Params,              # {'k','v'} [B, C, KV, hd] ring/linear buffer
    pos: jax.Array,             # i32 scalar — absolute position of this token
    *,
    window: int = 0,
    rope_sin: jax.Array | None = None,   # [hd/2] — from the online RoPE unit
    rope_cos: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """One decode step: project, rotate (online RoPE), cache-update, attend."""
    b = x_star.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q, k, v = _project_qkv(p, x_star, sig_inv, engine, "decode", cfg)
    if rope_sin is not None:
        q = orp.apply_rope(q, rope_sin, rope_cos)
        k = orp.apply_rope(k, rope_sin, rope_cos)
    q = q[:, 0].reshape(b, kv, h // kv, hd)

    c = cache_capacity(cache["k"])
    # Sliding-window caches are ring buffers; linear caches clamp at capacity
    # (admission rejects requests that would reach it — CacheCapacityError).
    slot = (pos % c) if window else jnp.minimum(pos, c - 1)
    k_cache = cache_update(cache["k"], k, slot)
    v_cache = cache_update(cache["v"], v, slot)
    n_valid = jnp.minimum(pos + 1, c)
    out = kops.flash_decode(q, k_cache, v_cache, n_valid,
                            impl=engine.config.kernel_impl)
    out = engine.linear(p["wo"], out.reshape(b, 1, h * hd), "decode")
    return out, {"k": k_cache, "v": v_cache}


def gqa_chunk(
    p: Params,
    x_star: jax.Array,          # [B, C, D] — one prefill chunk
    sig_inv: jax.Array | None,
    engine: HSAEngine,
    cfg: ModelConfig,
    cache: Params,              # {'k','v'} decode-layout ring/linear buffer
    pos: jax.Array,             # i32 scalar — absolute position of chunk[0]
    *,
    window: int | jax.Array = 0,
    rope_sin: jax.Array | None = None,   # [C, hd/2] at absolute positions
    rope_cos: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """Chunked prefill (MMM phase over a warm cache): append C tokens at
    ``pos`` and attend to the whole resident prefix.

    Because ``pos`` is traced, one compiled shape serves every chunk offset.
    Linear caches append in place; sliding-window rings scatter at
    ``pos % window`` and are gathered back into position order for the flash
    call (chunk size must not exceed the window so no slot is written twice).
    """
    b, c, _ = x_star.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q, k, v = _project_qkv(p, x_star, sig_inv, engine, "prefill", cfg)
    if rope_sin is not None:
        q = orp.apply_rope(q, rope_sin[None, :, None, :], rope_cos[None, :, None, :])
        k = orp.apply_rope(k, rope_sin[None, :, None, :], rope_cos[None, :, None, :])

    if cfg.sliding_window:
        w = cfg.sliding_window
        if c > w:
            raise ValueError(f"chunk ({c}) must fit the sliding window ({w})")
        # Attend BEFORE evicting: the chunk's earliest queries still window
        # back to keys the chunk's own writes are about to overwrite.  Key
        # j of the linearized view sits at absolute position (pos - w + j):
        # the old ring in position order, then the chunk's fresh keys.
        # Negative positions alias valid slots but are masked via k_offset.
        base = pos - w
        slots = (base + jnp.arange(w)) % w
        # The fresh chunk attends through the same cache round trip its
        # writes will take, so verify-chunk scores match the per-token decode
        # steps bit-for-bit under quantized formats (exact no-op in fp).
        k_lin = jnp.concatenate(
            [from_cache_dtype(cache_gather(cache["k"], slots)),
             from_cache_dtype(to_cache_like(k, cache["k"]))], axis=1)
        v_lin = jnp.concatenate(
            [from_cache_dtype(cache_gather(cache["v"], slots)),
             from_cache_dtype(to_cache_like(v, cache["v"]))], axis=1)
        k_off = base
        idx = (pos + jnp.arange(c)) % w
        k_cache = cache_scatter(cache["k"], k, idx)
        v_cache = cache_scatter(cache["v"], v, idx)
    else:
        k_cache = cache_update(cache["k"], k, pos)
        v_cache = cache_update(cache["v"], v, pos)
        k_lin, v_lin, k_off = (from_cache_dtype(k_cache),
                               from_cache_dtype(v_cache), 0)

    g = h // kv
    out = flash_attention(q.reshape(b, c, kv, g, hd), k_lin, v_lin,
                          causal=True, window=window, q_offset=pos,
                          k_offset=k_off, kv_len=pos + c)
    out = engine.linear(p["wo"], out.reshape(b, c, h * hd), "prefill")
    return out, {"k": k_cache, "v": v_cache}


def ring_rollback(prev: Params, new: Params, pos: jax.Array, c: int,
                  n_accept: jax.Array, window: int) -> Params:
    """Undo rejected speculative writes into a sliding-window ring cache.

    A verify block wrote K/V for chunk positions ``pos .. pos+c-1`` into ring
    slots ``(pos+i) % window``; each of those writes *evicted* the key that
    was still serving window position ``pos+i-window``.  A position-pointer
    rewind alone would therefore leave rejected drafts' keys aliased over
    live history, so slots written by positions ``>= pos + n_accept`` are
    restored from the pre-verify ring.  Works on ``{'k','v'}`` pytrees of any
    leading shape (slot axis at -3), including layer-stacked pool slots.
    """
    slots = (pos + jnp.arange(c)) % window
    restore = jnp.zeros((window,), bool).at[slots].set(
        jnp.arange(c) >= n_accept)

    def merge(old, cur):
        return jnp.where(restore[:, None, None], old, cur)

    return jax.tree.map(merge, prev, new)


def make_cache_leaf(shape: tuple, dtype) -> Any:
    """One attention-cache buffer: ``dtype`` is a jnp dtype or a kvq format
    name ('int8_tok' / 'mxint4_blk'), in which case the leaf is the encoded
    dict (bit-identical to encoding a zero buffer)."""
    if kvq.is_format(dtype):
        return kvq.zeros(shape, dtype)
    return jnp.zeros(shape, dtype)


def gqa_make_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype=jnp.bfloat16) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    # Sliding-window rings are always `window` slots — prefill pads short
    # prompts up to the window (_seed_attn_cache), so cold caches must match
    # that layout even when cache_len < window (serving.CachePool slots).
    c = cfg.sliding_window if cfg.sliding_window else cache_len
    return {
        "k": make_cache_leaf((batch, c, kv, hd), dtype),
        "v": make_cache_leaf((batch, c, kv, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v3)
# ---------------------------------------------------------------------------


def mla_init(b: ParamBuilder, cfg: ModelConfig) -> None:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b.linear("wq_a", d, qr, "embed", None)             # q down-projection
    norm_init(b, "q_norm", qr, cfg)
    b.linear("wq_b", qr, h * (dn + dr), None, "heads")  # q up-projection
    b.linear("wkv_a", d, kvr + dr, "embed", None)       # c_kv + shared k_rope
    norm_init(b, "kv_norm", kvr, cfg)
    b.linear("wk_b", kvr, h * dn, None, "heads")        # k up (nope part)
    b.linear("wv_b", kvr, h * dv, None, "heads")        # v up
    b.linear("wo", h * dv, d, "heads", "embed")


def _mla_q(p, x_star, sig_inv, engine, phase, cfg):
    b, s, _ = x_star.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_lat = engine.linear(p["wq_a"], x_star, phase, row_scale=sig_inv)
    q_lat, q_sig = norm_emit(p["q_norm"], q_lat, engine, cfg)
    q = engine.linear(p["wq_b"], q_lat, phase, row_scale=q_sig)
    q = q.reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]                    # (q_nope, q_rope)


def mla_apply(p: Params, x_star, sig_inv, engine: HSAEngine, phase: str,
              cfg: ModelConfig, *, rope_sin=None, rope_cos=None
              ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Prefill/train MLA: materialize per-head k/v (compute-rich MMM phase).

    Returns (out, (c_kv, k_rope)) — the *compressed* tensors are what gets
    cached (MLA's memory win: kv_lora_rank + qk_rope_head_dim per token).
    """
    b, s, _ = x_star.shape
    h = cfg.n_heads
    kvr, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_head_dim,
                       cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _mla_q(p, x_star, sig_inv, engine, phase, cfg)

    kv_a = engine.linear(p["wkv_a"], x_star, phase, row_scale=sig_inv)
    c_kv, k_rope = kv_a[..., :kvr], kv_a[..., kvr:]
    c_kv = norm_full(p["kv_norm"], c_kv, cfg)
    if rope_sin is not None:
        q_rope = orp.apply_rope(q_rope, rope_sin[None, :, None, :],
                                rope_cos[None, :, None, :])
        k_rope = orp.apply_rope(k_rope[:, :, None, :], rope_sin[None, :, None, :],
                                rope_cos[None, :, None, :])[:, :, 0]
    k_nope = engine.linear(p["wk_b"], c_kv, phase).reshape(b, s, h, dn)
    v = engine.linear(p["wv_b"], c_kv, phase).reshape(b, s, h, dv)

    # Pack rope part alongside nope so one flash call handles both terms:
    # scores = q_nope.k_nope + q_rope.k_rope (k_rope shared across heads).
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1)
    # Head-parallel region (see gqa_apply): keep flash inputs off the
    # sequence-parallel sharding so its seq reshapes stay shardable.
    q_full = constrain(q_full, ("batch", None, "heads", None))
    k_full = constrain(k_full, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    out = flash_attention(q_full[:, :, :, None, :].reshape(b, s, h, 1, dn + dr),
                          k_full, v, causal=True)
    out = engine.linear(p["wo"], out.reshape(b, s, h * dv), phase)
    return out, (c_kv, k_rope)


def mla_decode(p: Params, x_star, sig_inv, engine: HSAEngine, cfg: ModelConfig,
               cache: Params, pos: jax.Array, *, rope_sin=None, rope_cos=None
               ) -> tuple[jax.Array, Params]:
    """Decode MLA with *absorbed* projections: attention runs directly in the
    compressed latent space, so per-step work is O(S * kv_lora_rank) and the
    cache stays compressed.  (Required for 671B decode feasibility —
    DESIGN.md §8; materializing per-head K at 32k context would be ~TBs.)
    """
    b = x_star.shape[0]
    h = cfg.n_heads
    kvr, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_head_dim,
                       cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _mla_q(p, x_star, sig_inv, engine, "decode", cfg)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]        # [B, H, dn], [B, H, dr]

    kv_a = engine.linear(p["wkv_a"], x_star, "decode", row_scale=sig_inv)
    c_kv_new, k_rope_new = kv_a[..., :kvr], kv_a[..., kvr:]
    c_kv_new = norm_full(p["kv_norm"], c_kv_new, cfg)
    if rope_sin is not None:
        q_rope = orp.apply_rope(q_rope, rope_sin, rope_cos)
        k_rope_new = orp.apply_rope(k_rope_new, rope_sin, rope_cos)

    c = cache_capacity(cache["c_kv"])
    slot = jnp.minimum(pos, c - 1)
    c_kv = cache_update(cache["c_kv"], c_kv_new, slot)
    k_rope = cache_update(cache["k_rope"], k_rope_new, slot)

    # Absorb W_uk into q: q_abs[b,h,r] = sum_n q_nope[b,h,n] * Wk_b[r, h, n];
    # attention then runs directly in the compressed latent space through the
    # flash-decode op (the rope term rides as the second score stream).
    wk_b = p["wk_b"]["w"].reshape(kvr, h, dn).astype(jnp.float32)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32), wk_b)
    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    n_valid = jnp.minimum(pos + 1, c)
    lat_out = kops.flash_decode(q_abs, c_kv, c_kv, n_valid, q2=q_rope,
                                k2=k_rope, scale=scale,
                                impl=engine.config.kernel_impl)
    wv_b = p["wv_b"]["w"].reshape(kvr, h, dv).astype(jnp.float32)
    out_heads = jnp.einsum("bhr,rhv->bhv", lat_out, wv_b)
    out = engine.linear(p["wo"], out_heads.reshape(b, 1, h * dv), "decode")
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_chunk(p: Params, x_star, sig_inv, engine: HSAEngine, cfg: ModelConfig,
              cache: Params, pos: jax.Array, *, rope_sin=None, rope_cos=None
              ) -> tuple[jax.Array, Params]:
    """Chunked prefill for MLA: append the chunk's compressed latents at
    ``pos``, then re-expand the *whole* resident prefix through wk_b/wv_b for
    the flash call (compute-rich MMM work; the cache itself stays compressed).
    """
    b, c, _ = x_star.shape
    h = cfg.n_heads
    kvr, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_head_dim,
                       cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _mla_q(p, x_star, sig_inv, engine, "prefill", cfg)

    kv_a = engine.linear(p["wkv_a"], x_star, "prefill", row_scale=sig_inv)
    c_kv_new, k_rope_new = kv_a[..., :kvr], kv_a[..., kvr:]
    c_kv_new = norm_full(p["kv_norm"], c_kv_new, cfg)
    if rope_sin is not None:
        q_rope = orp.apply_rope(q_rope, rope_sin[None, :, None, :],
                                rope_cos[None, :, None, :])
        k_rope_new = orp.apply_rope(k_rope_new[:, :, None, :],
                                    rope_sin[None, :, None, :],
                                    rope_cos[None, :, None, :])[:, :, 0]

    c_kv = cache_update(cache["c_kv"], c_kv_new, pos)
    k_rope = cache_update(cache["k_rope"], k_rope_new, pos)

    cap = cache_capacity(c_kv)
    c_kv_f = from_cache_dtype(c_kv)
    k_nope = engine.linear(p["wk_b"], c_kv_f, "prefill").reshape(b, cap, h, dn)
    v = engine.linear(p["wv_b"], c_kv_f, "prefill").reshape(b, cap, h, dv)
    k_rope_f = from_cache_dtype(k_rope)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_f[:, :, None, :], (b, cap, h, dr))],
        axis=-1)
    out = flash_attention(q_full[:, :, :, None, :].reshape(b, c, h, 1, dn + dr),
                          k_full, v, causal=True, q_offset=pos,
                          kv_len=pos + c)
    out = engine.linear(p["wo"], out.reshape(b, c, h * dv), "prefill")
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_make_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype=jnp.bfloat16) -> Params:
    return {
        "c_kv": make_cache_leaf((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_rope": make_cache_leaf((batch, cache_len, cfg.qk_rope_head_dim),
                                  dtype),
    }
