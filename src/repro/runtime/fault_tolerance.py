"""Fault tolerance for long multi-pod runs: heartbeats, straggler detection,
elastic re-meshing.

Large fleets lose nodes mid-run; the framework's contract is:

  1. `HeartbeatMonitor` tracks per-host liveness (on TPU pods this reads the
     coordination service; here hosts are simulated so the failure path is
     testable on CPU).
  2. `StragglerDetector` flags hosts whose step times exceed
     `threshold x` the fleet median over a sliding window — the mitigation at
     the launcher level is checkpoint + exclude + re-mesh (same path as a
     hard failure, just proactive).
  3. `plan_elastic_mesh` maps surviving chip count -> the largest valid mesh
     that preserves the 'model' axis (TP degree must not change — param
     shards would be orphaned otherwise) and shrinks the DP axes; the
     launcher then restores the latest checkpoint into the new mesh via
     CheckpointManager.restore(shardings=new) — resharding is free because
     checkpoints are mesh-agnostic.

`examples/distributed_train.py` + tests/test_fault_tolerance.py exercise the
full loop: inject failure -> detect -> re-mesh -> restore -> resume.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class HostState:
    last_seen: float
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, hosts: list[str], timeout_s: float = 60.0):
        now = time.monotonic()
        self.timeout = timeout_s
        self.hosts = {h: HostState(last_seen=now) for h in hosts}

    def beat(self, host: str, now: float | None = None) -> None:
        st = self.hosts[host]
        st.last_seen = now if now is not None else time.monotonic()
        st.alive = True

    def mark_failed(self, host: str) -> None:
        """Out-of-band failure report (e.g. launcher saw the process die)."""
        self.hosts[host].alive = False

    def check(self, now: float | None = None) -> list[str]:
        """Returns newly-dead hosts (timeout or marked)."""
        now = now if now is not None else time.monotonic()
        dead = []
        for h, st in self.hosts.items():
            if st.alive and now - st.last_seen > self.timeout:
                st.alive = False
            if not st.alive:
                dead.append(h)
        return dead

    def alive_hosts(self) -> list[str]:
        return [h for h, st in self.hosts.items() if st.alive]


class StragglerDetector:
    """Flags hosts persistently slower than the fleet median."""

    def __init__(self, threshold: float = 2.0, window: int = 16,
                 min_samples: int = 4):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self.times: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, host: str, step_time_s: float) -> None:
        self.times[host].append(step_time_s)

    def stragglers(self) -> list[str]:
        meds = {h: sorted(ts)[len(ts) // 2]
                for h, ts in self.times.items() if len(ts) >= self.min_samples}
        if len(meds) < 2:
            return []
        fleet = sorted(meds.values())[len(meds) // 2]
        return [h for h, m in meds.items() if m > self.threshold * fleet]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    dropped_chips: int


def plan_elastic_mesh(alive_chips: int, model_parallel: int = 16,
                      pods: int = 1) -> MeshPlan:
    """Largest mesh with the TP degree preserved and DP shrunk to fit.

    TP ('model') cannot change across a restore — every param shard assumes
    that factor — so we keep it and give DP the biggest power-of-two (or
    exact) factor that fits the survivors.  Any remainder chips idle until
    the next full restart (reported as dropped).
    """
    if alive_chips < model_parallel:
        raise ValueError(f"fewer chips ({alive_chips}) than TP degree "
                         f"({model_parallel})")
    dp = alive_chips // (model_parallel * pods)
    # largest power of two <= dp keeps collectives ring-friendly
    p = 1
    while p * 2 <= dp:
        p *= 2
    used = p * model_parallel * pods
    if pods > 1:
        return MeshPlan(shape=(pods, p, model_parallel),
                        axes=("pod", "data", "model"),
                        dropped_chips=alive_chips - used)
    return MeshPlan(shape=(p, model_parallel), axes=("data", "model"),
                    dropped_chips=alive_chips - used)


class FailureInjector:
    """Deterministic failure schedule for tests/examples: {step: [hosts]}."""

    def __init__(self, schedule: dict[int, list[str]]):
        self.schedule = schedule

    def maybe_fail(self, step: int, monitor: HeartbeatMonitor) -> list[str]:
        failed = self.schedule.get(step, [])
        for h in failed:
            monitor.mark_failed(h)
        return failed
