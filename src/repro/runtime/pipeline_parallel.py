"""GPipe-style pipeline parallelism over a mesh axis (optional wrapper).

For depth-dominated models at extreme scale, PP trades the FSDP all-gather
volume for point-to-point stage transfers.  This implementation maps the
layer-stacked params onto a `stage` mesh axis with `shard_map`: each device
group owns `L/S` layers, microbatches stream through with
`jax.lax.ppermute` between stages, and the steady-state keeps all stages busy
(classic GPipe schedule: S + M - 1 ticks for M microbatches).

It is deliberately self-contained (wraps any per-layer `block_fn`), validated
on a virtual 4-device mesh in tests/test_pipeline.py, and reported in
DESIGN.md as the PP option for the 1000+-node regime; the 40-cell dry-run
grid uses DP/TP/FSDP/EP (PP is not required at 512 chips for any assigned
arch since FSDP fits them all).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

Params = dict[str, Any]


def pipeline_forward(
    block_fn: Callable[[Params, jax.Array], jax.Array],
    stacked_params: Params,          # leaves [L, ...]
    x: jax.Array,                    # [M, mb, ...] microbatched activations
    mesh: Mesh,
    stage_axis: str = "stage",
) -> jax.Array:
    """Run M microbatches through L layers split over the stage axis.

    Returns activations after all layers, microbatch-major [M, mb, ...].
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x.shape[0]
    l_total = jax.tree.leaves(stacked_params)[0].shape[0]
    if l_total % n_stages != 0:
        raise ValueError(f"layers {l_total} not divisible by stages "
                         f"{n_stages}")

    def per_stage(params_stage, x_all):
        # params_stage: [L/S, ...] this stage's layers; x_all: [M, mb, ...]
        stage = jax.lax.axis_index(stage_axis)

        def run_layers(h):
            def body(h, pl):
                return block_fn(pl, h), None
            h, _ = jax.lax.scan(body, h, params_stage)
            return h

        # GPipe schedule: T = S + M - 1 ticks.  Each tick: receive from the
        # previous stage, run this stage's layers on the live microbatch,
        # send onward.  Stage 0 injects microbatch t at tick t.
        ticks = n_stages + n_micro - 1
        mb_shape = x_all.shape[1:]
        outputs = jnp.zeros_like(x_all)
        carry_in = jnp.zeros(mb_shape, x_all.dtype)

        def tick(state, t):
            carry, outs = state
            inject = jnp.where(t < n_micro, t, 0)
            h_in = jnp.where(stage == 0,
                             x_all[inject],
                             carry)
            h_out = run_layers(h_in)
            # valid iff this stage is processing a real microbatch at tick t
            mb_idx = t - stage
            valid = (mb_idx >= 0) & (mb_idx < n_micro)
            updated = jax.lax.dynamic_update_index_in_dim(
                outs, h_out.astype(outs.dtype), jnp.maximum(mb_idx, 0), 0)
            keep = valid & (stage == n_stages - 1)
            outs = jnp.where(keep, updated, outs)
            # send to next stage (ring permute; last->first ignored)
            nxt = jax.lax.ppermute(
                h_out, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (carry, outputs), _ = jax.lax.scan(
            tick, (carry_in, outputs), jnp.arange(ticks))
        # Only the last stage wrote real outputs; others hold zeros, so a
        # psum broadcasts the result to every stage exactly.
        return jax.lax.psum(outputs, stage_axis)

    specs_params = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(specs_params, P()),
        out_specs=P(),
        check_vma=False)   # carries start replicated, become stage-varying
    return fn(stacked_params, x)
