"""pjit train-step builder: FSDP/TP/EP distribution, grad accumulation,
int8 gradient compression, buffer donation.

`build_train_step` returns the pure step function plus the sharding trees the
launcher (train.py) and the multi-pod dry-run both consume:

    built = build_train_step(cfg, mesh)
    jit_step = jax.jit(built["step"], in_shardings=(built["state_shardings"],
                       built["batch_shardings"](batch_shapes)),
                       out_shardings=(built["state_shardings"], None),
                       donate_argnums=(0,))
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.hsa import HSAEngine
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw, compression
from repro.runtime import sharding as shd

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    microbatches: int = 1            # >1: sequential grad accumulation
    compress_grads: bool = False     # int8 error-feedback (DCN-bound regimes)


def train_step_fn(cfg: ModelConfig, engine: HSAEngine,
                  opt_cfg: adamw.AdamWConfig, opts: TrainOptions,
                  param_axes: Params | None = None):
    """The pure step: (state, batch) -> (state, metrics).

    state = {'params', 'opt'[, 'residuals']}."""

    def loss_fn(params, batch):
        return lm.forward_train(params, batch, cfg, engine)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if opts.microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            if b % opts.microbatches != 0:
                raise ValueError(f"batch {b} not divisible by microbatches "
                                 f"{opts.microbatches}")
            return x.reshape(opts.microbatches, b // opts.microbatches,
                             *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            g_acc, l_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            return (jax.tree.map(jnp.add, g_acc, grads), l_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, l), _ = jax.lax.scan(acc_step, (zeros, jnp.float32(0.0)), micro)
        inv = 1.0 / opts.microbatches
        grads = jax.tree.map(lambda x: x * inv, g)
        return l * inv, {"loss": l * inv}, grads

    def step(state: Params, batch: Params):
        params, opt_state = state["params"], state["opt"]
        loss, metrics, grads = compute_grads(params, batch)
        if param_axes is not None:
            # Pin gradients to the param layout so the optimizer never runs
            # on replicated tensors (embedding-scatter grads arrive
            # replicated otherwise — multi-GB at 100B+ scale).
            grads = shd.constrain_tree(grads, param_axes)
        if opts.compress_grads:
            grads, new_res, _ = compression.compressed_grads(
                grads, state["residuals"])
        new_params, new_opt, opt_metrics = adamw.update(
            grads, opt_state, params, opt_cfg)
        new_state = {"params": new_params, "opt": new_opt}
        if opts.compress_grads:
            new_state["residuals"] = new_res
        return new_state, {**metrics, **opt_metrics}

    return step


def init_state(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
               opts: TrainOptions, key: jax.Array,
               abstract: bool = False):
    """(state, state_axes, linear_paths); abstract => ShapeDtypeStructs."""
    params, axes, paths = lm.init(cfg, key, abstract=abstract)
    if abstract:
        mdt = jnp.dtype(opt_cfg.moment_dtype)
        mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt), params)
        opt = {"m": mom, "v": mom,
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
        res = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    else:
        opt = adamw.init(params, opt_cfg)
        res = compression.init_residuals(params) if opts.compress_grads else None
    state = {"params": params, "opt": opt}
    state_axes = {"params": axes, "opt": {"m": axes, "v": axes, "step": ()}}
    if opts.compress_grads:
        state["residuals"] = res
        state_axes["residuals"] = axes
    return state, state_axes, paths


def build_train_step(cfg: ModelConfig, mesh: Mesh,
                     policy: shd.ShardingPolicy | None = None,
                     engine: HSAEngine | None = None,
                     opt_cfg: adamw.AdamWConfig | None = None,
                     opts: TrainOptions | None = None):
    policy = policy or shd.ShardingPolicy()
    engine = engine or HSAEngine()
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    opts = opts or TrainOptions()

    state_shapes, state_axes, paths = init_state(
        cfg, opt_cfg, opts, jax.random.key(0), abstract=True)
    step = train_step_fn(cfg, engine, opt_cfg, opts,
                         param_axes=state_axes["params"])
    st_shard = shd.tree_shardings(state_shapes, state_axes, mesh, policy)

    def batch_shardings(batch_shapes):
        return shd.shardings_from_specs(
            shd.batch_specs(batch_shapes, mesh, policy), mesh)

    return {
        "step": step,
        "state_shapes": state_shapes,
        "state_axes": state_axes,
        "state_shardings": st_shard,
        "batch_shardings": batch_shardings,
        "linear_paths": paths,
        "policy": policy,
        "init_state": lambda key: init_state(cfg, opt_cfg, opts, key)[0],
    }
