"""Logical-axis -> mesh-axis sharding rules engine.

Models annotate every param dimension with a *logical* axis name
(models/modules.py); decode caches carry their own axes tree (lm.cache_axes).
This module maps logical names onto physical mesh axes with a
divisibility-aware fallback: each logical name carries an ordered candidate
list (each candidate = one mesh axis or a composite tuple of axes); the first
candidate whose axes (a) all exist in the mesh, (b) have a product that evenly
divides the dimension, and (c) are not already used by another dimension of
the same tensor wins; otherwise the dimension is replicated.

That one rule serves every arch without special cases: hymba's 25 heads fall
through a 16-way 'model' axis to replicated (its MLP/inner dims still shard),
deepseek's 128 heads shard cleanly, long_500k's batch=1 falls through so its
KV/state length axis picks up the 'data' axis (sequence-sharded cache).

Mesh layout (launch/mesh.py):
    single-pod:  (data=16, model=16)
    multi-pod :  (pod=2, data=16, model=16)   -- 'pod' = DCN-connected pods

Baseline policy (the paper-faithful "naive" distribution; §Perf hillclimbs
swap in variants):
    * batch over ('pod','data')          (pure DP)
    * TP over 'model' for heads/mlp/vocab/experts/inner
    * FSDP (param + optimizer-state sharding) over 'data' for d_model dims
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = dict[str, Any]

Candidates = tuple[tuple[str, ...], ...]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """One named distribution strategy."""

    name: str = "baseline"
    rules: tuple[tuple[str, Candidates], ...] = (
        ("vocab", (("model",),)),
        # Embedding-table dims: the table shards on d_model (TP) with the
        # vocab rows replicated, so token gathers and their scatter-add
        # gradients never materialize a full [V, D] tensor (GSPMD handles
        # dynamic-index scatter poorly on a sharded indexed dim).
        ("embed_tp", (("model",),)),
        ("heads", (("model",),)),
        ("kv", (("model",),)),
        ("mlp", (("model",),)),
        ("experts", (("model",),)),
        ("inner", (("model",),)),
        ("embed", (("pod", "data"), ("data",))),  # FSDP/ZeRO axes (params+opt)
        ("batch", (("pod", "data"), ("data",))),
        ("cache", (("data",), ("model",))),  # KV/state length axis
        ("seq", (("model",),)),             # activation sequence-parallelism
        ("capacity", (("data",),)),         # MoE dispatch-buffer rows
        ("layers", ()),                     # scan dim: never sharded
    )

    def rule(self, logical: str | None) -> Candidates:
        if logical is None:
            return ()
        for k, v in self.rules:
            if k == logical:
                return v
        return ()

    def with_rule(self, logical: str, candidates: Candidates) -> "ShardingPolicy":
        rules = tuple((k, candidates if k == logical else v)
                      for k, v in self.rules)
        if logical not in dict(self.rules):
            rules = rules + ((logical, candidates),)
        return dataclasses.replace(self, rules=rules)


def spec_for_tensor(shape: tuple[int, ...], axes: tuple,
                    mesh: Mesh, policy: ShardingPolicy) -> P:
    """Resolve one tensor's PartitionSpec under the divisibility fallback."""
    used: set[str] = set()
    out: list = []
    for dim, logical in zip(shape, axes):
        chosen = None
        for cand in policy.rule(logical):
            size = 1
            ok = all(a in mesh.shape and a not in used for a in cand)
            if not ok:
                continue
            for a in cand:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                chosen = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(chosen)
    return P(*out)


def _walk(params: Params, axes: Params, fn, path=()):
    out = {}
    for k, v in params.items():
        a = axes.get(k) if isinstance(axes, dict) else None
        if isinstance(v, dict):
            if isinstance(a, tuple):
                # Quantized cache leaf (core/kvq): the leaf's axes tuple
                # broadcasts over the encoded sub-dict — every sub-leaf
                # keeps the leaf's rank, only the last (replicated) axis
                # is resized by the codec.
                a = {k2: a for k2 in v}
            out[k] = _walk(v, a if isinstance(a, dict) else {}, fn, path + (k,))
        elif hasattr(v, "ndim"):
            out[k] = fn(v, a, path + (k,))
        else:
            # Non-dict pytree node (e.g. OnlineRopeState): replicate; jit
            # in_shardings treats a single spec as a prefix for the subtree.
            out[k] = P()
    return out


def tree_specs(tree: Params, axes: Params, mesh: Mesh,
               policy: ShardingPolicy) -> Params:
    """PartitionSpec tree for any (params/cache) tree; ShapeDtypeStruct-safe.

    Leaves without a matching axes annotation are replicated.
    """

    def one(leaf, a, path):
        if a is None or not isinstance(a, tuple) or len(a) != leaf.ndim:
            return P()
        return spec_for_tensor(leaf.shape, a, mesh, policy)

    return _walk(tree, axes, one)


def tree_shardings(tree: Params, axes: Params, mesh: Mesh,
                   policy: ShardingPolicy) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(tree, axes, mesh, policy),
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shapes: Params, mesh: Mesh,
                policy: ShardingPolicy) -> Params:
    """Data-input sharding: leading batch dim over the DP axes."""

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        spec = spec_for_tensor(leaf.shape, ("batch",) + (None,) * (leaf.ndim - 1),
                               mesh, policy)
        return spec

    return jax.tree.map(one, batch_shapes)


def shardings_from_specs(specs: Params, mesh: Mesh) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def stacked_axes(axes: Params, n_lead: int = 1) -> Params:
    """Prepend ``n_lead`` unsharded leading dims to every axes tuple in a
    logical-axes tree — the pool's ``[n_slots, ...]`` slot stacking: lanes
    are an addressing dim, never a distribution dim."""
    if isinstance(axes, dict):
        return {k: stacked_axes(v, n_lead) for k, v in axes.items()}
    if isinstance(axes, tuple):
        return (None,) * n_lead + axes
    return axes


def _is_sharding(x) -> bool:
    return isinstance(x, jax.sharding.Sharding)


def shardings_key(tree) -> tuple:
    """Hashable identity of a shardings pytree — the jit-cache key the
    sharded engine uses so one `jax.jit` object (and its compile cache) is
    reused across calls that resolve to the same placement."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_sharding)
    return (treedef, tuple(leaves))


def sharding_leaves(tree) -> list[jax.sharding.Sharding]:
    """Every Sharding leaf of a shardings pytree (specs/None skipped) — the
    program audit walks these to prove an engine's jit entries all target
    one mesh (the ServeCell plan's), never a stray device set."""
    return [l for l in jax.tree.leaves(tree, is_leaf=_is_sharding)
            if _is_sharding(l)]


def sharding_mismatches(tree: Params, shardings: Params) -> list[str]:
    """Array leaves whose actual sharding is not equivalent to the expected
    one — the `jax.debug.visualize_array_sharding`-style on-mesh check, as
    data.  ``shardings`` may be a prefix tree (a single sharding standing
    for a whole subtree, as `tree_shardings` emits for non-dict nodes).
    Returns human-readable mismatch descriptions; empty means fully placed.
    """
    bad: list[str] = []

    def check(leaf, expect, path):
        if not (_is_sharding(expect) and hasattr(leaf, "sharding")):
            return
        if not leaf.sharding.is_equivalent_to(expect, leaf.ndim):
            bad.append(f"{'/'.join(map(str, path))}: "
                       f"{leaf.sharding} != {expect}")

    def rec(t, s, path):
        if isinstance(t, dict):
            for k, v in t.items():
                rec(v, s[k] if isinstance(s, dict) else s, path + (k,))
        elif hasattr(t, "ndim") and hasattr(t, "sharding"):
            check(t, s, path)
        else:                        # non-dict pytree node: one sharding
            for i, leaf in enumerate(jax.tree.leaves(t)):
                if hasattr(leaf, "sharding"):
                    check(leaf, s, path + (f"[{i}]",))

    rec(tree, shardings, ())
    return bad


# ---------------------------------------------------------------------------
# Activation sharding constraints (logical): a contextvar carries the active
# (mesh, policy) so model code can annotate intermediates ('seq'-parallel
# residual stream, MoE dispatch buffers) without threading mesh handles
# through every layer.  Outside a context (CPU smoke tests) it's a no-op —
# the MaxText-style logical-constraint pattern.
# ---------------------------------------------------------------------------

_CTX: contextvars.ContextVar[tuple[Mesh, ShardingPolicy] | None] = \
    contextvars.ContextVar("sharding_ctx", default=None)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, policy: ShardingPolicy):
    tok = _CTX.set((mesh, policy))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current_ctx() -> tuple[Mesh, ShardingPolicy] | None:
    """The active (mesh, policy), or None outside a sharding context."""
    return _CTX.get()


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, policy = ctx
    spec = spec_for_tensor(x.shape, logical_axes, mesh, policy)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree: Params, axes: Params) -> Params:
    """`constrain` over a whole tree (e.g. gradients onto the param layout,
    so optimizer math never runs on accidentally-replicated tensors)."""
    ctx = _CTX.get()
    if ctx is None:
        return tree
    mesh, policy = ctx

    def rec(t, a):
        if isinstance(t, dict):
            if isinstance(a, tuple):
                a = {k: a for k in t}       # quantized leaf: broadcast tuple
            return {k: rec(v, a.get(k) if isinstance(a, dict) else None)
                    for k, v in t.items()}
        if hasattr(t, "ndim") and isinstance(a, tuple) and len(a) == t.ndim:
            spec = spec_for_tensor(t.shape, a, mesh, policy)
            return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))
        return t

    return rec(tree, axes)
