"""Distributed runtime: sharding rules, step builders, checkpointing,
fault tolerance, pipeline parallelism."""
