"""Analytic per-cell workload model: MODEL_FLOPS / HBM bytes / roofline terms.

XLA's `cost_analysis()` counts each `while` (lax.scan) body once — verified
experimentally (EXPERIMENTS.md §Dry-run methodology) — so scanned-layer models
under-report FLOPs/bytes by ~L x.  This module derives the terms analytically
from the config (exact trip counts, standard 6ND accounting), while the
*collective* term comes from the partitioned HLO with trip-count correction
(launch/dryrun.py).  Both the analytic and raw-HLO numbers appear in
EXPERIMENTS.md §Roofline.

Hardware constants (TPU v5e-class, per the assignment):
    197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.models import lm
from repro.models.config import InputShape, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 6.25e9          # ~50 Gb/s effective per-chip cross-pod share

MX_BITS = 4.25           # MXINT4 streamed bits/weight (C2)


@dataclasses.dataclass(frozen=True)
class ParamCounts:
    total: int           # all params
    expert: int          # routed-expert params (EP-sharded, sparsely active)
    embed: int           # embedding + lm_head

    @property
    def active(self) -> float:
        return self.total - self.expert  # + the active slice, added below


def param_counts(cfg: ModelConfig) -> ParamCounts:
    shapes, _, _ = lm.init(cfg, jax.random.key(0), abstract=True)
    total = expert = 0

    def walk(tree, in_experts):
        nonlocal total, expert
        for k, v in tree.items():
            if isinstance(v, dict):
                walk(v, in_experts or k == "experts")
            else:
                total += v.size
                if in_experts:
                    expert += v.size

    walk(shapes, False)
    embed = shapes["embed"].size + shapes["lm_head"]["w"].size
    return ParamCounts(total=total, expert=expert, embed=embed)


def active_params(cfg: ModelConfig, pc: ParamCounts) -> float:
    """Params touched per token (MoE: shared + top-k slice of experts)."""
    if cfg.n_experts:
        return pc.total - pc.expert * (1 - cfg.top_k / cfg.n_experts)
    return pc.total


def _attn_flops_per_token(cfg: ModelConfig, context: float) -> float:
    """Score+value matmul FLOPs per token at the given average context."""
    if cfg.family == "ssm":
        return 4 * cfg.n_layers * cfg.d_inner_ * cfg.ssm_state
    if cfg.family == "retnet":
        dk, dv = cfg.d_model // cfg.n_heads, 2 * cfg.d_model // cfg.n_heads
        return 4 * cfg.n_layers * cfg.n_heads * dk * dv
    h, hd = cfg.n_heads, cfg.head_dim_
    if cfg.attn_type == "mla":
        hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ctx = min(context, cfg.sliding_window) if cfg.sliding_window else context
    layers = cfg.n_layers + cfg.encoder_layers
    ssm_extra = (4 * cfg.n_layers * cfg.d_inner_ * cfg.ssm_state
                 if cfg.family == "hybrid" else 0)
    return 4 * layers * h * hd * ctx + ssm_extra


@dataclasses.dataclass(frozen=True)
class Workload:
    """Per-device, per-step workload of one (arch x shape x mesh) cell."""

    model_flops: float        # useful FLOPs (causal-aware, analytic)
    hbm_bytes: float          # analytic HBM traffic
    tokens: float             # tokens processed per step per device

    def compute_term(self) -> float:
        return self.model_flops / PEAK_FLOPS

    def memory_term(self) -> float:
        return self.hbm_bytes / HBM_BW


def train_workload(cfg: ModelConfig, shape: InputShape, n_chips: int,
                   remat_factor: float = 1.0,
                   param_bytes_each: float = 2.0,
                   moment_bytes_each: float = 4.0) -> Workload:
    """6ND accounting + attention + full-remat recompute.

    fwd 2ND + bwd 4ND (+ remat re-fwd 2ND * remat_factor).
    HBM: params read fwd+bwd+rematfwd + grads r/w + moments r/w + new params
    + activation stack w+r (layer inputs, bf16, seq-parallel).
    """
    pc = param_counts(cfg)
    n_act = active_params(cfg, pc)
    tokens = shape.global_batch * shape.seq_len
    causal_ctx = shape.seq_len / 2
    flops_tok = (6 + 2 * remat_factor) * n_act \
        + 1.5 * _attn_flops_per_token(cfg, causal_ctx)  # fwd+bwd+remat attn
    model_flops = flops_tok * tokens / n_chips

    p_bytes = pc.total * param_bytes_each
    weight_traffic = p_bytes * (2 + remat_factor)     # fwd + bwd + remat reads
    grad_traffic = 2 * p_bytes
    opt_traffic = 2 * 2 * pc.total * moment_bytes_each + p_bytes
    act_stack = 2 * (cfg.n_layers + cfg.encoder_layers) * tokens \
        * cfg.d_model * 2.0                            # save + re-read, bf16
    hbm = (weight_traffic + grad_traffic + opt_traffic) / n_chips \
        + act_stack / n_chips
    return Workload(model_flops, hbm, tokens / n_chips)


def prefill_workload(cfg: ModelConfig, shape: InputShape,
                     n_chips: int) -> Workload:
    pc = param_counts(cfg)
    n_act = active_params(cfg, pc)
    tokens = shape.global_batch * shape.seq_len
    flops_tok = 2 * n_act + 0.5 * _attn_flops_per_token(cfg, shape.seq_len / 2)
    model_flops = flops_tok * tokens / n_chips
    # W8A8 prefill: int8 weights read once per weight tile reuse window;
    # activations stream through; KV cache written once.
    hbm = (pc.total * 1.0 + tokens * cfg.d_model * 2 * 4
           + _cache_bytes(cfg, shape.seq_len, shape.global_batch)) / n_chips
    return Workload(model_flops, hbm, tokens / n_chips)


def _cache_bytes(cfg: ModelConfig, cache_len: int, batch: int,
                 dtype_bytes: float = 2.0,
                 cache_format: str | None = None) -> float:
    """Total decode-cache footprint (read per decode step).

    ``cache_format`` selects the quantized decode-residency encoding
    (`core.kvq.FORMATS`); it reprices the *attention* KV rows via
    `kvq.nbytes_per_row` and leaves the fp recurrent states (ssm / retnet /
    hybrid-mamba) untouched — exactly what `lm.quantize_cache` encodes."""
    from repro.core import kvq

    layers = cfg.n_layers
    if cfg.family == "ssm":
        return layers * batch * cfg.d_inner_ * cfg.ssm_state * 4 * 2
    if cfg.family == "retnet":
        dk, dv = cfg.d_model // cfg.n_heads, 2 * cfg.d_model // cfg.n_heads
        return layers * batch * cfg.n_heads * dk * dv * 4 * 2

    def row(d: int) -> float:
        if cache_format is None:
            return d * dtype_bytes
        return kvq.nbytes_per_row(cache_format, d)

    if cfg.attn_type == "mla":
        per_tok = row(cfg.kv_lora_rank) + row(cfg.qk_rope_head_dim)
        return layers * batch * cache_len * per_tok
    ctx = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    kv = layers * batch * ctx * cfg.n_kv_heads * 2 * row(cfg.head_dim_)
    if cfg.family == "hybrid":
        kv += layers * batch * cfg.d_inner_ * cfg.ssm_state * 4 * 2
    return kv


def decode_workload(cfg: ModelConfig, shape: InputShape, n_chips: int,
                    weight_bits: float = MX_BITS,
                    cache_format: str | None = None) -> Workload:
    """One decode step: every active weight streamed, cache read+updated."""
    pc = param_counts(cfg)
    n_act = active_params(cfg, pc)
    b = shape.global_batch
    flops = (2 * n_act + _attn_flops_per_token(cfg, shape.seq_len)) * b / n_chips
    # MoE decode with small batch: only experts hit by b*top_k tokens stream.
    weight_entities = n_act if not cfg.n_experts else (
        pc.total - pc.expert
        + pc.expert * min(1.0, b * cfg.top_k / cfg.n_experts))
    hbm = (weight_entities * weight_bits / 8
           + _cache_bytes(cfg, shape.seq_len, b,
                          cache_format=cache_format)) / n_chips
    return Workload(flops, hbm, b / n_chips)


def cell_workload(cfg: ModelConfig, shape: InputShape, n_chips: int,
                  **kw) -> Workload:
    if shape.kind == "train":
        return train_workload(cfg, shape, n_chips, **kw)
    if shape.kind == "prefill":
        return prefill_workload(cfg, shape, n_chips)
    return decode_workload(cfg, shape, n_chips, **kw)
