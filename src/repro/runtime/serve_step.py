"""Serving step builders: prefill (MMM dataflow) and decode (MVM dataflow).

The serving params are the *deployed* tree (models/deploy.py): prefill streams
per-tensor INT8, decode streams MXINT4 packed+shifts — the paper's phase-
dependent formats (C1/C2).  Cache sharding comes from lm.cache_axes + the
rules engine: batch over DP axes when divisible, sequence-sharded KV for
long_500k, TP'd SSM state.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.hsa import HSAConfig, HSAEngine
from repro.models import deploy, lm
from repro.models.config import InputShape, ModelConfig
from repro.runtime import sharding as shd

Params = dict[str, Any]


def serving_engine(kernel_impl: str = "auto") -> HSAEngine:
    return HSAEngine(HSAConfig(prefill_format="w8a8", decode_format="mxint4",
                               kernel_impl=kernel_impl))


def deployed_shapes(cfg: ModelConfig) -> tuple[Params, Params]:
    """(serving param ShapeDtypeStructs, their axes) — no allocation."""
    params_abs, axes, paths = lm.init(cfg, jax.random.key(0), abstract=True)
    served = jax.eval_shape(
        lambda p: deploy.deploy_quantize(p, paths), params_abs)
    served_axes = deploy.deployed_axes(axes, paths)
    return served, served_axes


def prefill_step_fn(cfg: ModelConfig, engine: HSAEngine, cache_len: int = 0):
    def prefill(params, batch):
        return lm.forward_prefill(params, batch, cfg, engine,
                                  cache_len=cache_len)
    return prefill


def decode_step_fn(cfg: ModelConfig, engine: HSAEngine):
    def decode(params, tokens, cache):
        logits, new_cache = lm.forward_decode(params, tokens, cache, cfg, engine)
        return logits, new_cache
    return decode


def build_serve(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                policy: shd.ShardingPolicy | None = None,
                kernel_impl: str = "auto",
                local_batch: int | None = None,
                cache_dtype=jnp.bfloat16):
    """Shardings + shapes for one serving cell (prefill or decode kind)."""
    policy = policy or shd.ShardingPolicy()
    engine = serving_engine(kernel_impl)
    batch = local_batch or shape.global_batch

    served_shapes, served_axes = deployed_shapes(cfg)
    param_shardings = shd.tree_shardings(served_shapes, served_axes, mesh, policy)

    cache_shapes = jax.eval_shape(
        lambda: lm.make_decode_cache(cfg, batch, shape.seq_len, cache_dtype))
    c_axes = lm.cache_axes(cfg)
    # Prepend 'batch' resolution: cache axes use the logical 'batch'/'cache'
    # names directly; tree_specs resolves per-tensor with fallback.
    cache_shardings = shd.tree_shardings(cache_shapes, c_axes, mesh, policy)

    return {
        "engine": engine,
        "prefill": prefill_step_fn(cfg, engine, cache_len=shape.seq_len),
        "decode": decode_step_fn(cfg, engine),
        "param_shapes": served_shapes,
        "param_axes": served_axes,
        "param_shardings": param_shardings,
        "cache_shapes": cache_shapes,
        "cache_shardings": cache_shardings,
        "policy": policy,
    }
