"""Serving step builders — thin shim over `repro.serving.cell`.

The serving-cell planner (typed `ServeCell`: shardings + shapes for one
prefill/decode deployment) moved to `repro.serving`, the unified inference
package.  This module keeps the historical import path alive for runtime
callers (launch/dryrun.py and external scripts); new code should import from
`repro.serving` directly.
"""

from __future__ import annotations

from repro.serving.cell import (ServeCell, build_serve, decode_step_fn,
                                deployed_shapes, prefill_step_fn,
                                serving_engine)

__all__ = ["ServeCell", "build_serve", "decode_step_fn", "deployed_shapes",
           "prefill_step_fn", "serving_engine"]
