"""Sharded checkpoint manager: atomic, async, keep-N, reshard-on-load.

Design for the 1000+-node regime (DESIGN.md):
  * **Atomicity** — write to ``step_XXXX.tmp/`` then os.rename; a crash
    mid-save never corrupts the latest checkpoint.
  * **Async** — serialization runs on a background thread so the train loop
    only blocks on device->host transfer (`save(..., blocking=False)`).
  * **Keep-N** — bounded disk footprint, oldest checkpoints garbage-collected.
  * **Reshard-on-load** — the manifest stores only the logical tree; restore
    takes the *current* mesh's shardings and `jax.device_put`s each leaf into
    them, so a checkpoint written on a 512-chip mesh restores onto a shrunken
    elastic mesh (fault_tolerance.plan_elastic_mesh) or a single CPU host.

Storage: one ``.npz`` per checkpoint with '/'-joined tree paths (pure numpy —
no orbax dependency), plus a JSON manifest (step, tree structure, dtypes).
On a real multi-host pod each host would write its address-space shard; the
single-process container gathers to host first (noted in DESIGN.md §8).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]

_SEP = "/"


def _flatten(tree: Params, prefix="") -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "".join(
            p.key if hasattr(p, "key") else f"[{p.idx}]" if hasattr(p, "idx")
            else str(p) for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def _tree_paths(tree: Params):
    return jax.tree_util.tree_flatten_with_path(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------

    def save(self, step: int, state: Params, extra: dict | None = None,
             blocking: bool = True) -> None:
        """Snapshot `state` at `step`.  Device->host copy happens here;
        serialization happens on a worker thread unless blocking.

        Leaves are stored as raw uint8 buffers (npz can't encode bf16/int4);
        the manifest carries dtype+shape for reconstruction."""
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(x) for x in leaves]      # gather to host

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "state.npz"),
                     **{f"leaf_{i}": np.frombuffer(a.tobytes(), np.uint8)
                        for i, a in enumerate(host_leaves)})
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "dtypes": [str(a.dtype) for a in host_leaves],
                "shapes": [list(a.shape) for a in host_leaves],
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                          # atomic publish
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Params, step: int | None = None,
                shardings: Params | None = None) -> tuple[Params, dict]:
        """Load into the structure of `like`; device_put into `shardings`
        (the *current* mesh) if given — this is the elastic reshard path."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "state.npz"))
        like_leaves, treedef = jax.tree_util.tree_flatten(like)
        leaves = []
        for i, ref in enumerate(like_leaves):
            raw = data[f"leaf_{i}"]
            dt = np.dtype(ref.dtype)
            leaves.append(np.frombuffer(raw.tobytes(), dt).reshape(ref.shape))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        state = jax.tree.map(
            lambda ref, x: jnp.asarray(x, dtype=ref.dtype), like, state)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest
