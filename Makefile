# Developer entry points.  `make test` is the tier-1 verification command
# (ROADMAP.md); PYTHONPATH=src keeps the repo importable without installing.

PY ?= python

.PHONY: test test-fast install serve-demo bench-serving

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q \
		tests/test_serving_engine.py tests/test_serving.py tests/test_kernels.py

install:
	$(PY) -m pip install -e .[test]

serve-demo:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch retnet-1.3b --reduced --scenario SILO --scale 0.1 --batch 2

# Serving-path perf trajectory: writes BENCH_serving.json (tokens/s, prefill
# compiles triggered, decode-stall steps) for PR-over-PR comparison.
bench-serving:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_serving
