# Developer entry points.  `make test` is the tier-1 verification command
# (ROADMAP.md); PYTHONPATH=src keeps the repo importable without installing.

PY ?= python

.PHONY: test test-fast install serve-demo smoke-host-spill smoke-prefix \
	smoke-frontend smoke-sharded trace-demo bench-serving bench-kernels \
	lint-invariants audit-program

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q \
		tests/test_serving_engine.py tests/test_serving.py tests/test_kernels.py

install:
	$(PY) -m pip install -e .[test]

serve-demo:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch retnet-1.3b --reduced --scenario SILO --scale 0.1 --batch 2

# Tiny oversubscribed scheduler run: 5 requests over 2 device lanes with the
# host-memory spill tier + priority preemption (CI smoke leg).
smoke-host-spill:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch retnet-1.3b --reduced --scenario SILO --scale 0.02 \
		--requests 5 --slots 2 --chunk-size 8 --host-spill

# Shared-prefix reuse smoke: 5 requests repeating one system prompt through
# a prefix_cache=True scheduler — later admissions adopt the cached pages
# and prefill only their unique tails (hit stats printed; CI smoke leg).
smoke-prefix:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch qwen3-8b --reduced --scenario LISO --scale 0.08 \
		--requests 5 --slots 2 --chunk-size 8 --prefix-cache

# Open-loop front-end smoke on the deterministic virtual clock: 8 bursty
# arrivals through the asyncio frontend's SLO-aware admission — wall-clock
# free, and `serve.py` itself asserts the contract (nonzero goodput, zero
# unexplained sheds) before exiting 0 (CI smoke leg).
smoke-frontend:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch retnet-1.3b --reduced --scenario SILO --scale 0.02 \
		--frontend --virtual-clock --requests 8 --rate 40 --slots 2 \
		--chunk-size 8 --arrival bursty

# Tiny multi-chip smoke: a 2x2 virtual-device (data, model) mesh serving
# 3 requests through one device lane with the host-spill tier — a sharded
# generate plus one preemption/resume round trip (CI multi-device leg).
smoke-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch retnet-1.3b --reduced --scenario SILO --scale 0.02 \
		--requests 3 --slots 1 --chunk-size 8 --host-spill --mesh 2,2

# Observability demo: the oversubscribed scheduler run with request-lifecycle
# tracing on — writes trace.json (Chrome trace events; load in Perfetto or
# chrome://tracing to see admits, prefill chunks, preempt/resume gaps) and
# metrics.json (counters/gauges/p50-p95-p99 histograms).  CI uploads both.
trace-demo:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve \
		--arch retnet-1.3b --reduced --scenario SILO --scale 0.02 \
		--requests 5 --slots 2 --chunk-size 8 --host-spill \
		--trace trace.json --metrics metrics.json

# Serving-path perf trajectory: writes BENCH_serving.json (tokens/s, prefill
# compiles triggered, decode-stall steps) for PR-over-PR comparison.
bench-serving:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_serving

# Kernel microbench: MXINT4 dequant-matmul block sweep + the flash-decode
# split-KV attention leg (byte ladder per cache format + interpret-mode wall
# cross-check of the Pallas kernel vs the jnp reference).
bench-kernels:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.kernel_bench

# Layer-1 invariant lint: AST rules over src/repro (compat-api routing, no
# bare asserts, no host syncs on the hot path, no module-scope jnp work).
# Fast — no jax import.  docs/analysis.md documents the rules.
lint-invariants:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.analysis lint

# Layer-2 program audit: compile the serving hot path and check the lowered
# programs (recompile ladder, cache donation, transfer-free decode loop,
# ServeCell sharding realization).  The 4 virtual devices give the sharding
# audit a real 2x2 (data, model) mesh; the flag must precede jax init.
audit-program:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.analysis audit \
		--mesh 2,2
